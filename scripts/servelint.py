#!/usr/bin/env python
"""Run servelint from a checkout without installing the package:

    python scripts/servelint.py src tests benchmarks examples scripts

Thin wrapper over ``python -m repro.analysis`` that puts ``src/`` on
sys.path; keeps working on a bare interpreter (no jax required).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
