"""Quick dev smoke: every reduced arch forward + prefill/decode parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.models import init_model, model_decode, model_forward, model_prefill


def batch_for(cfg, B=2, S=16):
    rng = np.random.RandomState(0)
    b = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "vlm":
        F = cfg.frontend_seq
        b["vision_embeds"] = jnp.asarray(rng.randn(B, F, cfg.d_model), jnp.float32)
        pos = np.arange(F + S)
        b["positions"] = jnp.asarray(np.broadcast_to(pos[None, :, None], (B, F + S, 3)).copy())
    if cfg.family == "encdec":
        b["src_embeds"] = jnp.asarray(rng.randn(B, cfg.frontend_seq, cfg.d_model), jnp.float32)
    return b


B, S = 2, 16
for name, full in ARCHS.items():
    cfg = full.reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    b = batch_for(cfg, B, S)
    F = cfg.frontend_seq if cfg.family == "vlm" else 0
    logits, aux = model_forward(params, cfg, b)
    assert not bool(jnp.isnan(logits).any()), name

    lp, cache = model_prefill(params, cfg, b, cache_len=F + S + 8)
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    dec_pos = jnp.int32(F + S)
    dec_positions = jnp.full((B, 1, 3), F + S, jnp.int32) if cfg.family == "vlm" else None
    ld, cache = model_decode(params, cfg, nxt, cache, dec_pos, positions=dec_positions)

    b2 = dict(b)
    b2["tokens"] = jnp.concatenate([b["tokens"], nxt], axis=1)
    if cfg.family == "vlm":
        pos = np.arange(F + S + 1)
        b2["positions"] = jnp.asarray(np.broadcast_to(pos[None, :, None], (B, F + S + 1, 3)).copy())
    lf, _ = model_forward(params, cfg, b2)
    err0 = float(jnp.max(jnp.abs(lp - lf[:, -2])))
    err1 = float(jnp.max(jnp.abs(ld - lf[:, -1])))
    print(f"{name:24s} logits={tuple(logits.shape)} prefill_err={err0:.2e} decode_err={err1:.2e} aux={float(aux):.3f}")
print("OK")
