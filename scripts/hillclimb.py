"""§Perf hillclimb runner: lowers baseline + candidate variants for the
three selected (arch x shape) pairs and prints before/after roofline terms.

Run AFTER the baseline artifact regen:
  PYTHONPATH=src python scripts/hillclimb.py [--target h1|h2|h3|all]
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_pair  # noqa: E402  (sets XLA_FLAGS first)


def show(tag, art):
    print(f"  {tag:28s} compute={1e3*art['compute_s']:9.3f}ms "
          f"memory={1e3*art['memory_s']:9.3f}ms "
          f"collective={1e3*art['collective_s']:9.3f}ms "
          f"dominant={art['dominant']}")
    return art


def h1():
    """command-r-plus decode_32k — most collective-bound.

    Hypothesis: kv_heads=8 < model=16 forces the KV cache onto sequence
    sharding (context-parallel decode) -> partial-softmax all-gathers every
    step. A per-instance (data=32, model=8) topology keeps all 256 chips
    but lets kv heads shard cleanly -> predict collective term drops ~10x
    while compute/memory stay flat (same chip count)."""
    print("\n[H1] command-r-plus-104b x decode_32k")
    base = show("baseline (16x16)",
                run_pair("command-r-plus-104b", "decode_32k", verbose=False))
    opt = show("variant mesh 32x8",
               run_pair("command-r-plus-104b", "decode_32k", verbose=False,
                        variant="mesh32x8", mesh_shape=(32, 8)))
    print(f"  -> collective {1e3*base['collective_s']:.3f} -> "
          f"{1e3*opt['collective_s']:.3f} ms "
          f"({100*(1-opt['collective_s']/max(base['collective_s'],1e-12)):+.0f}% reduction)")
    return base, opt


def h2():
    """smollm-360m x train_4k — worst roofline fraction (comm/compute ~0.9).

    Over-sharded tiny model. Candidates (napkin math in EXPERIMENTS.md):
    (a) TP=4 instead of 16 (mesh 64x4): 4x fewer ranks in the per-layer
        all-reduces and larger per-rank shards; (b) no-remat (kills the
        recompute pass's duplicated collectives at the cost of memory)."""
    print("\n[H2] smollm-360m x train_4k")
    base = show("baseline (16x16)",
                run_pair("smollm-360m", "train_4k", verbose=False))
    a = show("variant mesh 64x4",
             run_pair("smollm-360m", "train_4k", verbose=False,
                      variant="mesh64x4", mesh_shape=(64, 4)))
    b = show("variant noremat",
             run_pair("smollm-360m", "train_4k", verbose=False,
                      variant="noremat", remat_=False))
    c = show("variant mesh64x4+noremat",
             run_pair("smollm-360m", "train_4k", verbose=False,
                      variant="mesh64x4_noremat", mesh_shape=(64, 4),
                      remat_=False))
    return base, a, b, c


def h3():
    """deepseek-v2-236b x decode_32k — paper-representative (largest served
    decode; the Spin cost model's dominant regime).

    Hypothesis: the no-drop decode dispatch (capacity = T = 128) makes all
    160 experts process up to 128 slots -> ~21x more expert compute/bytes
    than the routed top-6 needs. Capacity factor 2.5 bounds the buffer at
    C = ceil(128*6*2.5/160) = 12 with negligible drop probability
    (P[Binom(768, 1/160) > 12] ~ 1e-3 per expert)."""
    print("\n[H3] deepseek-v2-236b x decode_32k")
    base = show("baseline (no-drop)",
                run_pair("deepseek-v2-236b", "decode_32k", verbose=False))
    opt = show("variant moe_cf=2.5",
               run_pair("deepseek-v2-236b", "decode_32k", verbose=False,
                        variant="moecf2.5", decode_moe_cf=2.5))
    both = show("variant cf2.5+mesh32x8",
                run_pair("deepseek-v2-236b", "decode_32k", verbose=False,
                         variant="moecf2.5_mesh32x8", decode_moe_cf=2.5,
                         mesh_shape=(32, 8)))
    return base, opt, both


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", default="all",
                    choices=["h1", "h2", "h3", "all"])
    args = ap.parse_args()
    if args.target in ("h1", "all"):
        h1()
    if args.target in ("h2", "all"):
        h2()
    if args.target in ("h3", "all"):
        h3()
