"""CI gate over a --metrics-dump artifact set.

Asserts the observability plane actually observed a serve run:

  * the Prometheus exposition has NON-ZERO ``ttft_s`` and ``itl_s``
    histogram counts (per-request lifecycle tracing fired);
  * the event log records at least one capacity decision (a ``scale``
    event from the replica pool or an ``orch`` event from Algorithm 1);
  * the cost attribution plane fired: a nonzero ``cost_per_query_usd``
    gauge (the chip-second ledger closed at least one request) and
    nonzero ``kv_pool_bytes`` gauges (resident-memory accounting);
  * optionally, a flight-record JSONL (second argument) parses and
    follows the recorder schema: every line is a ``dump`` / ``step`` /
    ``event`` record with a timestamp, and at least one dump header
    exists;
  * with ``--chaos``, the fault-tolerance plane actually fired: at
    least one fault injected (``fault_injected_total``), at least one
    replica quarantined (``replicas_quarantined_total``), and at least
    one retried request that went on to FINISH
    (``retries_recovered_total``) — a chaos run where nothing was
    killed, or nothing recovered, proves nothing.

Usage: python scripts/check_metrics_dump.py [--chaos] PATH [FLIGHT_JSONL]
       (expects PATH and PATH.events.jsonl as written by
        ``write_metrics_dump`` / ``--metrics-dump``; FLIGHT_JSONL as
        written by ``--flight-record``)
"""
from __future__ import annotations

import json
import re
import sys


def hist_count(text: str, metric: str) -> int:
    """Total observations across every label of ``metric``."""
    pat = re.compile(rf"^repro_{metric}_count(?:\{{[^}}]*\}})? (\d+)$")
    return sum(int(m.group(1)) for ln in text.splitlines()
               if (m := pat.match(ln)))


def gauge_values(text: str, metric: str) -> list:
    """Every sample value of a gauge/counter ``metric`` (any labels)."""
    pat = re.compile(rf"^repro_{metric}(?:\{{[^}}]*\}})? (\S+)$")
    return [float(m.group(1)) for ln in text.splitlines()
            if (m := pat.match(ln))]


def check_flight(path: str, failures: list) -> None:
    kinds = {"dump": 0, "step": 0, "event": 0}
    for i, ln in enumerate(open(path), 1):
        if not ln.strip():
            continue
        try:
            rec = json.loads(ln)
        except json.JSONDecodeError:
            failures.append(f"flight line {i}: not valid JSON")
            return
        kind = rec.get("record")
        if kind not in kinds:
            failures.append(f"flight line {i}: unknown record {kind!r}")
            return
        if not isinstance(rec.get("t"), (int, float)):
            failures.append(f"flight line {i}: missing timestamp")
            return
        if kind == "dump" and "reason" not in rec:
            failures.append(f"flight line {i}: dump without reason")
            return
        if kind == "event" and "event" not in rec:
            failures.append(f"flight line {i}: event without name")
            return
        kinds[kind] += 1
    print(f"{'flight':12s} records:      "
          f"{kinds['dump']:3d} dumps / {kinds['step']} steps / "
          f"{kinds['event']} events  "
          f"[{'ok' if kinds['dump'] else 'MISSING'}]")
    if not kinds["dump"]:
        failures.append("flight record has no dump header")


def check_chaos(text: str, failures: list) -> None:
    for metric, what in (
            ("fault_injected_total", "no fault was ever injected"),
            ("replicas_quarantined_total", "no replica was quarantined"),
            ("retries_recovered_total",
             "no retried request ever finished")):
        total = sum(gauge_values(text, metric))
        status = "ok" if total > 0 else "MISSING"
        print(f"{metric[:12]:12s} total:        {total:6.0f}  [{status}]")
        if total <= 0:
            failures.append(f"{what} ({metric} is zero)")


def main() -> int:
    args = [a for a in sys.argv[1:] if a != "--chaos"]
    chaos = len(args) < len(sys.argv) - 1
    if len(args) not in (1, 2):
        print(__doc__)
        return 2
    path = args[0]
    text = open(path).read()
    failures = []
    for metric in ("ttft_s", "itl_s"):
        n = hist_count(text, metric)
        status = "ok" if n > 0 else "MISSING"
        print(f"{metric:12s} observations: {n:6d}  [{status}]")
        if n == 0:
            failures.append(f"{metric} histogram is empty")
    cost = gauge_values(text, "cost_per_query_usd")
    print(f"{'cost/query':12s} gauges:       {len(cost):6d}  "
          f"[{'ok' if any(v > 0 for v in cost) else 'MISSING'}]")
    if not any(v > 0 for v in cost):
        failures.append("no nonzero cost_per_query_usd gauge "
                        "(chip-second ledger never closed a request)")
    kv = gauge_values(text, "kv_pool_bytes")
    print(f"{'kv bytes':12s} gauges:       {len(kv):6d}  "
          f"[{'ok' if sum(kv) > 0 else 'MISSING'}]")
    if sum(kv) <= 0:
        failures.append("kv_pool_bytes gauges missing or all zero")
    events = [json.loads(ln)
              for ln in open(path + ".events.jsonl") if ln.strip()]
    scale = [e for e in events if e["event"] in ("scale", "orch")]
    print(f"{'scale/orch':12s} events:       {len(scale):6d}  "
          f"[{'ok' if scale else 'MISSING'}]")
    if not scale:
        failures.append("no scale/orch capacity decision in the event log")
    if chaos:
        check_chaos(text, failures)
    if len(args) == 2:
        check_flight(args[1], failures)
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    print("metrics dump: all observability gates pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
