"""CI gate over a --metrics-dump artifact set.

Asserts the observability plane actually observed a serve run:

  * the Prometheus exposition has NON-ZERO ``ttft_s`` and ``itl_s``
    histogram counts (per-request lifecycle tracing fired);
  * the event log records at least one capacity decision (a ``scale``
    event from the replica pool or an ``orch`` event from Algorithm 1).

Usage: python scripts/check_metrics_dump.py PATH
       (expects PATH and PATH.events.jsonl as written by
        ``write_metrics_dump`` / ``--metrics-dump``)
"""
from __future__ import annotations

import json
import re
import sys


def hist_count(text: str, metric: str) -> int:
    """Total observations across every label of ``metric``."""
    pat = re.compile(rf"^repro_{metric}_count(?:\{{[^}}]*\}})? (\d+)$")
    return sum(int(m.group(1)) for ln in text.splitlines()
               if (m := pat.match(ln)))


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    text = open(path).read()
    failures = []
    for metric in ("ttft_s", "itl_s"):
        n = hist_count(text, metric)
        status = "ok" if n > 0 else "MISSING"
        print(f"{metric:12s} observations: {n:6d}  [{status}]")
        if n == 0:
            failures.append(f"{metric} histogram is empty")
    events = [json.loads(ln)
              for ln in open(path + ".events.jsonl") if ln.strip()]
    scale = [e for e in events if e["event"] in ("scale", "orch")]
    print(f"{'scale/orch':12s} events:       {len(scale):6d}  "
          f"[{'ok' if scale else 'MISSING'}]")
    if not scale:
        failures.append("no scale/orch capacity decision in the event log")
    if failures:
        print("FAIL: " + "; ".join(failures))
        return 1
    print("metrics dump: all observability gates pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
