"""Reinforcement-based routing (beyond-paper / the paper's future work)."""
import numpy as np

from repro.configs.registry import ARCHS
from repro.core import (PROFILES, ClusterSimulator, KeywordRouter,
                        ServiceRegistry, SimConfig, poisson_arrivals)
from repro.core.bandit import BanditPolicy, BetaArm
from repro.data.benchmarks import generate_corpus

POOL = ["smollm-360m", "phi3-medium-14b", "command-r-plus-104b"]


def test_beta_arm_updates():
    arm = BetaArm()
    for _ in range(30):
        arm.update(True)
    for _ in range(10):
        arm.update(False)
    assert 0.6 < arm.mean < 0.85
    rng = np.random.RandomState(0)
    draws = [arm.sample(rng) for _ in range(200)]
    assert 0.5 < np.mean(draws) < 0.9


def test_bandit_learns_tier_structure():
    """After enough closed-loop traffic, the posterior prefers large
    models for high-complexity prompts and not for low ones."""
    prompts = generate_corpus(1200, seed=21)
    decisions = KeywordRouter().route_many([p.text for p in prompts])
    arr = poisson_arrivals(prompts, 10.0, seed=21)
    workload = [(t, p, d) for (t, p), d in zip(arr, decisions)]
    reg = ServiceRegistry({k: ARCHS[k] for k in POOL})
    pol = BanditPolicy(reg, seed=21)
    sim = ClusterSimulator(reg, pol, PROFILES["balanced"],
                           SimConfig(seed=21, static=True))
    rep = sim.run(workload)
    assert pol.n_feedback > 1000
    learned = pol.learned_capability()
    # high-complexity: large must beat small in learned success rate
    hi_large = learned.get("large", {}).get("high", 0.5)
    hi_small = learned.get("small", {}).get("high", 0.5)
    assert hi_large > hi_small
    # the system stays functional while learning
    assert rep.success_rate() > 0.5


def test_bandit_select_returns_valid_selection():
    reg = ServiceRegistry({k: ARCHS[k] for k in POOL})
    for e in reg.entries():
        e.replicas = 1
    pol = BanditPolicy(reg, seed=0)
    d = KeywordRouter().route("prove the theorem step by step")
    sel = pol.select(d, 64, 64, PROFILES["balanced"])
    assert sel.entry is not None
    assert sel.pred_latency > 0
