"""servelint: fixture-pair tests per rule, suppression honoring,
config loading, and the self-clean gate on the repo's own sources.

The fixture corpus under ``tests/fixtures/servelint/`` seeds the exact
bugs the rules were built from (the PR-6 mixed-clock stamp, the PR-7
double-``now`` resolution) next to clean twins; every rule must fire
on its ``_bad`` file and stay silent on its ``_ok`` twin.
"""
import copy
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import Config, load_config, run_paths, run_source
from repro.analysis.core import parse_toml, scan_suppressions

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "fixtures" / "servelint"


def fixture_config() -> Config:
    """Repo config, with the corpus un-excluded, the fixture engine
    marked hot for SL002, and its spec path configured for SL006."""
    data = copy.deepcopy(load_config(str(ROOT / "servelint.toml")).data)
    data["exclude"] = []
    data["SL002"]["hot_functions"] = ["*::Engine._decode_once"]
    data["SL006"]["verify_functions"] = ["*::Engine._decode_spec"]
    data["SL007"]["modules"] = ["*sl007_*.py"]
    return Config(data=data, root=str(ROOT))


def run_fixture(name: str):
    cfg = fixture_config()
    return run_paths([str(FIXTURES / name)], config=cfg).findings


# ---------------------------------------------------------------------------
# fixture pairs — each rule proven live (true positive) and quiet
# (true negative)


PAIRS = [
    ("SL001", "sl001_mixed_clock_bad.py", "sl001_mixed_clock_ok.py", 1),
    ("SL001", "sl001_double_now_bad.py", "sl001_double_now_ok.py", 1),
    ("SL002", "sl002_host_sync_bad.py", "sl002_host_sync_ok.py", 3),
    ("SL003", "sl003_retrace_bad.py", "sl003_retrace_ok.py", 2),
    ("SL004", "sl004_donation_bad.py", "sl004_donation_ok.py", 1),
    ("SL005", "sl005_cardinality_bad.py", "sl005_cardinality_ok.py", 2),
    ("SL006", "sl006_spec_verify_bad.py", "sl006_spec_verify_ok.py", 3),
    ("SL007", "sl007_fault_path_bad.py", "sl007_fault_path_ok.py", 3),
]


@pytest.mark.parametrize("rule,bad,ok,n_bad", PAIRS,
                         ids=[p[1][:-3] for p in PAIRS])
def test_fixture_pair(rule, bad, ok, n_bad):
    bad_findings = run_fixture(bad)
    assert len(bad_findings) == n_bad, [f.render() for f in bad_findings]
    assert all(f.rule == rule for f in bad_findings)
    ok_findings = run_fixture(ok)
    assert ok_findings == [], [f.render() for f in ok_findings]


def test_pr6_mixed_clock_bug_caught_at_the_stamp_line():
    """The PR-6 bug verbatim: `record_latency(..., time.perf_counter(),
    ...)` inside a resolved-`now` step()."""
    (f,) = run_fixture("sl001_mixed_clock_bad.py")
    src = (FIXTURES / "sl001_mixed_clock_bad.py").read_text().splitlines()
    assert "time.perf_counter()" in src[f.line - 1]
    assert "record_latency" in src[f.line - 1]
    assert "takes simulated time" in f.message


def test_pr7_double_now_bug_caught_at_the_late_resolution():
    """The PR-7 bug verbatim: enqueue() consuming `now` on the fast and
    shed paths before the evict branch resolves it."""
    (f,) = run_fixture("sl001_double_now_bad.py")
    src = (FIXTURES / "sl001_double_now_bad.py").read_text().splitlines()
    assert src[f.line - 1].strip() == \
        "now = time.perf_counter() if now is None else now"
    assert "already used" in f.message


def test_sl002_catches_each_sync_kind():
    kinds = {f.message.split(" in hot-path")[0]
             for f in run_fixture("sl002_host_sync_bad.py")}
    assert kinds == {"`numpy.asarray`", "`.item()`",
                     "`int(flag)` on a device value"}


def test_sl003_catches_missing_donation_and_static_loop_var():
    msgs = [f.message for f in run_fixture("sl003_retrace_bad.py")]
    assert any("without donate_argnums" in m for m in msgs)
    assert any("static position 3" in m for m in msgs)


def test_sl004_names_the_donated_path():
    (f,) = run_fixture("sl004_donation_bad.py")
    assert "`self.cache` read after being donated" in f.message


def test_sl005_catches_uid_label_and_shape_fork():
    msgs = [f.message for f in run_fixture("sl005_cardinality_bad.py")]
    assert any("unbounded cardinality" in m for m in msgs)
    assert any("plain label here but composite" in m for m in msgs)


def test_sl007_names_each_swallowing_form():
    kinds = {f.message.split(" swallows")[0]
             for f in run_fixture("sl007_fault_path_bad.py")}
    assert kinds == {"bare `except:`", "`except Exception`",
                     "`except BaseException`"}


def test_sl007_silent_outside_configured_modules():
    """The rule is scoped: the same swallowing handler in an
    unconfigured file is not the serve plane's business."""
    src = (FIXTURES / "sl007_fault_path_bad.py").read_text()
    cfg = fixture_config()
    assert run_source("elsewhere/util.py", src, config=cfg) == []


# ---------------------------------------------------------------------------
# suppressions


CLOCKY = """\
import time

def tick(now=None):
    now = time.perf_counter() if now is None else now
    t = time.perf_counter(){directive}
    return now, t
"""


def test_unsuppressed_finding_fires():
    findings = run_source("x.py", CLOCKY.format(directive=""))
    assert [f.rule for f in findings] == ["SL001"]


def test_same_line_suppression_with_reason_is_honored():
    src = CLOCKY.format(
        directive="  # servelint: disable=SL001 -- real wall interval")
    assert run_source("x.py", src) == []


def test_standalone_directive_suppresses_next_line():
    src = CLOCKY.format(directive="").replace(
        "    t = time.perf_counter()",
        "    # servelint: disable=SL001 -- real wall interval\n"
        "    t = time.perf_counter()")
    assert run_source("x.py", src) == []


def test_disable_all_suppresses_any_rule():
    src = CLOCKY.format(directive="  # servelint: disable=all -- fixture")
    assert run_source("x.py", src) == []


def test_wrong_rule_id_does_not_suppress():
    src = CLOCKY.format(directive="  # servelint: disable=SL002 -- nope")
    assert [f.rule for f in run_source("x.py", src)] == ["SL001"]


def test_suppression_without_reason_is_itself_a_finding():
    src = CLOCKY.format(directive="  # servelint: disable=SL001")
    rules = sorted(f.rule for f in run_source("x.py", src))
    assert rules == ["SL000"]     # finding suppressed, hygiene violation kept


def test_scan_suppressions_parses_rules_and_reason():
    (s,) = scan_suppressions(
        "x = 1  # servelint: disable=SL001,SL004 -- measured interval\n")
    assert s.rules == frozenset({"SL001", "SL004"})
    assert s.reason == "measured interval"
    assert s.applies_to == 1


# ---------------------------------------------------------------------------
# config loading


def test_parse_toml_subset():
    data = parse_toml("""
# comment
[servelint]
exclude = ["a/*", "b/*"]   # trailing comment
[servelint.SL001]
clock_params = [
  "now",
  "clock",
]
threshold = 3
ratio = 0.5
flag = true
""")
    sl = data["servelint"]
    assert sl["exclude"] == ["a/*", "b/*"]
    assert sl["SL001"]["clock_params"] == ["now", "clock"]
    assert sl["SL001"]["threshold"] == 3
    assert sl["SL001"]["ratio"] == 0.5
    assert sl["SL001"]["flag"] is True


def test_load_config_merges_over_defaults(tmp_path):
    p = tmp_path / "servelint.toml"
    p.write_text("[servelint.SL001]\nclock_params = [\"tick\"]\n")
    cfg = load_config(str(p))
    assert cfg.rule("SL001")["clock_params"] == ["tick"]
    # untouched keys keep their defaults
    assert "time.perf_counter" in cfg.rule("SL001")["wall_calls"]
    assert cfg.rule("SL005")["uid_label_names"]


def test_exclude_globs(tmp_path):
    (tmp_path / "skip").mkdir()
    (tmp_path / "skip" / "bad.py").write_text(CLOCKY.format(directive=""))
    cfg = Config(data={**Config().data, "exclude": ["skip/*"]},
                 root=str(tmp_path))
    assert run_paths(["skip"], config=cfg).findings == []


def test_repo_config_parses_and_excludes_corpus():
    cfg = load_config(str(ROOT / "servelint.toml"), root=str(ROOT))
    assert cfg.excluded("tests/fixtures/servelint/sl001_mixed_clock_bad.py")
    assert not cfg.excluded("src/repro/serving/engine.py")


# ---------------------------------------------------------------------------
# the gate itself


def test_repo_src_is_clean():
    """Zero unsuppressed findings on the repo's own src/ — the CI gate's
    core promise — and every suppression carries a reason."""
    cfg = load_config(str(ROOT / "servelint.toml"), root=str(ROOT))
    report = run_paths(["src"], config=cfg)
    assert report.findings == [], [f.render() for f in report.findings]
    assert all(s.reason for _, s in report.suppressed)


def test_cli_exits_zero_on_repo_and_writes_report(tmp_path):
    out = tmp_path / "servelint.json"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "servelint.py"),
         "--root", str(ROOT), "--report", str(out),
         "src", "tests", "benchmarks", "examples", "scripts"],
        cwd=str(ROOT), capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(out.read_text())
    assert data["findings"] == []
    assert all(s["reason"] for s in data["suppressed"])


def test_cli_exits_one_on_findings(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(CLOCKY.format(directive=""))
    proc = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "servelint.py"),
         "--root", str(tmp_path), str(bad)],
        capture_output=True, text=True, timeout=60)
    assert proc.returncode == 1
    assert "SL001" in proc.stdout
