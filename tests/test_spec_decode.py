"""Speculative decoding on the fused hot path.

The acceptance bar: with ANY draft — identical, adversarial, or absent —
the served token streams are byte-for-byte what plain fused stepwise
decode emits (greedy AND seeded stochastic), because each emitted token
is the target's own seeded sample at its fed position. On top of that
exactness floor: device-side retirement matches the host-visible
semantics (EOS / max_new / deadline), the draft's KV pool leases and
frees with its slots, the transfer guard holds with spec on (only the
``(max_batch, K+1)`` int32 id matrix + reason bits cross per verify),
and every viability gate degrades to plain decode instead of failing.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import init_model
from repro.obs import Observability
from repro.serving import (InferenceEngine, PagedInferenceEngine, Request,
                           SamplingParams, SpecDraft, get_backend)

SMOL = "smollm-360m"
LENGTHS = [5, 8, 16, 32, 7]


@pytest.fixture(scope="module")
def stack():
    cfg = reduced_f32(SMOL)
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, get_backend("trt")


@pytest.fixture(scope="module")
def drafts(stack):
    """identity: the target's own weights (agrees everywhere, ~every
    draft accepted); adversarial: same arch, different init (agrees
    ~never — every verify pays K+1 positions for 1 token)."""
    cfg, params, _ = stack
    return {"identity": SpecDraft(cfg=cfg, params=params, k=4),
            "adversarial": SpecDraft(
                cfg=cfg, params=init_model(cfg, jax.random.PRNGKey(9)), k=4)}


def _reqs(cfg, lengths, max_new=6, seed=3, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i, tokens=list(rng.randint(0, cfg.vocab_size, L)),
                    sampling=SamplingParams(max_new_tokens=max_new, **kw))
            for i, L in enumerate(lengths)]


def _run(cls, stack, reqs, spec=None, **kw):
    cfg, params, bk = stack
    eng = cls(cfg, params, bk, max_seq=96, chunk_tokens=8, spec=spec, **kw)
    out = []
    for r in reqs:
        eng.submit(r)
    while eng.has_work():
        out.extend(eng.step())
    return eng, {r.uid: r for r in out}


def _assert_streams_equal(plain, spec):
    assert set(plain) == set(spec)
    for uid in plain:
        assert plain[uid].new_tokens == spec[uid].new_tokens, uid
        assert plain[uid].completed == spec[uid].completed, uid


# ---------------------------------------------------------------------------
# exactness: spec == plain for any draft, greedy and stochastic


@pytest.mark.parametrize("draft", ["identity", "adversarial"])
@pytest.mark.parametrize("sampling", ["greedy", "stochastic"],
                         ids=["greedy", "stochastic"])
def test_paged_spec_matches_plain(stack, drafts, draft, sampling):
    cfg, _, _ = stack
    kw = {} if sampling == "greedy" else {"temperature": 1.0, "top_k": 8}
    _, plain = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS, **kw))
    eng, spec = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS, **kw),
                     spec=drafts[draft])
    assert eng.spec is not None
    assert eng._spec_drafted > 0
    _assert_streams_equal(plain, spec)


@pytest.mark.parametrize("draft", ["identity", "adversarial"])
@pytest.mark.parametrize("sampling", ["greedy", "stochastic"],
                         ids=["greedy", "stochastic"])
def test_dense_spec_matches_plain(stack, drafts, draft, sampling):
    cfg, _, _ = stack
    kw = {} if sampling == "greedy" else {"temperature": 1.0, "top_k": 8}
    _, plain = _run(InferenceEngine, stack, _reqs(cfg, LENGTHS[:3], **kw))
    eng, spec = _run(InferenceEngine, stack, _reqs(cfg, LENGTHS[:3], **kw),
                     spec=drafts[draft])
    assert eng.spec is not None
    assert eng._spec_drafted > 0
    _assert_streams_equal(plain, spec)


def test_acceptance_counters_reflect_draft_quality(stack, drafts):
    # identity draft: near-total acceptance (only the max_new tail of
    # each request truncates a window); adversarial: near-zero. Both
    # report per-request drafted/accepted usage on the result.
    cfg, _, _ = stack
    eng_id, res_id = _run(PagedInferenceEngine, stack,
                          _reqs(cfg, LENGTHS, max_new=16),
                          spec=drafts["identity"])
    eng_ad, _ = _run(PagedInferenceEngine, stack,
                     _reqs(cfg, LENGTHS, max_new=16),
                     spec=drafts["adversarial"])
    id_rate = eng_id._spec_accepted / eng_id._spec_drafted
    ad_rate = eng_ad._spec_accepted / eng_ad._spec_drafted
    assert id_rate > 0.5
    assert ad_rate < 0.2
    assert id_rate > ad_rate
    for r in res_id.values():
        assert r.drafted_tokens > 0
        assert 0 <= r.accepted_tokens <= r.drafted_tokens
    assert sum(r.drafted_tokens for r in res_id.values()) == \
        eng_id._spec_drafted
    assert sum(r.accepted_tokens for r in res_id.values()) == \
        eng_id._spec_accepted


def test_spec_composes_with_prefix_cache(stack, drafts):
    # a repeat prompt admits through the radix cache (target-side skip)
    # while the draft prefills the whole prompt itself — streams match
    cfg, _, _ = stack
    reqs = _reqs(cfg, [40], max_new=6)
    repeat = [Request(uid=100 + r.uid, tokens=list(r.tokens),
                      sampling=r.sampling) for r in reqs]
    _, plain = _run(PagedInferenceEngine, stack,
                    _reqs(cfg, [40], max_new=6))
    eng, _ = _run(PagedInferenceEngine, stack, reqs,
                  spec=drafts["identity"])
    for r in repeat:
        eng.submit(r)
    out = {}
    while eng.has_work():
        out.update({r.uid: r for r in eng.step()})
    assert out[100].cached_tokens > 0
    assert out[100].new_tokens == plain[0].new_tokens


# ---------------------------------------------------------------------------
# device-side retirement == host-visible semantics


def test_spec_eos_truncates_exactly_like_plain(stack, drafts):
    # the without-eos stream is the ground truth; with eos_id set, both
    # plain and spec engines must cut at the FIRST occurrence, inclusive,
    # and report completed (FINISH_EOS) — the device saw it mid-window
    cfg, _, _ = stack
    _, free = _run(PagedInferenceEngine, stack,
                   _reqs(cfg, LENGTHS, max_new=24))
    # pick an eos id the unconstrained run actually emits mid-stream, so
    # the truncation branch is guaranteed to exercise
    eos = next(t for r in free.values() for t in r.new_tokens[1:-1])
    _, plain = _run(PagedInferenceEngine, stack,
                    _reqs(cfg, LENGTHS, max_new=24, eos_id=eos))
    eng, spec = _run(PagedInferenceEngine, stack,
                     _reqs(cfg, LENGTHS, max_new=24, eos_id=eos),
                     spec=drafts["identity"])
    _assert_streams_equal(plain, spec)
    truncated = 0
    for uid, r in spec.items():
        toks = free[uid].new_tokens
        if eos in toks:
            cut = toks.index(eos) + 1
            assert r.new_tokens == toks[:cut]
            assert r.completed
            truncated += 1
        else:
            assert r.new_tokens == toks
    assert truncated > 0, "no stream hit eos — test lost its teeth"


def test_spec_max_new_retires_at_the_exact_length(stack, drafts):
    cfg, _, _ = stack
    for spec in (None, drafts["adversarial"]):
        _, res = _run(PagedInferenceEngine, stack,
                      _reqs(cfg, LENGTHS, max_new=11), spec=spec)
        for r in res.values():
            assert len(r.new_tokens) == 11
            assert r.completed and not r.timed_out


@pytest.mark.parametrize("draft", [None, "identity"],
                         ids=["plain", "spec"])
def test_deadline_expiry_mid_decode_times_out(stack, drafts, draft):
    # the one retirement the device cannot see: the wall clock. Age the
    # request's deadline once it is actively decoding — the next
    # _consume_reason must retire it timed_out, not completed
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                               spec=drafts[draft] if draft else None)
    (req,) = _reqs(cfg, [16], max_new=64)
    eng.submit(req)
    while not eng._finished and not any(
            not s.done and not s.prefilling and s.res.new_tokens
            for s in eng._slots):
        eng.step()
    req.deadline_s = 1e-9                 # already expired, mid-stream
    out = []
    while eng.has_work():
        out.extend(eng.step())
    (r,) = out
    assert r.timed_out and not r.completed
    assert 0 < len(r.new_tokens) < 64


# ---------------------------------------------------------------------------
# KV accounting with two resident caches


def test_draft_pool_leases_and_frees_with_slots(stack, drafts):
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                               spec=drafts["identity"])
    for r in _reqs(cfg, LENGTHS, max_new=8):
        eng.submit(r)
    leased = 0
    while eng.has_work():
        eng.step()
        leased = max(leased, eng.spec_blocks - eng.spec_pool.num_free)
    assert leased > 0, "draft pool never leased a block"
    # reap returns every draft block; the draft pool has no radix cache,
    # so unlike the target pool nothing stays behind as reusable prefix
    assert eng.spec_pool.num_free == eng.spec_blocks
    assert eng.pool.num_free + len(eng.prefix) == eng.num_blocks


def test_resident_bytes_counts_the_draft(stack, drafts):
    from repro.obs.cost import param_bytes
    cfg, params, bk = stack
    plain = PagedInferenceEngine(cfg, params, bk, max_seq=96)
    spec = PagedInferenceEngine(cfg, params, bk, max_seq=96,
                                spec=drafts["identity"])
    assert spec._spec_bytes > 0
    assert spec.resident_bytes() == (plain.resident_bytes()
                                     + param_bytes(cfg) + spec._spec_bytes)


# ---------------------------------------------------------------------------
# graceful degradation: every gate falls back to plain decode


def test_vocab_mismatch_draft_is_refused(stack, drafts):
    # acceptance compares token ids — a different vocab can't draft
    cfg, params, _ = stack
    dcfg = dataclasses.replace(cfg, vocab_size=cfg.vocab_size // 2)
    bad = SpecDraft(cfg=dcfg, params=params, k=4)
    _, plain = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS))
    eng, res = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS),
                    spec=bad)
    assert eng.spec is None
    _assert_streams_equal(plain, res)


def test_draft_pool_too_small_for_one_sequence_is_refused(stack, drafts):
    cfg, params, _ = stack
    tiny = dataclasses.replace(drafts["identity"], num_blocks=2)
    eng, res = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS),
                    spec=tiny)
    _, plain = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS))
    assert eng.spec is None
    _assert_streams_equal(plain, res)


def test_draft_cache_heavier_than_target_is_refused(stack, drafts):
    # KV-pressure gate: a draft whose cache outweighs the target's own
    # would starve the model it is meant to help
    cfg, params, bk = stack
    heavy = dataclasses.replace(drafts["identity"],
                                num_blocks=8 * bk.max_batch * (96 // 16))
    eng, res = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS),
                    spec=heavy)
    _, plain = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS))
    assert eng.spec is None
    _assert_streams_equal(plain, res)


def test_partial_draft_residency_falls_back_per_batch(stack, drafts):
    # a draft pool with room for ONE sequence: only the first admitted
    # request gets draft residency, so batches containing the others run
    # plain stepwise (spec needs EVERY active row leased) — and the
    # streams still match plain exactly
    cfg, params, _ = stack
    scarce = dataclasses.replace(drafts["identity"], num_blocks=96 // 16)
    eng, res = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS),
                    spec=scarce)
    _, plain = _run(PagedInferenceEngine, stack, _reqs(cfg, LENGTHS))
    assert eng.spec is not None           # viable — just under-provisioned
    _assert_streams_equal(plain, res)


# ---------------------------------------------------------------------------
# transfer guard with spec enabled: only int32 ids cross per verify


def test_spec_verify_moves_only_token_ids(stack, drafts, monkeypatch):
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                               spec=drafts["identity"])
    for r in _reqs(cfg, [16, 8, 5], max_new=48):
        eng.submit(r)
    while any(s.prefilling for s in eng._slots) or eng._queue:
        eng.step()                       # admission + prefill off-guard
    active = [i for i, s in enumerate(eng._slots) if not s.done]
    assert active and eng._spec_ready(active)

    pulled = []
    real_get = jax.device_get

    def spy_get(x):
        jax.tree_util.tree_map(lambda a: pulled.append(a), x)
        return real_get(x)

    monkeypatch.setattr(jax, "device_get", spy_get)
    with jax.transfer_guard_device_to_host("disallow"):
        with jax.transfer_guard_host_to_device("disallow"):
            for _ in range(3):
                eng.step()
    monkeypatch.undo()
    assert eng._spec_drafted > 0, "guarded steps never took the spec path"
    assert pulled, "spec steps pulled nothing?"
    k = eng.spec.k
    for arr in pulled:
        assert np.asarray(arr).dtype == np.int32
        # the widest designed pull: the (max_batch, K+1) id matrix
        assert np.asarray(arr).size <= eng.max_batch * (k + 1)
    eng.run([])


def test_spec_metrics_observed(stack, drafts):
    cfg, params, bk = stack
    bundle = Observability()
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                               spec=drafts["identity"],
                               obs=bundle.engine_obs(SMOL, "trt"))
    eng.run(_reqs(cfg, LENGTHS, max_new=8))
    hist = bundle.registry.histogram("spec_accept_len", SMOL,
                                     bounds=(0.0, 1.0, 2.0, 4.0, 8.0, 16.0))
    assert hist.count > 0
    rate = bundle.registry.gauge("spec_accept_rate", SMOL).value
    assert 0.0 <= rate <= 1.0
    assert rate == eng._spec_accepted / eng._spec_drafted


# ---------------------------------------------------------------------------
# serve-plane threading: --spec-draft reaches the engines + the response


def test_gateway_threads_spec_draft_to_engines():
    # ONE target model so routing is deterministic; the draft arch is
    # resolved from the registry by the pool (it need not be served)
    from repro.core.gateway import Gateway
    gw = Gateway({"phi3-medium-14b": reduced_f32("phi3-medium-14b")},
                 max_seq=96, spec_draft="smollm-360m", spec_k=4)
    r = gw.handle("sum the list", max_new_tokens=8)
    assert r.completed
    assert r.usage.drafted_tokens > 0
    assert 0 <= r.usage.accepted_tokens <= r.usage.drafted_tokens
    for _, eng in gw.frontend.pool.engines():
        assert eng.spec is not None and eng.spec.k == 4


def test_pool_never_drafts_a_model_with_itself():
    from repro.core.gateway import Gateway
    gw = Gateway({"smollm-360m": reduced_f32("smollm-360m")},
                 max_seq=96, spec_draft="smollm-360m")
    r = gw.handle("sum the list", max_new_tokens=4)
    assert r.completed
    assert r.usage.drafted_tokens == 0
    for _, eng in gw.frontend.pool.engines():
        assert eng.spec is None


# ---------------------------------------------------------------------------
# mid-prefill prefix re-match (the chunk-boundary extension)


def test_staggered_twin_adopts_blocks_mid_prefill(stack):
    # the head start means the twin's ADMISSION lookup sees only the
    # blocks the first prompt had registered by then; everything beyond
    # must be adopted by the chunk-boundary re-lookup while the twin is
    # itself mid-prefill — without it, cached_tokens stays at the
    # admission-time match
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=8,
                               chunk_tokens=8)
    rng = np.random.RandomState(29)
    prompt = list(rng.randint(0, cfg.vocab_size, 64))
    sp = SamplingParams(max_new_tokens=4)
    first = Request(uid=1, tokens=list(prompt), sampling=sp)
    eng.submit(first)
    for _ in range(2):                    # head start: ~2 chunks land
        eng.step()
    twin = Request(uid=2, tokens=list(prompt), sampling=sp)
    admission_match = eng.prefix_peek(twin)
    assert admission_match < len(prompt) - 1   # the twin starts behind
    eng.submit(twin)
    res = {r.uid: r for r in eng.run([])}
    assert res[2].cached_tokens > admission_match
    assert res[1].new_tokens == res[2].new_tokens
