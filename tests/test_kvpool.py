"""Paged KV-cache plane: block pool, radix prefix cache, paged engine.

The acceptance bar: a paged engine is token-for-token equivalent to the
dense engine under greedy sampling, prefix hits genuinely skip prefill,
and the serve plane's cache-aware policies act on pool state.
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.core.gateway import ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.scoring import PROFILES
from repro.models import init_model
from repro.serving import (BlockPool, InferenceEngine, PagedInferenceEngine,
                           PoolExhausted, RadixPrefixCache, Request,
                           SamplingParams, get_backend)
from repro.serving.kvquant import dequantize, quantize

SMOL = "smollm-360m"
KEY = (SMOL, "trt")


# ---------------------------------------------------------------------------
# allocator


def test_block_pool_alloc_free_refcount():
    pool = BlockPool(num_blocks=4, block_size=16)
    a, b = pool.alloc(), pool.alloc()
    assert pool.num_free == 2 and pool.refcount(a) == 1
    pool.incref(a)                      # shared lease
    assert not pool.decref(a)           # still referenced
    assert pool.decref(a)               # now free
    assert pool.num_free == 3
    c, d, e = pool.alloc(), pool.alloc(), pool.alloc()
    assert pool.num_free == 0
    with pytest.raises(PoolExhausted):
        pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc_many(1)
    assert len({b, c, d, e}) == 4       # live blocks never double-handed


def test_radix_match_insert_evict():
    pool = BlockPool(num_blocks=8, block_size=4)
    cache = RadixPrefixCache(pool)
    seq = list(range(12))               # 3 full blocks
    blocks = pool.alloc_many(3)
    assert cache.insert(seq, blocks) == 3
    # cache holds one ref on top of ours
    assert all(pool.refcount(b) == 2 for b in blocks)

    got, n = cache.match(seq + [99])    # longer lookup still matches 3
    assert got == blocks and n == 12
    assert all(pool.refcount(b) == 3 for b in blocks)
    for b in got:
        pool.decref(b)

    got, n = cache.match([0, 1, 2, 3, 7, 7, 7, 7])   # diverges after blk 0
    assert got == blocks[:1] and n == 4
    pool.decref(got[0])
    assert cache.peek(seq) == 12

    # release our allocation refs -> blocks are cache-only and evictable
    for b in blocks:
        pool.decref(b)
    assert cache.evictable_blocks() == 3
    assert cache.evict(2) == 2          # LRU leaves cascade up
    assert cache.peek(seq) == 4         # only the root block remains
    assert pool.num_free == 7


def test_radix_live_lease_blocks_eviction():
    pool = BlockPool(num_blocks=4, block_size=4)
    cache = RadixPrefixCache(pool)
    blocks = pool.alloc_many(2)
    cache.insert(list(range(8)), blocks)
    pool.decref(blocks[0])              # blk0 cache-only, blk1 still leased
    assert cache.evictable_blocks() == 0     # leaf pinned -> parent pinned
    assert cache.evict(2) == 0
    pool.decref(blocks[1])
    assert cache.evictable_blocks() == 2
    assert cache.evict(5) == 2 and pool.num_free == 4


def test_kvquant_round_trip_absmax():
    rng = np.random.RandomState(0)
    x = np.asarray(rng.randn(4, 6, 2, 32) * 3.0, np.float32)
    q, s = quantize(x)
    assert q.dtype == np.int8 and s.shape == x.shape[:-1] + (1,)
    back = np.asarray(dequantize(q, s, dtype=np.float32))
    # absmax int8: error bounded by half a quantization step per entry
    step = np.asarray(s)
    assert np.all(np.abs(back - x) <= step * 0.51 + 1e-7)
    # exact at the extremes: each row's absmax element maps to +-127
    flat_err = np.abs(np.asarray(q)).max(axis=-1)
    assert np.all(flat_err == 127)


def test_kvquant_round_trip_property():
    hypothesis = pytest.importorskip(
        "hypothesis", reason="optional dep: property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(vals=st.lists(st.floats(-1e4, 1e4, allow_nan=False,
                                   allow_infinity=False, width=32),
                         min_size=1, max_size=64))
    @settings(max_examples=100, deadline=None)
    def check(vals):
        x = np.asarray(vals, np.float32)[None, :]
        q, s = quantize(x)
        back = np.asarray(dequantize(q, s, dtype=np.float32))
        assert np.all(np.abs(back - x) <= np.asarray(s) * 0.51 + 1e-6)

    check()


# ---------------------------------------------------------------------------
# paged engine vs dense engine


@pytest.fixture(scope="module")
def engines():
    cfg = reduced_f32(SMOL)
    params = init_model(cfg, jax.random.PRNGKey(0))
    bk = get_backend("trt")
    dense = InferenceEngine(cfg, params, bk, max_seq=96)
    paged = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=16)
    return cfg, params, dense, paged


def _mixed_reqs(cfg, lengths, max_new=6, seed=3):
    # power-of-2-safe lengths: the dense engine's floor-pow2 bucketing
    # does not truncate them, so both engines see identical prompts
    rng = np.random.RandomState(seed)
    return [Request(uid=i, tokens=list(rng.randint(0, cfg.vocab_size, L)),
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i, L in enumerate(lengths)]


def test_paged_matches_dense_greedy(engines):
    cfg, _, dense, paged = engines
    lengths = [5, 8, 16, 32, 64, 7, 16]
    rd = {r.uid: r for r in dense.run(_mixed_reqs(cfg, lengths))}
    rp = {r.uid: r for r in paged.run(_mixed_reqs(cfg, lengths))}
    assert rd.keys() == rp.keys()
    for u in rd:
        assert rd[u].new_tokens == rp[u].new_tokens
        assert rp[u].completed
    # every request's blocks were freed on reap
    assert paged.pool.num_free + len(paged.prefix) == paged.num_blocks


def test_paged_matches_dense_greedy_int8(engines):
    cfg, _, _, _ = engines
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_model(cfg8, jax.random.PRNGKey(0))
    bk = get_backend("trt")
    dense = InferenceEngine(cfg8, params, bk, max_seq=96)
    paged = PagedInferenceEngine(cfg8, params, bk, max_seq=96)
    lengths = [8, 16, 32]
    rd = {r.uid: r.new_tokens for r in dense.run(_mixed_reqs(cfg8, lengths))}
    rp = {r.uid: r.new_tokens for r in paged.run(_mixed_reqs(cfg8, lengths))}
    assert rd == rp


def test_prefix_hit_skips_prefill_and_keeps_tokens(engines):
    cfg, _, _, paged = engines
    rng = np.random.RandomState(11)
    prompt = list(rng.randint(0, cfg.vocab_size, 40))
    sp = SamplingParams(max_new_tokens=4)
    r1 = paged.run([Request(uid=900, tokens=prompt, sampling=sp)])[0]
    h0, p0 = paged.hit_tokens, paged.prompt_tokens
    r2 = paged.run([Request(uid=901, tokens=prompt, sampling=sp)])[0]
    # the repeat reused every full block of the prompt (2 x 16 of 40)
    assert paged.hit_tokens - h0 == 32
    assert paged.prefix_hit_rate() > 0
    assert r1.new_tokens == r2.new_tokens       # reuse changes nothing


def test_copy_on_write_on_fully_cached_prompt(engines):
    # a prompt that is a block-UNALIGNED prefix of a cached sequence must
    # recompute its last token: the shared block is COW'd, the cached
    # sequence keeps its data, and greedy output still matches dense
    cfg, params, dense, paged = engines
    rng = np.random.RandomState(13)
    base = list(rng.randint(0, cfg.vocab_size, 40))
    sp = SamplingParams(max_new_tokens=4)
    paged.run([Request(uid=910, tokens=base, sampling=sp)])
    sub = base[:16]                     # plen 16: keep=15 inside block 0
    rp = paged.run([Request(uid=911, tokens=sub, sampling=sp)])[0]
    rd = dense.run([Request(uid=911, tokens=sub, sampling=sp)])[0]
    assert rp.new_tokens == rd.new_tokens
    # and the longer cached prefix is still intact for future hits
    assert paged.prefix.peek(base) >= 32


def test_admission_gated_on_free_blocks(engines):
    # a pool far smaller than slots x max_seq still serves everything:
    # admission waits for blocks, blocks are freed on reap
    cfg, params, _, _ = engines
    eng = PagedInferenceEngine(cfg, params, get_backend("trt"), max_seq=96,
                               block_size=16, num_blocks=12)
    res = eng.run(_mixed_reqs(cfg, [16, 32, 16, 8, 32, 16, 8, 16], seed=5))
    assert len(res) == 8 and all(r.completed for r in res)
    assert eng.pool.num_free + len(eng.prefix) == eng.num_blocks


def test_dense_free_slots_clamped_at_zero(engines):
    # regression: queue deeper than free slots made free_slots() negative
    cfg, _, dense, paged = engines
    for eng in (dense, paged):
        for r in _mixed_reqs(cfg, [8] * (eng.max_batch + 3), seed=7):
            eng.submit(r)
        assert eng.free_slots() == 0
        eng.run([])                                  # drain
        assert eng.free_slots() == eng.max_batch


def test_paged_free_slots_counts_blocks(engines):
    cfg, params, _, _ = engines
    eng = PagedInferenceEngine(cfg, params, get_backend("trt"), max_seq=96,
                               block_size=16, num_blocks=6, prefix_cache=False)
    # 6 blocks = one full sequence: capacity is 1 admission despite 4 slots
    assert eng.free_slots() == 1
    leases = eng.pool.alloc_many(3)
    assert eng.free_slots() == 0
    for b in leases:
        eng.pool.decref(b)


# ---------------------------------------------------------------------------
# cache-aware serve plane


@pytest.fixture(scope="module")
def agw():
    spin = SpinConfig(window_s=20.0, cooldown_s=0.0, idle_tau_s=0.5,
                      tick_s=3600.0, max_replicas=2,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    # paged=True: force paged engines on the trt column so the
    # cache-aware serve-plane policies are exercised end to end
    return ServeFrontend({SMOL: reduced_f32(SMOL)},
                         profile=PROFILES["balanced"], max_seq=96, spin=spin,
                         paged=True)


def test_pool_spins_paged_engines_and_reports_gauges(agw):
    h = agw.submit("sum the numbers please", max_new_tokens=4)
    agw.serve_all()
    assert h.response.completed
    eng = agw.pool.replicas(*KEY)[0]
    assert eng.paged
    stats = agw.pool.kv_stats(SMOL)
    assert stats and 0.0 <= stats["kv_pressure"] <= 1.0
    # scheduler pushed the gauges into the telemetry Spin ticks on
    assert agw.telemetry.gauge(SMOL, "kv_pressure") == stats["kv_pressure"]
    assert agw.telemetry.gauge(SMOL, "kv_hit_rate") >= 0.0


def test_scheduler_dispatches_best_prefix_first(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 1)
    eng = agw.pool.replicas(*KEY)[0]
    cfg = agw.models[SMOL]
    rng = np.random.RandomState(21)
    warm = list(rng.randint(0, cfg.vocab_size, 48))
    sp = SamplingParams(max_new_tokens=2)
    eng.run([Request(uid=800, tokens=warm, sampling=sp)])   # seed the radix

    # occupy all but one slot so exactly one dispatch can happen
    blockers = [Request(uid=801 + i,
                        tokens=list(rng.randint(0, cfg.vocab_size, 8)),
                        sampling=SamplingParams(max_new_tokens=16))
                for i in range(eng.max_batch - 1)]
    for b in blockers:
        eng.submit(b)
    eng.step()
    assert eng.free_slots() == 1

    cold = Request(uid=880, tokens=list(rng.randint(0, cfg.vocab_size, 48)),
                   sampling=SamplingParams(max_new_tokens=16),
                   arrival_t=time.perf_counter())
    hot = Request(uid=881, tokens=warm + [1, 2, 3],
                  sampling=SamplingParams(max_new_tokens=16),
                  arrival_t=time.perf_counter())
    q = agw.scheduler._queues[KEY]
    q.extend([cold, hot])               # FIFO order favors the cold one
    agw.registry.entry(*KEY).queued += 2
    agw.scheduler.dispatch(time.perf_counter())
    # the prefix hit jumped the FIFO: it went to the engine, cold stayed
    assert [r.uid for r in eng._queue] == [881]
    assert [r.uid for r in q] == [880]
    eng.step()
    assert 881 in {s.req.uid for s in eng._slots if not s.done}
    q.clear()
    agw.registry.entry(*KEY).queued = 0
    agw.serve_all()                     # drain the blockers + hot request


def test_block_watermark_sheds_early(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 1)
    eng = agw.pool.replicas(*KEY)[0]
    eng.prefix.clear()
    hold = eng.pool.alloc_many(eng.pool.num_free)   # starve the pool
    try:
        assert agw.pool.kv_free_frac(*KEY) < agw.scheduler.cfg.block_watermark
        depth = agw.scheduler._depth_limit(*KEY)
        assert depth == max(1, agw.scheduler.cfg.max_queue_depth //
                            agw.scheduler.cfg.watermark_depth_div)
        shed0 = agw.scheduler.stats.shed_blocks
        handles = [agw.submit(f"add numbers {i}", max_new_tokens=2)
                   for i in range(depth + 6)]
        assert sum(h.shed for h in handles) >= 2    # early backpressure
        assert agw.scheduler.stats.shed_blocks > shed0
    finally:
        for b in hold:
            eng.pool.decref(b)
        agw.serve_all()


def test_orchestrator_scales_up_on_kv_pressure(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 1)
    now = time.perf_counter()
    agw.telemetry.record_request(SMOL, now)         # not idle
    agw.telemetry.record_gauge(SMOL, "kv_pressure", now, 0.99)
    decisions = agw.orch.tick(time.perf_counter())
    assert decisions.get(SMOL, 0) >= 2              # memory-bound scale-up
    agw.telemetry.record_gauge(SMOL, "kv_pressure", time.perf_counter(), 0.0)
    agw.settle(timeout_s=3.0)
