"""Fault-tolerant serve plane: seeded injection, health & quarantine,
deterministic retry-from-prefix, graceful drain.

The determinism tests are the tier-1 acceptance: with a seeded
``FaultPlan`` killing a replica mid-decode or mid-prefill, the recovered
completions must equal the fault-free run token-for-token — greedy AND
seeded stochastic, dense AND paged. The invariant this rests on: per-
request PRNG streams are keyed by uid x draw index (not batch or
replica), and a retried request chains its emitted tokens onto the
prompt while resuming its draw counter (``prefix_draws``)."""
import time

import jax
import pytest

from conftest import reduced_f32
from repro.core.gateway import ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.scoring import PROFILES
from repro.models import init_model
from repro.serving import (FaultPlan, FaultSpec, InferenceEngine,
                           InjectedFault, PagedInferenceEngine, Request,
                           SamplingParams, SchedulerConfig, compile_fns,
                           compile_paged_fns, get_backend)

SMOL = "smollm-360m"
KEY = (SMOL, "trt")
PROMPTS = ("the quick brown fox jumps over the lazy dog",
           "pack my box with five dozen liquor jugs")


def _fe(faults=None, paged=False, sched=None, **kw):
    spin = SpinConfig(window_s=20.0, cooldown_s=0.0, idle_tau_s=0.5,
                      tick_s=3600.0, max_replicas=3,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    return ServeFrontend({SMOL: reduced_f32(SMOL)},
                         profile=PROFILES["balanced"], max_seq=96,
                         spin=spin, faults=faults, paged=paged,
                         sched=sched, **kw)


def _submit_pair(fe, max_new=12):
    """One greedy + one seeded-stochastic request (fixed uids 0/1 on a
    fresh frontend, so per-request PRNG streams line up across runs)."""
    return [fe.submit(PROMPTS[0], max_new_tokens=max_new),
            fe.submit(PROMPTS[1], max_new_tokens=max_new,
                      sampling=SamplingParams(temperature=1.3, top_k=8,
                                              max_new_tokens=max_new))]


def _check_identical(base, out):
    for b, r in zip(base, out):
        assert r.completed
        assert r.new_tokens == b.new_tokens
        assert r.finish_reason == b.finish_reason
        assert r.usage.prompt_tokens == b.usage.prompt_tokens


# -- fault plan unit behavior ------------------------------------------------

def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultSpec("segfault")


def test_fault_plan_rate_streams_are_deterministic():
    plan = FaultPlan([FaultSpec("step_error", rate=0.3)], seed=11)

    def fires(incarnation):
        inj = plan.injector(SMOL, "trt", incarnation)
        return [inj.begin_step() for _ in range(40)]

    assert fires(0) == fires(0)           # same identity -> same schedule
    assert fires(0) != fires(1)           # incarnations draw independently
    assert any(k for k in fires(0))       # 40 steps at 30%: something fired


def test_fault_plan_targets_replica_and_step():
    plan = FaultPlan([FaultSpec("step_error", at_step=3, replica=0)])
    assert plan.injector(SMOL, "trt", 1) is None      # wrong incarnation
    inj = plan.injector(SMOL, "trt", 0)
    assert [inj.begin_step() for _ in range(4)] == \
        [[], [], ["step_error"], []]
    assert plan.fired == [(SMOL, "trt", 0, 3, "step_error")]


def test_spin_fail_consults_before_spin():
    plan = FaultPlan([FaultSpec("spin_fail", replica=0)])
    assert plan.spin_fails(SMOL, "trt", 0)
    assert not plan.spin_fails(SMOL, "trt", 1)        # substitute spins
    assert plan.fired[0][4] == "spin_fail"


# -- engine-level injection --------------------------------------------------

@pytest.fixture(scope="module")
def ep():
    cfg = reduced_f32(SMOL)
    return cfg, init_model(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dense_fns(ep):
    cfg, _ = ep
    return compile_fns(cfg, get_backend("trt"), 96)


@pytest.fixture(scope="module")
def paged_fns(ep):
    cfg, _ = ep
    return compile_paged_fns(cfg, get_backend("trt"), 96, 16)


def _req(cfg, uid=0, max_new=6, n=12):
    return Request(uid=uid, tokens=list(range(5, 5 + n)),
                   sampling=SamplingParams(max_new_tokens=max_new))


def test_injected_step_error_is_clean(ep, dense_fns):
    cfg, params = ep
    plan = FaultPlan([FaultSpec("step_error", at_step=2, count=1)])
    eng = InferenceEngine(cfg, params, get_backend("trt"), max_seq=96,
                          fns=dense_fns, fault=plan.injector(SMOL, "trt", 0))
    eng.submit(_req(cfg))
    eng.step()                                        # step 1: fine
    with pytest.raises(InjectedFault):
        eng.step()                                    # step 2: injected
    # clean crash: fired BEFORE device work, state intact, not poisoned
    assert not eng.poisoned
    res = []
    while eng.has_work():
        res.extend(eng.step())
    assert res and res[0].completed


def test_straggler_injects_wall_latency(ep, dense_fns):
    cfg, params = ep
    plan = FaultPlan([FaultSpec("straggler", at_step=2, delay_s=0.05)])
    eng = InferenceEngine(cfg, params, get_backend("trt"), max_seq=96,
                          fns=dense_fns, fault=plan.injector(SMOL, "trt", 0))
    eng.submit(_req(cfg))
    eng.step()
    t0 = time.perf_counter()
    eng.step()
    assert time.perf_counter() - t0 >= 0.05
    assert plan.fired[0][4] == "straggler"


def test_kv_alloc_fail_defers_admission(ep, paged_fns):
    cfg, params = ep
    plan = FaultPlan([FaultSpec("kv_alloc_fail", at_step=1, for_steps=2)])
    eng = PagedInferenceEngine(cfg, params, get_backend("trt"), max_seq=96,
                               block_size=16, fns=paged_fns,
                               fault=plan.injector(SMOL, "trt", 0))
    eng.submit(_req(cfg))
    eng.step()                                        # denied: stays queued
    assert eng._queued() == 1 and eng.pool.num_free == eng.pool.num_blocks
    eng.step()                                        # denied again
    assert eng._queued() == 1
    res = []
    while eng.has_work():                             # step 3+: admitted
        res.extend(eng.step())
    assert res[0].completed
    assert [f[4] for f in plan.fired] == ["kv_alloc_fail"] * 2


def test_poisoned_step_conserves_resources(ep, paged_fns):
    """Satellite: a mid-step exception (host/device possibly diverged)
    must not leak KV blocks, slots, or uid-index entries once the
    engine is evacuated."""
    cfg, params = ep
    eng = PagedInferenceEngine(cfg, params, get_backend("trt"), max_seq=96,
                               block_size=16, fns=paged_fns,
                               prefix_cache=False)
    free0, slots0 = eng.pool.num_free, eng.free_slots()
    for i in range(2):
        eng.submit(_req(cfg, uid=i))

    def boom(active):
        raise RuntimeError("mid-step poison")

    eng._decode_once = boom
    with pytest.raises(RuntimeError):
        eng.step()
    assert eng.poisoned                               # latch for containment
    evac = eng.evacuate()
    assert len(evac) == 2
    assert eng.pool.num_free == free0                 # KV blocks conserved
    assert eng.free_slots() == slots0                 # slots conserved
    assert not eng._by_uid and not eng.has_work()


# -- deterministic retry (tier-1 acceptance) ---------------------------------

def _run_pair(faults, paged, chunk_tokens=None, replicas=1, max_new=12):
    fe = _fe(faults=faults, paged=paged, quarantine_after=1,
             chunk_tokens=chunk_tokens)
    if replicas > 1:
        fe.pool.scale(SMOL, "trt", replicas)
    hs = _submit_pair(fe, max_new=max_new)
    fe.serve_all()
    return fe, [h.response for h in hs]


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_retry_mid_decode_token_identical(paged):
    _, base = _run_pair(None, paged)
    plan = FaultPlan([FaultSpec("step_error", at_step=5, replica=0)], seed=3)
    fe, out = _run_pair(plan, paged)
    assert [f[4] for f in plan.fired] == ["step_error"]
    assert fe.pool.quarantines == 1
    _check_identical(base, out)
    assert all(r.usage.retries == 1 for r in out)
    # the quarantined replica's work was resubmitted, never dropped
    assert fe.scheduler.stats.retries == 2
    assert fe.scheduler.stats.failed == 0


@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_retry_mid_prefill_token_identical(paged):
    """Kill at step 2 of a chunked prefill (cursor > 0, nothing emitted
    yet): the retry re-prefills the served prompt from scratch on the
    substitute and must still match the fault-free run exactly."""
    _, base = _run_pair(None, paged, chunk_tokens=8)
    plan = FaultPlan([FaultSpec("step_error", at_step=2, replica=0)], seed=3)
    fe, out = _run_pair(plan, paged, chunk_tokens=8)
    assert [f[4] for f in plan.fired] == ["step_error"]
    assert fe.pool.quarantines == 1
    _check_identical(base, out)
    assert all(r.usage.retries == 1 for r in out)


def test_retry_onto_surviving_replica_cold_prefix_cache():
    """Satellite edge case: the retry lands on a replica whose radix
    cache never saw the chained prefix (fresh substitute / evicted
    blocks) — it falls back to a full re-prefill and must still be
    token-identical. With two replicas the survivor takes the evacuees
    while serving its own work."""
    _, base = _run_pair(None, True, replicas=2)
    plan = FaultPlan([FaultSpec("step_error", at_step=5, replica=0)], seed=3)
    fe, out = _run_pair(plan, True, replicas=2)
    assert fe.pool.quarantines == 1
    _check_identical(base, out)
    # full re-prefill fallback: the final result never reports more
    # cached tokens than its original served prompt
    for r in out:
        assert r.usage.cached_tokens <= r.usage.prompt_tokens


def test_degraded_replica_recovers_below_threshold():
    """One clean injected failure with quarantine_after=2 degrades the
    replica (kept in placement, state intact); the next clean step
    resets the breaker to healthy. No retry is ever needed."""
    _, base = _run_pair(None, False)
    plan = FaultPlan([FaultSpec("step_error", at_step=5, replica=0)], seed=3)
    fe = _fe(faults=plan, paged=False, quarantine_after=2)
    hs = _submit_pair(fe)
    fe.serve_all()
    out = [h.response for h in hs]
    _check_identical(base, out)
    assert fe.pool.quarantines == 0
    assert fe.scheduler.stats.retries == 0
    eng = fe.pool.replicas(*KEY)[0]
    assert eng.health.state == "healthy" and eng.health.failures == 1
    assert all(r.usage.retries == 0 for r in out)


def test_retry_budget_exhaustion_is_structured():
    """Every replica (original + substitutes) dies every step: the
    request burns its retry budget and resolves as finish_reason ==
    "failed" with the retry count in usage — never a hang or a crash."""
    plan = FaultPlan([FaultSpec("step_error", at_step=2)], seed=1)
    fe = _fe(faults=plan, paged=False, quarantine_after=1,
             sched=SchedulerConfig(max_retries=1))
    h = fe.submit(PROMPTS[0], max_new_tokens=8)
    fe.serve_all()
    r = h.response
    assert r.finish_reason == "failed" and not r.completed
    assert r.usage.retries == 1
    assert fe.scheduler.stats.failed == 1
    assert fe.pool.quarantines >= 2


def test_retry_racing_cancel_resolves_cancelled():
    """Satellite edge case: the client cancels while the retried request
    is waiting to re-dispatch. The result is a clean cancellation that
    still carries the tokens emitted before the failure."""
    plan = FaultPlan([FaultSpec("step_error", at_step=5, replica=0)], seed=3)
    fe = _fe(faults=plan, paged=False, quarantine_after=1,
             sched=SchedulerConfig(retry_backoff_s=60.0))
    h = fe.submit(PROMPTS[0], max_new_tokens=12)
    for _ in range(200):
        fe.step()
        if fe.scheduler.stats.retries:
            break
    assert fe.scheduler.stats.retries == 1
    assert h.cancel()
    r = h.response
    assert r.finish_reason == "cancelled"
    assert 0 < len(r.new_tokens) < 12          # pre-failure tokens kept
    assert not fe.scheduler._retry_ctx         # bookkeeping cleaned up
    assert not fe.has_work()


def test_no_containment_baseline_reraises():
    plan = FaultPlan([FaultSpec("step_error", at_step=2, replica=0)])
    fe = _fe(faults=plan, paged=False,
             sched=SchedulerConfig(contain_failures=False))
    fe.submit(PROMPTS[0], max_new_tokens=8)
    with pytest.raises(InjectedFault):
        fe.serve_all()


# -- quarantine / replacement / spin failures --------------------------------

def test_quarantine_replaces_and_settles_ledger_once():
    plan = FaultPlan([FaultSpec("step_error", at_step=4, replica=0)], seed=3)
    fe = _fe(faults=plan, paged=False, quarantine_after=1)
    h = fe.submit(PROMPTS[0], max_new_tokens=10)
    fe.serve_all()
    assert h.response.completed
    pool = fe.pool
    assert pool.quarantines == 1
    # the sick replica left placement and a substitute serves instead
    assert len(pool.replicas(*KEY)) == 1
    live = pool.replicas(*KEY)[0]
    assert live.incarnation == 1 and live.health.state == "healthy"
    assert not pool._pending_replace
    kinds = [e.kind for e in pool.events]
    assert "quarantine" in kinds
    # ledger: the quarantined meter settled exactly once; settling again
    # (drain/scale paths reaching the same engine) is a no-op
    ledger = fe.obs.ledger
    downs = [m for m in ledger.meters if m.down_t is not None]
    assert len(downs) == 1
    down_t0 = downs[0].down_t
    pool.quarantine(SMOL, "trt", live, time.perf_counter())  # now settles #2
    pool.quarantine(SMOL, "trt", live, time.perf_counter())  # idempotent
    assert downs[0].down_t == down_t0
    assert sum(1 for m in ledger.meters if m.down_t is not None) == 2
    # health gauges published per state
    reg = fe.obs.registry
    assert reg.value("replica_health", f"{SMOL}|state=quarantined") >= 1.0
    assert reg.value("replicas_quarantined_total", SMOL) >= 1.0
    assert reg.value("fault_injected_total", f"{SMOL}|kind=step_error") == 1.0
    assert reg.value("retries_total", SMOL) >= 1.0


def test_spin_fail_contained_and_retried_next_attempt():
    plan = FaultPlan([FaultSpec("spin_fail", replica=0)])
    fe = _fe(faults=plan, paged=False)
    pool = fe.pool
    assert pool.scale(SMOL, "trt", 1) == 0            # attempt 0 injected
    assert plan.fired[0][4] == "spin_fail"
    h = fe.submit(PROMPTS[0], max_new_tokens=4)       # spin-on-demand:
    fe.serve_all()                                    # attempt 1 succeeds
    assert h.response.completed
    assert pool.replicas(*KEY)[0].incarnation == 1


# -- graceful drain ----------------------------------------------------------

def test_scale_down_drains_in_flight_work():
    fe = _fe(paged=False)
    h = fe.submit(PROMPTS[0], max_new_tokens=24)
    fe.step()
    fe.step()                                         # mid-decode
    _, base = _run_pair(None, False, max_new=24)
    pool = fe.pool
    pool.scale(SMOL, "trt", 0)
    # out of placement immediately, still stepping until done
    assert not pool.replicas(*KEY)
    assert pool.total_replicas() == 1
    assert fe.registry.entry(*KEY).replicas == 0
    assert any(e.kind == "drain" for e in pool.events)
    fe.serve_all()
    r = h.response
    assert r.completed and len(r.new_tokens) == 24
    assert r.new_tokens == base[0].new_tokens         # drain changed nothing
    assert pool.total_replicas() == 0                 # retired after drain
    assert any(e.kind == "drained" for e in pool.events)
    assert fe.obs.registry._hists[("drain_s", SMOL)].count == 1


def test_drain_deadline_evacuates_and_retries():
    """A drain that can't finish in time force-evacuates; the evacuees
    are resubmitted (deterministic retry) onto a fresh replica."""
    _, base = _run_pair(None, False, max_new=24)
    fe = _fe(paged=False, drain_deadline_s=0.0)
    h = fe.submit(PROMPTS[0], max_new_tokens=24)
    fe.step()
    fe.step()
    fe.pool.scale(SMOL, "trt", 0)                     # deadline already past
    fe.serve_all()
    r = h.response
    assert r.completed and r.new_tokens == base[0].new_tokens
    assert r.usage.retries == 1
    assert any(e.kind == "drain-timeout" for e in fe.pool.events)
    assert fe.pool.total_replicas() in (0, 1)         # respun on demand
