"""Per-arch smoke tests (reduced configs) + prefill/decode parity.

Every assigned architecture instantiates a REDUCED member of its family
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one train
step on CPU, asserting output shapes and no NaNs — the assignment's smoke
requirement. Parity tests assert prefill+decode == full forward exactly
(f32, no-drop MoE).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.configs.registry import ARCHS
from repro.models import (init_cache, init_model, model_decode,
                          model_forward, model_prefill)
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.trainer import make_train_step

ALL_ARCHS = sorted(ARCHS)


def batch_for(cfg, B=2, S=16, seed=0, labels=False):
    rng = np.random.RandomState(seed)
    b = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
    if labels:
        b["labels"] = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))
    if cfg.family == "vlm":
        F = cfg.frontend_seq
        b["vision_embeds"] = jnp.asarray(
            rng.randn(B, F, cfg.d_model).astype(np.float32) * 0.1)
        pos = np.arange(F + S)
        b["positions"] = jnp.asarray(
            np.broadcast_to(pos[None, :, None], (B, F + S, 3)).copy())
    if cfg.family == "encdec":
        b["src_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_seq, cfg.d_model).astype(np.float32) * 0.1)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward(arch):
    cfg = ARCHS[arch].reduced()          # family-faithful reduced variant
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = init_model(cfg, jax.random.PRNGKey(0))
    b = batch_for(cfg)
    logits, aux = model_forward(params, cfg, b)
    F = cfg.frontend_seq if cfg.family == "vlm" else 0
    assert logits.shape == (2, 16 + F, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced_f32(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt_state = init_adamw(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=10)))
    b = batch_for(cfg, labels=True)
    params2, opt_state2, metrics = step(params, opt_state, b)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b_))) > 0
        for a, b_ in zip(jax.tree_util.tree_leaves(params),
                         jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_parity(arch):
    cfg = reduced_f32(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    F = cfg.frontend_seq if cfg.family == "vlm" else 0
    b = batch_for(cfg, B, S)
    lp, cache = model_prefill(params, cfg, b, cache_len=F + S + 8, moe_cf=None)
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    pos3 = jnp.full((B, 1, 3), F + S, jnp.int32) if cfg.family == "vlm" else None
    ld, _ = model_decode(params, cfg, nxt, cache, jnp.int32(F + S),
                         positions=pos3, moe_cf=None)
    b2 = dict(b)
    b2["tokens"] = jnp.concatenate([b["tokens"], nxt], axis=1)
    if cfg.family == "vlm":
        pos = np.arange(F + S + 1)
        b2["positions"] = jnp.asarray(
            np.broadcast_to(pos[None, :, None], (B, F + S + 1, 3)).copy())
    lf, _ = model_forward(params, cfg, b2, moe_cf=None)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lf[:, -2]),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf[:, -1]),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "mamba2-2.7b",
                                  "zamba2-1.2b", "deepseek-v2-236b"])
def test_ragged_decode_positions(arch):
    """Per-sequence positions (continuous batching) == per-sequence scalar."""
    cfg = reduced_f32(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    b = batch_for(cfg, B, S)
    _, cache = model_prefill(params, cfg, b, cache_len=S + 8, moe_cf=None)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    # vector pos (both at S) must equal scalar pos
    l_vec, _ = model_decode(params, cfg, tok, cache,
                            jnp.asarray([S, S], jnp.int32), moe_cf=None)
    l_scl, _ = model_decode(params, cfg, tok, cache, jnp.int32(S), moe_cf=None)
    np.testing.assert_allclose(np.asarray(l_vec), np.asarray(l_scl),
                               atol=1e-5, rtol=1e-5)


def test_sliding_window_matches_full_when_window_covers():
    """window >= seq => identical logits to full attention."""
    cfg = reduced_f32("phi3-medium-14b")
    cfg_sw = dataclasses.replace(cfg, sliding_window=64)
    params = init_model(cfg, jax.random.PRNGKey(0))
    b = batch_for(cfg, 1, 16)
    lf, _ = model_forward(params, cfg, b)
    lw, _ = model_forward(params, cfg_sw, b)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw), atol=1e-5,
                               rtol=1e-5)


def test_sliding_window_ring_decode_parity():
    """Ring-buffer decode == full-cache decode while within the window."""
    cfg = dataclasses.replace(reduced_f32("smollm-360m"), sliding_window=32)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 1, 16
    b = batch_for(cfg, B, S)
    lp, cache = model_prefill(params, cfg, b, cache_len=64)
    # window cache must have window-sized seq dim
    assert cache["stack"]["k"].shape[3 - 1] == 32 or \
        cache["stack"]["k"].shape[2] == 32
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    ld, _ = model_decode(params, cfg, nxt, cache, jnp.int32(S))
    b2 = {"tokens": jnp.concatenate([b["tokens"], nxt], 1)}
    lf, _ = model_forward(params, cfg, b2)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_int8_kv_cache_accuracy():
    """Quantized GQA cache (§Perf H1 it. 3): int8 decode tracks bf16."""
    cfg = reduced_f32("phi3-medium-14b")
    cfg_q = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    b = batch_for(cfg, B, S)
    lp, cache = model_prefill(params, cfg, b, cache_len=S + 8)
    lpq, cacheq = model_prefill(params, cfg_q, b, cache_len=S + 8)
    assert cacheq["stack"]["k"].dtype == jnp.int8
    assert cacheq["stack"]["k_scale"].shape[-1] == 1
    # prefill logits don't read the cache: identical
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lpq), atol=1e-5)
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    ld, _ = model_decode(params, cfg, nxt, cache, jnp.int32(S))
    ldq, _ = model_decode(params, cfg_q, nxt, cacheq, jnp.int32(S))
    # top-1 agreement and high correlation under int8 noise
    assert bool((jnp.argmax(ld, -1) == jnp.argmax(ldq, -1)).all())
    corr = np.corrcoef(np.asarray(ld).ravel(), np.asarray(ldq).ravel())[0, 1]
    assert corr > 0.999


def test_int8_kv_ring_cache():
    """int8 + sliding-window ring cache compose."""
    cfg = dataclasses.replace(reduced_f32("smollm-360m"),
                              sliding_window=32, kv_cache_dtype="int8")
    params = init_model(cfg, jax.random.PRNGKey(0))
    b = batch_for(cfg, 1, 16)
    lp, cache = model_prefill(params, cfg, b, cache_len=64)
    assert cache["stack"]["k"].dtype == jnp.int8
    assert cache["stack"]["k"].shape[2] == 32
    nxt = jnp.argmax(lp, -1)[:, None].astype(jnp.int32)
    ld, _ = model_decode(params, cfg, nxt, cache, jnp.int32(16))
    assert bool(jnp.all(jnp.isfinite(ld)))


def test_mla_cache_is_latent_sized():
    """MLA decode cache stores the latent stream, not 2*H*D per token."""
    cfg = reduced_f32("deepseek-v2-236b")
    cache = init_cache(cfg, batch=2, cache_len=64)
    ckv = cache["stack"]["ckv"]
    assert ckv.shape[-1] == cfg.kv_lora_rank
    # the serving win holds on the FULL assigned config
    full = ARCHS["deepseek-v2-236b"]
    full_kv_floats = 2 * full.num_heads * full.qk_nope_head_dim
    latent_floats = full.kv_lora_rank + full.qk_rope_head_dim
    assert latent_floats < full_kv_floats / 4   # 576 vs 32768 per token
