"""Observability plane: quantile registry, snapshot merges, lifecycle
tracing, exporters, and the telemetry bridge.

Pure host-side tests (no jax, no engines): the numeric contracts are
checked against numpy oracles — histogram quantiles within one log-bucket
ratio of ``np.percentile``, merged snapshots exactly equal to the
histogram fed the concatenated stream, telemetry's windowed quantiles
exact. Plus the PR-6 satellite regressions: the scheduler stamps
telemetry with the step's OWN clock under simulated time, and every
terminal resolution closes a span.
"""
import json
import math

import numpy as np
import pytest

from repro.core.telemetry import Telemetry
from repro.obs import (DEFAULT_BUCKETS, EventLog, Histogram, MetricsRegistry,
                       Observability, Tracer, log_buckets, prometheus_text,
                       snapshot_quantile, write_metrics_dump)
from repro.serving.engine import GenResult, Request
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import RequestScheduler, SchedulerConfig

# one log-spaced bucket spans this ratio; quantile error is bounded by it
BUCKET_RATIO = 10 ** (1 / 10)


# ---------------------------------------------------------------------------
# histogram quantiles vs the numpy oracle


def test_log_buckets_cover_decades():
    b = log_buckets(1e-5, 1e4, 10)
    assert b[0] == pytest.approx(1e-5) and b[-1] == pytest.approx(1e4)
    assert len(b) == 91
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(BUCKET_RATIO, rel=1e-9) for r in ratios)


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
@pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
def test_quantile_within_one_bucket_of_numpy(dist, q):
    seeds = {"lognormal": 100, "uniform": 200, "bimodal": 300}
    rng = np.random.RandomState(seeds[dist] + int(q * 100))
    if dist == "lognormal":
        xs = rng.lognormal(mean=-3.0, sigma=1.2, size=4000)
    elif dist == "uniform":
        xs = rng.uniform(1e-3, 2.0, size=4000)
    else:
        # unequal modes so no tested quantile falls in the density gap
        # between them (where any bucketed estimate is ill-defined)
        xs = np.concatenate([rng.lognormal(-5, 0.3, 2600),
                             rng.lognormal(0, 0.3, 1400)])
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    oracle = float(np.percentile(xs, 100 * q))
    est = h.quantile(q)
    # log-interpolation inside the landing bucket: within one bucket
    # ratio of the exact percentile (small slack for interpolation)
    assert oracle / (BUCKET_RATIO * 1.05) <= est <= \
        oracle * BUCKET_RATIO * 1.05


def test_quantile_clamps_to_observed_range():
    h = Histogram()
    for v in (0.2, 0.21, 0.22):
        h.observe(v)
    assert h.quantile(0.0) >= 0.2
    assert h.quantile(1.0) <= 0.22
    assert h.min == 0.2 and h.max == 0.22


def test_quantile_empty_and_overflow():
    h = Histogram()
    assert h.quantile(0.95) == 0.0
    h.observe(1e6)                        # beyond the last bound -> +Inf slot
    assert h.counts[-1] == 1
    assert h.quantile(0.5) == 1e6         # clamped to observed max


def test_histogram_mean_and_count():
    h = Histogram()
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    assert h.count == 3
    assert h.mean == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# snapshot merge: associative, commutative, equal to the combined stream


def _filled_registry(seed, n=300):
    rng = np.random.RandomState(seed)
    r = MetricsRegistry()
    for m in ("a", "b"):
        r.counter("reqs", m).inc(int(rng.randint(1, 50)))
        r.gauge("load", m).set(float(rng.rand()), stamp=float(rng.rand()))
        h = r.histogram("lat", m)
        for x in rng.lognormal(-2, 1, n):
            h.observe(float(x))
    return r


def _assert_snap_equal(a, b):
    """Snapshot equality up to float-addition rounding in histogram
    ``sum`` (counters and bucket counts are integers-in-floats and must
    match exactly; gauges must match exactly)."""
    assert a["counters"] == b["counters"]
    assert a["gauges"] == b["gauges"]
    assert a["histograms"].keys() == b["histograms"].keys()
    for k, ha in a["histograms"].items():
        hb = b["histograms"][k]
        for f in ("bounds", "counts", "count", "min", "max"):
            assert ha[f] == hb[f], (k, f)
        assert ha["sum"] == pytest.approx(hb["sum"])


def test_merge_associative_and_commutative():
    s1, s2, s3 = (_filled_registry(i).snapshot() for i in (1, 2, 3))
    left = MetricsRegistry.merge(MetricsRegistry.merge(s1, s2), s3)
    right = MetricsRegistry.merge(s1, MetricsRegistry.merge(s2, s3))
    _assert_snap_equal(left, right)
    _assert_snap_equal(MetricsRegistry.merge(s1, s2),
                       MetricsRegistry.merge(s2, s1))
    _assert_snap_equal(MetricsRegistry.merge_all([s1, s2, s3]), left)


def test_merge_equals_combined_stream():
    rng = np.random.RandomState(7)
    xs = rng.lognormal(-2, 1, 500)
    ha, hb, hall = Histogram(), Histogram(), Histogram()
    for i, x in enumerate(xs):
        (ha if i % 2 else hb).observe(float(x))
        hall.observe(float(x))
    sa = {"counters": {}, "gauges": {}, "histograms": {("l", "m"):
                                                       ha.snapshot()}}
    sb = {"counters": {}, "gauges": {}, "histograms": {("l", "m"):
                                                       hb.snapshot()}}
    merged = MetricsRegistry.merge(sa, sb)["histograms"][("l", "m")]
    full = hall.snapshot()
    for f in ("bounds", "counts", "count", "min", "max"):
        assert merged[f] == full[f]
    assert merged["sum"] == pytest.approx(full["sum"])
    for q in (0.5, 0.95, 0.99):
        assert snapshot_quantile(merged, q) == hall.quantile(q)


def test_merge_gauge_keeps_newest_stamp():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.gauge("g", "m").set(1.0, stamp=10.0)
    b.gauge("g", "m").set(2.0, stamp=5.0)           # older write
    merged = MetricsRegistry.merge(a.snapshot(), b.snapshot())
    assert merged["gauges"][("g", "m")] == (10.0, 1.0)


def test_merge_bucket_mismatch_raises():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("h", "m").observe(0.1)
    b.histogram("h", "m", bounds=log_buckets(per_decade=5)).observe(0.1)
    with pytest.raises(ValueError, match="bucket mismatch"):
        MetricsRegistry.merge(a.snapshot(), b.snapshot())


def test_registry_queries():
    r = _filled_registry(11)
    assert r.value("reqs", "a") > 0
    assert r.value("missing", "a") == 0.0
    assert r.labels("lat") == ["a", "b"]
    assert r.quantile("lat", "a", 0.95) > 0
    assert r.quantile("lat", "zzz", 0.95) == 0.0


# ---------------------------------------------------------------------------
# lifecycle tracing


def test_span_full_lifecycle_and_derived_phases():
    tr = Tracer(MetricsRegistry())
    tr.on_submit(1, "m", "trt", t=100.0)
    tr.on_admit(1, t=100.5)
    tr.on_chunk(1, t=100.6, n=32)
    tr.on_chunk(1, t=100.7, n=16)
    tr.on_first_token(1, t=100.8)
    tr.on_tokens(1, t=101.0, n=2)
    span = tr.on_finish(1, t=101.2, outcome="length")
    assert span.complete()
    assert span.queue_wait_s == pytest.approx(0.5)
    assert span.prefill_s == pytest.approx(0.3)
    assert span.ttft_s == pytest.approx(0.8)
    assert span.decode_s == pytest.approx(0.4)
    assert span.e2e_s == pytest.approx(1.2)
    assert span.chunks == 2 and span.chunk_tokens == 48
    assert span.decode_tokens == 3
    kinds = [e[0] for e in span.events]
    assert kinds == ["submit", "admit", "chunk", "chunk", "first_token",
                     "decode", "finish"]
    reg = tr.registry
    assert reg.histogram("queue_wait_s", "m").count == 1
    assert reg.histogram("ttft_s", "m").count == 1
    assert reg.histogram("e2e_s", "m").count == 1


def test_burst_itl_spread_over_k_tokens():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    tr.on_submit(1, "m", "trt", t=0.0)
    tr.on_admit(1, t=0.0)
    tr.on_first_token(1, t=1.0)
    tr.on_tokens(1, t=1.4, n=4)            # one burst replay: 0.4s wall
    h = reg.histogram("itl_s", "m")
    assert h.count == 4                    # K observations...
    assert h.mean == pytest.approx(0.1)    # ...each the per-token share


def test_shed_before_admit_span_incomplete():
    tr = Tracer(MetricsRegistry())
    tr.on_submit(2, "m", "trt", t=5.0)
    span = tr.on_finish(2, t=5.1, outcome="shed")
    assert span is not None and not span.complete()
    assert span.queue_wait_s == 0.0 and span.ttft_s == 0.0
    assert span.e2e_s == pytest.approx(0.1)


def test_tracer_ignores_warmup_probes_and_bounds_ring():
    tr = Tracer(max_spans=4)
    tr.on_submit(-1, "m", "trt", t=0.0)
    tr.on_admit(-1, t=0.0)
    assert tr.on_finish(-1, t=1.0, outcome="length") is None
    for uid in range(8):
        tr.on_submit(uid, "m", "trt", t=float(uid))
        tr.on_finish(uid, t=uid + 0.5, outcome="length")
    assert len(tr) == 4                    # ring keeps the newest
    assert [s.uid for s in tr.finished] == [4, 5, 6, 7]


def test_tracer_lazy_open_at_admit():
    # standalone engines (no frontend) open spans at admission
    tr = Tracer(MetricsRegistry())
    tr.on_admit(9, t=2.0, arrival_t=1.5, model="m", backend="trt")
    span = tr.on_finish(9, t=3.0, outcome="length")
    assert span.queue_wait_s == pytest.approx(0.5)
    assert span.model == "m"


# ---------------------------------------------------------------------------
# exporters


def test_prometheus_text_cumulative_buckets():
    r = MetricsRegistry()
    r.counter("requests", "m").inc(3)
    r.gauge("load", "m").set(0.5, stamp=1.0)
    h = r.histogram("lat", "m")
    for v in (0.01, 0.02, 5000.0):
        h.observe(v)
    text = prometheus_text(r.snapshot())
    assert '# TYPE repro_requests counter' in text
    assert 'repro_requests{model="m"} 3.0' in text
    assert 'repro_load{model="m"} 0.5' in text
    assert '# TYPE repro_lat histogram' in text
    assert 'repro_lat_bucket{model="m",le="+Inf"} 3' in text
    assert 'repro_lat_count{model="m"} 3' in text
    # bucket counts are CUMULATIVE and non-decreasing
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
              if ln.startswith("repro_lat_bucket")]
    assert counts == sorted(counts) and counts[-1] == 3
    assert f'repro_lat_sum{{model="m"}} {repr(5000.03)}' in text


def test_prometheus_label_values_escaped():
    # label VALUES must be escaped per the exposition spec (backslash,
    # double-quote, newline) — an unescaped quote breaks every scraper
    r = MetricsRegistry()
    weird = 'mo"del\\v1\n'
    r.gauge("load", weird).set(1.0, stamp=1.0)
    r.histogram("lat", weird).observe(0.1)
    text = prometheus_text(r.snapshot())
    esc = 'model="mo\\"del\\\\v1\\n"'
    assert f"repro_load{{{esc}}} 1.0" in text
    assert f'repro_lat_bucket{{{esc},le="+Inf"}} 1' in text
    assert f"repro_lat_count{{{esc}}} 1" in text
    # composite labels escape each value independently
    r2 = MetricsRegistry()
    r2.gauge("kv_pool_bytes", 'm|state=u"sed').set(2.0, stamp=1.0)
    assert 'repro_kv_pool_bytes{model="m",state="u\\"sed"} 2.0' in \
        prometheus_text(r2.snapshot())


def test_prometheus_single_type_line_per_metric():
    # one # TYPE line per metric NAME, no matter how many labels carry
    # it — scrapers reject duplicate metadata
    r = MetricsRegistry()
    for m in ("a", "b", "c"):
        r.gauge("load", m).set(1.0, stamp=1.0)
        r.histogram("lat", m).observe(0.1)
    text = prometheus_text(r.snapshot())
    lines = text.splitlines()
    assert lines.count("# TYPE repro_load gauge") == 1
    assert lines.count("# TYPE repro_lat histogram") == 1


def test_empty_histogram_exposition_well_formed():
    # a histogram that was created but never observed still renders a
    # full cumulative bucket ladder with zero counts and _sum/_count 0
    r = MetricsRegistry()
    r.histogram("lat", "m")
    text = prometheus_text(r.snapshot())
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
              if ln.startswith("repro_lat_bucket")]
    assert counts and all(c == 0 for c in counts)
    assert 'repro_lat_bucket{model="m",le="+Inf"} 0' in text
    assert 'repro_lat_sum{model="m"} 0.0' in text
    assert 'repro_lat_count{model="m"} 0' in text


def test_merge_disjoint_label_sets_is_union():
    # two replica snapshots that saw DIFFERENT models merge to the union
    # with every series intact (no key intersection assumed)
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("reqs", "only-a").inc(2)
    a.histogram("lat", "only-a").observe(0.1)
    b.counter("reqs", "only-b").inc(3)
    b.histogram("lat", "only-b").observe(0.2)
    b.gauge("load", "only-b").set(0.5, stamp=1.0)
    merged = MetricsRegistry.merge(a.snapshot(), b.snapshot())
    assert merged["counters"][("reqs", "only-a")] == 2
    assert merged["counters"][("reqs", "only-b")] == 3
    assert merged["gauges"][("load", "only-b")] == (1.0, 0.5)
    assert merged["histograms"][("lat", "only-a")]["count"] == 1
    assert merged["histograms"][("lat", "only-b")]["count"] == 1
    for q in (0.5, 0.95):
        assert snapshot_quantile(merged["histograms"][("lat", "only-a")],
                                 q) > 0


def test_event_log_bounded_and_jsonl():
    log = EventLog(maxlen=3)
    for i in range(5):
        log.append("shed", t=float(i), model="m", uid=i)
    assert len(log) == 3
    assert [e["uid"] for e in log.of("shed")] == [2, 3, 4]
    lines = [json.loads(ln) for ln in log.to_jsonl().splitlines()]
    assert lines[0] == {"event": "shed", "t": 2.0, "model": "m", "uid": 2}


def test_write_metrics_dump_artifacts(tmp_path):
    obs = Observability()
    obs.registry.histogram("ttft_s", "m").observe(0.1)
    obs.events.append("scale", t=1.0, model="m", kind="spin-cold")
    obs.tracer.on_submit(1, "m", "trt", t=0.0)
    obs.tracer.on_finish(1, t=1.0, outcome="length")
    path = str(tmp_path / "metrics.prom")
    paths = write_metrics_dump(path, obs.registry, events=obs.events,
                               tracer=obs.tracer)
    assert paths == [path, path + ".events.jsonl", path + ".spans.jsonl"]
    assert "repro_ttft_s_bucket" in open(path).read()
    events = [json.loads(ln) for ln in open(paths[1])]
    assert events[0]["kind"] == "spin-cold"
    spans = [json.loads(ln) for ln in open(paths[2])]
    assert spans[0]["uid"] == 1 and spans[0]["outcome"] == "length"


# ---------------------------------------------------------------------------
# telemetry bridge + windowed quantiles


def test_telemetry_latency_quantile_exact():
    tel = Telemetry(window_s=100.0)
    rng = np.random.RandomState(3)
    xs = rng.lognormal(-1, 0.7, 200)
    for i, x in enumerate(xs):
        tel.record_latency("m", float(i) * 0.1, float(x))
    now = 20.0
    for q in (0.5, 0.95, 0.99):
        assert tel.latency_quantile("m", now, q) == \
            pytest.approx(float(np.percentile(xs, 100 * q)))
    assert tel.p95_latency("m", now) == tel.latency_quantile("m", now, 0.95)
    assert tel.latency_quantile("zzz", now) == 1.0        # default


def test_telemetry_quantile_windowed():
    tel = Telemetry(window_s=10.0)
    tel.record_latency("m", 0.0, 100.0)          # will age out
    tel.record_latency("m", 50.0, 1.0)
    assert tel.latency_quantile("m", 51.0, 0.99) == 1.0


def test_telemetry_mirrors_into_registry():
    reg = MetricsRegistry()
    tel = Telemetry(registry=reg)
    tel.record_request("m", 1.0)
    tel.record_latency("m", 1.5, 0.25)
    tel.record_gauge("m", "kv_pressure", 2.0, 0.7)
    assert reg.value("requests", "m") == 1.0
    assert reg.histogram("service_latency_s", "m").count == 1
    assert reg.value("kv_pressure", "m") == 0.7
    assert reg.gauge("kv_pressure", "m").stamp == 2.0


# ---------------------------------------------------------------------------
# scheduler clock + event instrumentation (stub plane, no engines)


class _Entry:
    def __init__(self):
        self.queued = 0
        self.active_requests = 0


class _Reg:
    backends = ("trt",)

    def __init__(self):
        self._e = {}

    def entry(self, m, b):
        return self._e.setdefault((m, b), _Entry())


class _Eng:
    paged = False

    def __init__(self, results=()):
        self._results = list(results)

    def has_work(self):
        return bool(self._results)

    def step(self):
        out, self._results = self._results, []
        return out

    def drain_deltas(self):
        return []

    def free_slots(self):
        return 4

    def pending_tokens(self):
        return 0

    def prefix_peek(self, req):
        return 0

    def submit(self, req):
        pass

    def cancel(self, uid, now=None):
        return None


class _Pool:
    max_seq = 256

    def __init__(self, eng):
        self._replicas = {("m", "trt"): [eng]}

    def free_slots(self, m, b):
        return sum(e.free_slots() for e in self._replicas[(m, b)])

    def replicas(self, m, b):
        return self._replicas[(m, b)]

    def engines(self):
        for k, reps in self._replicas.items():
            for e in reps:
                yield k, e

    def paged_replicas(self, m, b):
        return []

    def kv_stats(self, m):
        return None

    def backlog_tokens(self, m):
        return 0

    def kv_free_frac(self, m, b):
        return 1.0

    def kv_bound(self, m, b):
        return False

    def scale(self, m, b, n, now=None):
        return n


def _req(uid, priority=1, arrival_t=0.0, n_tokens=4):
    return Request(uid=uid, arrival_t=arrival_t,
                   tokens=list(range(1, n_tokens + 1)),
                   sampling=SamplingParams(max_new_tokens=4),
                   priority=priority)


def test_scheduler_latency_stamped_with_simulated_now():
    # the PR-6 mixed-clock fix: a finish reported during step(now=SIM)
    # must land in telemetry at SIM, not at time.perf_counter() — the
    # simulated-time window otherwise never contains its own samples
    res = GenResult(uid=0, prompt_len=3)
    res.latency = 0.5
    tel = Telemetry(window_s=10.0)
    sched = RequestScheduler(_Pool(_Eng([res])), _Reg(), tel)
    sim_now = 1_000_000.0                 # far from any real perf_counter
    sched.step(now=sim_now)
    t, lat = tel._latency["m"][0]
    assert t == sim_now and lat == 0.5
    # and the window query AT simulated time sees the sample
    assert tel.avg_latency("m", sim_now) == 0.5


def test_scheduler_queue_wait_and_shed_instrumented():
    obs = Observability()
    eng = _Eng()
    sched = RequestScheduler(
        _Pool(eng), _Reg(), Telemetry(),
        cfg=SchedulerConfig(max_queue_depth=1, spin_on_demand=False),
        obs=obs)
    # fast path: free slot -> dispatched at now, queue wait = now-arrival
    assert sched.enqueue("m", "trt", _req(0, arrival_t=4.0), now=5.0)
    h = obs.registry.histogram("sched_queue_wait_s", "m")
    assert h.count == 1 and h.mean == pytest.approx(1.0)
    eng.free_slots = lambda: 0            # no slots: next ones queue
    assert sched.enqueue("m", "trt", _req(1), now=5.1)
    # queue full, equal priority -> shed, counted + logged
    assert not sched.enqueue("m", "trt", _req(2), now=5.2)
    assert obs.registry.value("sched_shed", "m") == 1
    assert obs.events.of("shed")[0]["reason"] == "queue_full"
    # higher priority evicts the queued low one -> preempt event
    assert sched.enqueue("m", "trt", _req(3, priority=2), now=5.3)
    assert obs.registry.value("sched_preempt", "m") == 1
    assert obs.events.of("preempt")[0] == {
        "event": "preempt", "t": 5.3, "model": "m", "uid": 1, "by": 3}
    assert sched.stats.preempted == 1


def test_scheduler_expire_event_logged():
    obs = Observability()
    eng = _Eng()
    eng.free_slots = lambda: 0
    sched = RequestScheduler(
        _Pool(eng), _Reg(), Telemetry(),
        cfg=SchedulerConfig(spin_on_demand=False), obs=obs)
    r = _req(0, arrival_t=0.0)
    r.deadline_s = 1.0
    assert sched.enqueue("m", "trt", r, now=0.0)
    sched.step(now=100.0)                 # way past the deadline
    assert obs.registry.value("sched_expire", "m") == 1
    assert obs.events.of("expire")[0]["uid"] == 0
    assert sched.stats.expired == 1
