"""SL005 seed: metric-label cardinality hazards.

(a) labelling a counter with the request ``uid`` creates one series
per request — unbounded registry growth; (b) ``kv_pool_bytes`` is
registered with a composite ``model|state=...`` label everywhere else,
so a plain-label call site silently forks the metric.  Servelint must
flag both.
"""


class Obs:
    def on_finish(self, registry, model, req):
        # (a) one series per request
        registry.counter("completions_total", f"{model}|uid={req.uid}").inc()

    def on_scale(self, registry, model, used, free):
        registry.gauge("kv_pool_bytes", f"{model}|state=used").set(used)
        registry.gauge("kv_pool_bytes", f"{model}|state=free").set(free)
        # (b) plain label where every other site uses |state=...
        registry.gauge("kv_pool_bytes", "total").set(used + free)
