"""SL002 clean twin of ``sl002_host_sync_bad.py``: ONE designed
readback via ``jax.device_get`` (carrying its reviewed suppression),
then pure host-side bookkeeping.  Servelint must stay silent."""
import jax


class Engine:
    def _decode_once(self, active):
        nxt, self.cache, self._dstate = self._fused_step(
            self.params, self.cache, self._dstate)
        # servelint: disable=SL002 -- the designed per-step sync point
        toks = jax.device_get(nxt)
        for i in active:
            s = self._slots[i]
            tok = int(toks[i])                # host value: no sync
            s.res.new_tokens.append(tok)
        return toks
