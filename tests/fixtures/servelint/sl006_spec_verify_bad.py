"""SL006 seed: per-drafted-position host syncs inside the speculative
verify path.

The verify step's whole point is ONE multi-token target forward with
ONE batched id readback; every pattern here re-introduces a blocking
device->host round-trip PER DRAFTED POSITION — ``.item()`` on each
candidate, ``np.asarray`` of the id matrix inside the row loop, and
``int()`` on a device value — turning the K-tokens-per-forward win
into K syncs.  Servelint (with this file's ``Engine._decode_spec``
configured as a verify function) must flag all three.
"""
import jax
import numpy as np


class Engine:
    def _decode_spec(self, active):
        out, reason, self.cache, self._dstate = self._spec_dispatch()
        for i in active:
            row = np.asarray(out[i])          # sync: per-row np pull
            s = self._slots[i]
            for j in range(self.spec.k + 1):
                tok = out[i, j].item()        # sync: per-position .item()
                if tok < 0:
                    break
                s.res.new_tokens.append(tok)
            s.reason = int(reason[i])         # sync: int() on device value
        return out
