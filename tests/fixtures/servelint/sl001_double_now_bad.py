"""SL001 seed: the PR-7 double-``now`` bug, verbatim.

``RequestScheduler.enqueue`` (as shipped in PR 6) forwarded ``now`` to
``_to_engine``/``_note`` UNRESOLVED on the fast and shed paths, then
resolved it mid-function on the evict path — one logical admission
could observe two different wall stamps (or a raw ``None``).  Fixed in
PR 7 by resolving once at entry.  Servelint must flag the mid-function
resolution as coming after prior uses.
"""
import time
from typing import Optional


class Scheduler:
    def enqueue(self, model: str, backend: str, req,
                now: Optional[float] = None) -> bool:
        """Admit a routed request. Returns False if shed (queue full and
        nothing of lower priority to evict)."""
        key = (model, backend)
        q = self._queues[key]
        self.stats.submitted += 1
        # fast path: nothing waiting and a free slot -> straight in
        if not q and self.pool.free_slots(model, backend) > 0:
            self._to_engine(key, req, now)
            self.stats.dispatched += 1
            return True
        if len(q) >= self._depth_limit(model, backend):
            victims = self._shed_victims(model, backend, q, req)
            if victims is None:
                self.stats.shed += 1
                self._note("shed", model, now, uid=req.uid,
                           reason="queue_full")
                return False
            now = time.perf_counter() if now is None else now
            entry = self.reg.entry(model, backend)
            for victim in victims:
                q.remove(victim)
                self.stats.preempted += 1
                self._note("preempt", model, now, uid=victim.uid,
                           by=req.uid)
            q.append(req)
            entry.queued = max(0, entry.queued - len(victims) + 1)
            return True
        q.append(req)
        self.reg.entry(model, backend).queued += 1
        return True
