"""SL007 clean twin: every broad handler on the fault path either
re-raises, routes the failure into a containment routine
(``report_step_failure`` / ``quarantine`` / ``note_exception``), or is
a typed handler for a designed, recoverable condition."""


class PoolExhausted(RuntimeError):
    pass


class Scheduler:
    def step_all(self, engines, now):
        for key, eng in engines:
            try:
                eng.step()
            except Exception as exc:
                self.flight.note_exception(key[0], exc, now)
                self.pool.report_step_failure(key[0], key[1], eng, exc, now)

    def reap(self, eng, now):
        try:
            return eng.drain_finished()
        except BaseException:
            eng.poisoned = True            # conserve, then propagate
            raise

    def admit(self, eng, req):
        try:
            eng.enqueue(req)
        except PoolExhausted:              # typed: designed backpressure
            self.requeue(req)

    def retire(self, eng, now):
        try:
            eng.flush()
        except Exception:
            self.pool.quarantine(eng.model, eng.backend, eng, now)
