"""SL005 clean twin of ``sl005_cardinality_bad.py``: bounded labels
(model only), one consistent composite shape per metric name, and the
request id goes to the trace, not a label.  Servelint must stay
silent."""


class Obs:
    def on_finish(self, registry, tracer, model, req):
        registry.counter("completions_total", model).inc()
        tracer.on_finish(req.uid)             # ids belong in the trace

    def on_scale(self, registry, model, used, free):
        registry.gauge("kv_pool_bytes", f"{model}|state=used").set(used)
        registry.gauge("kv_pool_bytes", f"{model}|state=free").set(free)
