"""SL006 clean twin: the designed verify readback — ONE batched
``jax.device_get`` of the int32 id matrix + reason bits per verify
dispatch, OUTSIDE any loop; the host loop then iterates the pulled
numpy copy (plain host ints, no device traffic)."""
import jax


class Engine:
    def _decode_spec(self, active):
        out, reason, self.cache, self._dstate = self._spec_dispatch()
        out, reason = jax.device_get((out, reason))   # the one sync point
        for i in active:
            s = self._slots[i]
            for tok in out[i]:
                if tok < 0:
                    break
                s.res.new_tokens.append(int(tok))
            s.reason = int(reason[i])
        return out
