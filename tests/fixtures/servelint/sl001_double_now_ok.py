"""SL001 clean twin of ``sl001_double_now_bad.py``: the PR-7 fix — the
clock is resolved ONCE, at function entry, before any path can consume
it.  Servelint must stay silent."""
import time
from typing import Optional


class Scheduler:
    def enqueue(self, model: str, backend: str, req,
                now: Optional[float] = None) -> bool:
        """Admit a routed request. Returns False if shed (queue full and
        nothing of lower priority to evict)."""
        key = (model, backend)
        q = self._queues[key]
        self.stats.submitted += 1
        # resolve the clock ONCE, up front: a shed below this point must
        # log the caller's (possibly simulated) timestamp, not a stray
        # perf_counter interleaved into sim time (the PR-6 bug class)
        now = time.perf_counter() if now is None else now
        # fast path: nothing waiting and a free slot -> straight in
        if not q and self.pool.free_slots(model, backend) > 0:
            self._to_engine(key, req, now)
            self.stats.dispatched += 1
            return True
        if len(q) >= self._depth_limit(model, backend):
            victims = self._shed_victims(model, backend, q, req)
            if victims is None:
                self.stats.shed += 1
                self._note("shed", model, now, uid=req.uid,
                           reason="queue_full")
                return False
            entry = self.reg.entry(model, backend)
            for victim in victims:
                q.remove(victim)
                self.stats.preempted += 1
                self._note("preempt", model, now, uid=victim.uid,
                           by=req.uid)
            q.append(req)
            entry.queued = max(0, entry.queued - len(victims) + 1)
            return True
        q.append(req)
        self.reg.entry(model, backend).queued += 1
        return True
