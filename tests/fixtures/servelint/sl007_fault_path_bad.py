"""SL007 seed: broad exception handlers that swallow replica failures.

Three violations of the serve plane's containment contract: a bare
``except:`` that drops the fault on the floor, an ``except Exception:``
that only logs, and a tuple handler catching ``BaseException`` that
"handles" the crash by zeroing state.  None re-raise, none route into a
containment routine — the exact pattern that turns an injected replica
crash into silent state corruption the chaos harness can never observe.
Servelint (with this file configured as a fault-path module) must flag
all three.
"""


class Scheduler:
    def step_all(self, engines, now):
        for key, eng in engines:
            try:
                eng.step()
            except:                        # noqa: E722  (the seed itself)
                pass                       # swallowed: replica keeps serving

    def reap(self, eng, now):
        try:
            return eng.drain_finished()
        except Exception as exc:
            print(f"step failed: {exc!r}")  # logged, never contained
            return []

    def reset(self, eng):
        try:
            eng.flush()
        except (ValueError, BaseException):
            eng.slots = []                 # "recovery" that loses requests
            return None
