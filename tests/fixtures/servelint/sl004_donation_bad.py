"""SL004 seed: use-after-donate.

``fused_step`` donates its cache and state arguments (positions 1, 2
of the bound callable) — jax reuses their buffers for the outputs.
Reading ``self.cache`` again WITHOUT rebinding it from the result
returns garbage (or raises on a deleted buffer).  Servelint must flag
the post-call read.
"""


class Engine:
    def step_once(self):
        nxt, new_cache, new_state = self.fused_step(
            self.params, self.cache, self._dstate)
        # BUG: self.cache was donated above and never rebound
        used = self.kv_bytes(self.cache)
        return nxt, used
