"""SL001 seed: the PR-6 mixed-clock bug, verbatim.

``RequestScheduler.step`` (as shipped in PR 5) resolved ``now`` once at
entry — and then stamped completion telemetry with a FRESH
``time.perf_counter()``, so simulated-clock drivers got wall-time
latency windows.  Fixed in PR 6 by stamping with the step's own clock.
Servelint must flag the ``record_latency`` line.
"""
import time
from typing import List, Tuple


class Scheduler:
    def step(self, now: float = None) -> List[Tuple[str, object]]:
        """One serve-loop iteration over the whole pool: admit queued work,
        run ONE batched decode on every engine with work, reap finished."""
        now = time.perf_counter() if now is None else now
        self.stats.steps += 1
        self.dispatch(now)
        out, self._reaped = self._reaped, []
        for key, eng in self.pool.engines():
            if not eng.has_work():
                continue
            entry = self.reg.entry(*key)
            for res in eng.step():
                entry.active_requests = max(0, entry.active_requests - 1)
                self.tel.record_latency(key[0], time.perf_counter(),
                                        res.latency)
                self.stats.completed += 1
                out.append((key, res))
        return out
