"""SL002 seed: per-token device->host syncs inside the decode hot path.

Every pattern here is one the transfer-guard test (PR 5) caught at
runtime: an ``.item()`` per sampled token, ``np.asarray`` on a device
array, and an ``int()`` on a device value — each forces a blocking
round-trip per decode step instead of the single designed readback.
Servelint (with this file's ``Engine._decode_once`` configured hot)
must flag all three.
"""
import jax
import numpy as np


class Engine:
    def _decode_once(self, active):
        nxt, self.cache, self._dstate = self._fused_step(
            self.params, self.cache, self._dstate)
        host = np.asarray(nxt)                # sync: np on device array
        for i in active:
            s = self._slots[i]
            tok = nxt[i].item()               # sync: per-token .item()
            s.res.new_tokens.append(tok)
        flag = self._fused_step(self.params, self.cache, self._dstate)
        done = int(flag)                      # sync: int() on device value
        return host, done
