"""SL003 seed: retrace/donation hazards around the jitted step fns.

(a) ``jax.jit`` on a cache-first step function WITHOUT donation keeps
two live copies of the KV cache in HBM every step; (b) a loop variable
in ``fused_burst``'s static position (K, ``static_argnums=(3,)``)
retraces the whole decode graph once per distinct value.  Servelint
must flag both.
"""
import jax


def _insert_impl(cache, rcache, slot):
    return cache


fns = {"insert": jax.jit(_insert_impl)}       # (a) missing donate_argnums


class Engine:
    def drain(self, params, cache, state, pending):
        for k in pending:
            # (b) loop variable in the static K position
            toks, cache, state = self.fused_burst(params, cache, state, k)
        return cache, state
