"""SL004 clean twin of ``sl004_donation_bad.py``: the donated buffers
are rebound from the call result in the same statement (the engine's
idiom), so every later read sees the live output buffer.  Servelint
must stay silent."""


class Engine:
    def step_once(self):
        nxt, self.cache, self._dstate = self.fused_step(
            self.params, self.cache, self._dstate)
        used = self.kv_bytes(self.cache)
        return nxt, used
