"""SL003 clean twin of ``sl003_retrace_bad.py``: the cache-first step
fn donates its input buffer, and the burst K is a fixed bucket hoisted
out of the loop.  Servelint must stay silent."""
import jax


def _insert_impl(cache, rcache, slot):
    return cache


fns = {"insert": jax.jit(_insert_impl, donate_argnums=(0,))}


class Engine:
    def drain(self, params, cache, state, pending):
        k = self.decode_burst                 # fixed bucket: one trace
        for _ in pending:
            toks, cache, state = self.fused_burst(params, cache, state, k)
        return cache, state
