"""SL001 clean twin of ``sl001_mixed_clock_bad.py``: the PR-6 fix —
completion telemetry stamped with the step's own resolved clock.
Servelint must stay silent."""
import time
from typing import List, Tuple


class Scheduler:
    def step(self, now: float = None) -> List[Tuple[str, object]]:
        """One serve-loop iteration over the whole pool: admit queued work,
        run ONE batched decode on every engine with work, reap finished."""
        now = time.perf_counter() if now is None else now
        self.stats.steps += 1
        self.dispatch(now)
        out, self._reaped = self._reaped, []
        for key, eng in self.pool.engines():
            if not eng.has_work():
                continue
            entry = self.reg.entry(*key)
            for res in eng.step():
                entry.active_requests = max(0, entry.active_requests - 1)
                # stamp with the step's OWN clock: mixing perf_counter
                # into a simulated `now` skewed the telemetry window
                self.tel.record_latency(key[0], now, res.latency)
                self.stats.completed += 1
                out.append((key, res))
        return out
