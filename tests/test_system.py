"""End-to-end behaviour tests for the paper's system.

Full loop: synthetic 8-benchmark corpus -> hybrid routing (keyword +
trained classifier) -> Algorithm-2 selection -> Algorithm-1 scaling in the
cluster simulator -> paper-metric report. Asserts the paper's headline
ORDERINGS (not exact numbers): multi-objective > random on success;
dynamic orchestration cheaper than static; eta > 1.
"""
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import (PROFILES, ClusterSimulator, HybridRouter,
                        KeywordRouter, MultiObjectivePolicy, RandomPolicy,
                        SemanticRouter, SimConfig, ServiceRegistry,
                        poisson_arrivals, routing_efficiency)
from repro.core.classifier import ClassifierConfig, train_classifier
from repro.data.benchmarks import generate_corpus, split

POOL = ["smollm-360m", "phi3-medium-14b", "glm4-9b",
        "command-r-plus-104b", "deepseek-v2-236b"]


@pytest.fixture(scope="module")
def trained_router():
    corpus = generate_corpus(800, seed=3)
    train, val = split(corpus, val_frac=0.15)
    cfg = ClassifierConfig(d_model=96, num_layers=1, d_ff=192, max_len=96)
    params, report = train_classifier(train, val, cfg, epochs=4, log=None)
    return SemanticRouter(params, cfg), report


def test_classifier_learns(trained_router):
    _, report = trained_router
    assert report["val_accuracy"] > 0.55     # 1-layer, 2 epochs, tiny corpus


def test_semantic_beats_keyword_on_tier_accuracy(trained_router):
    sem, _ = trained_router
    kw = KeywordRouter()
    prompts = generate_corpus(300, seed=9)
    texts = [p.text for p in prompts]
    gold = [p.complexity for p in prompts]
    acc_kw = np.mean([d.tier == g for d, g in zip(kw.route_many(texts), gold)])
    acc_sem = np.mean([d.tier == g for d, g in zip(sem.route_many(texts), gold)])
    assert acc_sem > acc_kw - 0.05      # semantic >= keyword (paper Fig. 5)


def test_hybrid_router_resolves_ambiguity(trained_router):
    sem, _ = trained_router
    hy = HybridRouter(sem)
    ds = hy.route_many(["Prove rigorously that the bound holds",
                        "sum the list", "a vague request about things"])
    assert all(d.mode == "hybrid" for d in ds)
    assert ds[0].tier == "high" and ds[1].tier == "low"


def test_full_loop_paper_orderings(trained_router):
    sem, _ = trained_router
    hy = HybridRouter(sem)
    prompts = generate_corpus(400, seed=5)
    decisions = hy.route_many([p.text for p in prompts])
    # bursty-with-idle workload (the deployment regime Table 4 measures)
    half = len(prompts) // 2
    workload = [(i * 0.25, p, d) for i, (p, d)
                in enumerate(zip(prompts[:half], decisions[:half]))]
    gap = half * 0.25 + 900.0
    workload += [(gap + i * 0.25, p, d) for i, (p, d)
                 in enumerate(zip(prompts[half:], decisions[half:]))]
    models = {k: ARCHS[k] for k in POOL}

    def run(policy_cls, static):
        reg = ServiceRegistry(models)
        sim = ClusterSimulator(reg, policy_cls(reg, seed=0),
                               PROFILES["balanced"],
                               SimConfig(seed=0, static=static))
        return sim.run(workload)

    r_rand = run(RandomPolicy, True)
    r_multi = run(MultiObjectivePolicy, True)
    r_dyn = run(MultiObjectivePolicy, False)

    # Table 3 ordering
    assert r_multi.success_rate() > r_rand.success_rate()
    # Table 4 ordering (dynamic cheaper when idle exists)
    assert r_dyn.usd_total < r_multi.usd_total
    # Eq. 9 efficiency > 1 (accuracy per unit attributed cost improves)
    eta = routing_efficiency(
        r_multi.success_rate(), r_rand.success_rate(),
        max(r_multi.attributed_cost_per_query(), 1e-9),
        max(r_rand.attributed_cost_per_query(), 1e-9))
    assert eta > 1.0


def test_report_metrics_well_formed(trained_router):
    prompts = generate_corpus(120, seed=6)
    decisions = KeywordRouter().route_many([p.text for p in prompts])
    arr = poisson_arrivals(prompts, 6.0, seed=6)
    reg = ServiceRegistry({k: ARCHS[k] for k in POOL})
    sim = ClusterSimulator(reg, MultiObjectivePolicy(reg, seed=0),
                           PROFILES["speed"], SimConfig(seed=0))
    rep = sim.run([(t, p, d) for (t, p), d in zip(arr, decisions)])
    s = rep.summary()
    assert 0.0 <= s["success_rate"] <= 1.0
    assert s["ttft_p50"] <= s["ttft_p95"] <= s["ttft_p99"]
    assert s["cost_per_query_usd"] >= 0
    assert 0.0 <= s["gpu_utilization"] <= 1.0
    assert s["attr_cost_per_query_usd"] >= 0
