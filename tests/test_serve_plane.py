"""Concurrent serve plane: scheduler, replica pool, live Spin control loop.

All tests run against REAL engines (reduced smollm on CPU) through the
full serving-API-v2 path: Router -> Algorithm-2 policy -> priority
bounded queues -> replica pool, with Algorithm-1 scaling applied to live
engines. ``submit()`` returns a ``CompletionHandle``; shed requests
resolve with a structured ``finish_reason == "shed"``.
"""
import time

import pytest

from conftest import reduced_f32
from repro.core.gateway import ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.scoring import PROFILES

SMOL = "smollm-360m"
KEY = (SMOL, "trt")


@pytest.fixture(scope="module")
def agw():
    # tick_s huge: tests drive Orchestrator.tick explicitly, so the serve
    # loop's inline ticks can't interfere with queue/slot assertions
    spin = SpinConfig(window_s=20.0, cooldown_s=0.0, idle_tau_s=0.5,
                      tick_s=3600.0, max_replicas=2,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    return ServeFrontend({SMOL: reduced_f32(SMOL)},
                         profile=PROFILES["balanced"], max_seq=96, spin=spin)


def test_concurrent_requests_interleave(agw):
    a = agw.submit("add the numbers now please", max_new_tokens=24)
    b = agw.submit("count the items quickly", max_new_tokens=4)
    agw.serve_all()
    ra, rb = a.response, b.response
    assert ra.completed and len(ra.new_tokens) == 24
    assert rb.completed and len(rb.new_tokens) == 4
    # B entered the batch while A was still decoding: its first token
    # landed (and it finished) before A's total latency elapsed — a
    # serial plane would give B ttft >= A's full latency
    assert rb.ttft_s < ra.latency_s
    assert rb.latency_s < ra.latency_s


def test_bounded_queue_sheds_when_saturated(agw):
    agw.serve_all()
    depth0 = agw.scheduler.cfg.max_queue_depth
    agw.scheduler.cfg.max_queue_depth = 2
    try:
        # 1 replica x 4 trt slots + depth 2 => 12 submissions can't all fit
        handles = [agw.submit(f"sum the numbers {i}", max_new_tokens=4)
                   for i in range(12)]
        shed = sum(h.shed for h in handles)
        assert shed >= 1
        # equal priority: nothing to evict, arrivals are rejected with a
        # structured shed response at submit time
        assert all(h.response.finish_reason == "shed"
                   for h in handles if h.shed)
        assert agw.scheduler.stats.shed >= shed
        assert len(agw.scheduler._queues[KEY]) <= 2
        assert agw.registry.entry(*KEY).queued <= 2
        agw.serve_all()
        assert all(h.response.completed for h in handles if not h.shed)
    finally:
        agw.scheduler.cfg.max_queue_depth = depth0


def test_scale_to_zero_then_warm_respin(agw):
    agw.serve_all()
    pool = agw.pool
    assert len(pool.replicas(*KEY)) >= 1
    cold_durs = [e.duration_s for e in pool.events if e.kind == "spin-cold"]
    assert cold_durs
    pool.scale(*KEY, 0)
    assert agw.registry.entry(*KEY).replicas == 0
    assert agw.registry.entry(*KEY).warm == 1       # params stayed resident
    assert pool.has_params(SMOL)
    assert pool.events[-1].kind == "zero"
    pool.scale(*KEY, 1)
    ev = pool.events[-1]
    assert ev.kind == "spin-warm"
    # warm re-spin reuses cached params + compiled step functions
    assert ev.duration_s < min(cold_durs)
    h = agw.submit("sum the list", max_new_tokens=2)
    agw.serve_all()
    assert h.response.completed


def test_cold_start_attributed_to_waiting_request(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 0)                         # force a respin
    h = agw.submit("sum the numbers", max_new_tokens=2)
    agw.serve_all()
    spin = agw.pool.cold_starts[-1]
    assert spin[0].startswith(f"{SMOL}/trt/")
    # the measured spin time this request waited on lands in its usage
    assert h.response.usage.cold_start_s == pytest.approx(spin[1])
    # a follow-up served by the now-live replica pays nothing
    h2 = agw.submit("sum the numbers again", max_new_tokens=2)
    agw.serve_all()
    assert h2.response.usage.cold_start_s == 0.0


def test_orchestrator_adds_replica_under_load(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 1)
    now = time.perf_counter()
    # hot telemetry: 40 rps x 2 s latency >> one replica's 4 slots, so
    # Little's law wants more capacity than one engine provides
    for i in range(200):
        t = now - 5.0 + i * 0.025
        agw.telemetry.record_request(SMOL, t)
        agw.telemetry.record_latency(SMOL, t, 2.0)
    before = len(agw.pool.replicas(*KEY))
    decisions = agw.orch.tick(time.perf_counter())
    assert decisions.get(SMOL, 0) >= 2              # Alg. 1 asked for more
    assert len(agw.pool.replicas(*KEY)) == agw.spin.max_replicas > before
    # the added replicas are LIVE: a burst larger than one engine's slot
    # count is absorbed without queue residue
    handles = [agw.submit(f"count items {i}", max_new_tokens=2)
               for i in range(6)]
    agw.serve_all()
    assert all(h.response.completed for h in handles)


def test_orchestrator_scales_to_zero_when_idle(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 1)
    # age out any hot request/latency telemetry a prior test injected —
    # a live window would keep Alg. 1 in its scale-up branch
    agw.telemetry._requests[SMOL].clear()
    agw.telemetry._latency[SMOL].clear()
    time.sleep(agw.spin.idle_tau_s + 0.2)           # no arrivals -> idle
    decisions = agw.orch.tick(time.perf_counter())
    assert decisions.get(SMOL) == 0
    assert len(agw.pool.replicas(*KEY)) == 0
    assert agw.pool.has_params(SMOL)                # warm pool survives
    # next request re-spins from the warm caches and completes
    h = agw.submit("sum the numbers", max_new_tokens=2)
    agw.serve_all()
    assert h.response.completed
    assert agw.pool.events[-1].kind == "spin-warm"


def test_expired_queued_requests_are_dropped(agw):
    agw.serve_all()
    agw.pool.scale(*KEY, 1)                         # exactly 4 trt slots
    # saturate the engine slots, then queue one request with a deadline
    # that expires while it waits: it must be reaped as timed_out without
    # ever occupying a slot
    blockers = [agw.submit(f"sum the items {i}", max_new_tokens=24)
                for i in range(4)]
    doomed = agw.submit("count this", max_new_tokens=4, deadline_s=1e-6)
    assert not doomed.done()                        # admitted, queued
    agw.serve_all()
    r = doomed.response
    assert r is not None and not r.completed
    assert r.finish_reason == "timeout"
    assert agw.scheduler.stats.expired >= 1
    assert all(h.response.completed for h in blockers)
