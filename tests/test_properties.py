"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.router import CAPABILITY, KeywordRouter, RouteDecision, relevance
from repro.core.scoring import (MinMaxNormalizer, OperatorProfile,
                                orchestration_score)
from repro.data.tokenizer import ByteTokenizer

pos_float = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False)


@given(alpha=pos_float, lam=pos_float, mu=pos_float,
       rel=st.floats(0, 1), lat=st.floats(0, 1e4), cost=st.floats(0, 1.0))
@settings(max_examples=200, deadline=None)
def test_score_is_convex_combination(alpha, lam, mu, rel, lat, cost):
    """Paper's guarantee: f in [0,1] for ANY non-negative preferences and
    any normalized inputs — weights always sum to 1."""
    prof = OperatorProfile("t", alpha, lam, mu)
    w = prof.weights
    assert abs(sum(w) - 1.0) < 1e-9
    tn, cn = MinMaxNormalizer(0, 1e4), MinMaxNormalizer(0, 1.0)
    f = orchestration_score(rel, lat, cost, prof, tn, cn)
    assert 0.0 <= f <= 1.0


@given(values=st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1,
                       max_size=50),
       probe=st.floats(-1e6, 1e6, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_normalizer_bounds(values, probe):
    n = MinMaxNormalizer(values[0], values[0])
    n.update_many(values)
    assert 0.0 <= n.norm(probe) <= 1.0
    for v in values:     # observed values stay in bounds
        assert 0.0 <= n.norm(v) <= 1.0


@given(text=st.text(max_size=300))
@settings(max_examples=200, deadline=None)
def test_keyword_router_total_and_deterministic(text):
    r = KeywordRouter()
    d1, d2 = r.route(text), r.route(text)
    assert d1.tier == d2.tier and d1.tier in ("low", "medium", "high")
    assert abs(sum(d1.probs.values()) - 1.0) < 1e-9
    for mt in CAPABILITY:
        assert 0.0 <= relevance(d1, mt) <= 1.0


@given(text=st.text(max_size=200))
@settings(max_examples=100, deadline=None)
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


@given(b=st.integers(1, 4), s=st.integers(2, 16), d=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_dyn_write_matches_numpy(b, s, d, seed):
    """Ragged cache writes == per-row numpy assignment."""
    from repro.models.attention import dyn_write
    rng = np.random.RandomState(seed)
    cache = rng.randn(b, s, d).astype(np.float32)
    new = rng.randn(b, 1, d).astype(np.float32)
    pos = rng.randint(0, s, size=(b,)).astype(np.int32)
    got = np.asarray(dyn_write(jnp.asarray(cache), jnp.asarray(new),
                               jnp.asarray(pos)))
    want = cache.copy()
    for i in range(b):
        want[i, pos[i]] = new[i, 0]
    np.testing.assert_allclose(got, want)


@given(t=st.integers(2, 32), e=st.integers(2, 8), k=st.integers(1, 3),
       seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_moe_combine_weights_conserved(t, e, k, seed):
    """Top-k combine weights renormalize to 1 per token; with no-drop
    capacity the dispatched mass equals the routed mass (nothing lost)."""
    import dataclasses
    from repro.configs.base import ModelConfig
    from repro.models.moe import init_moe, moe_ffn
    k = min(k, e)
    cfg = ModelConfig(name="t", family="moe", d_model=16, num_experts=e,
                      experts_per_token=k, moe_d_ff=8, num_shared_experts=0,
                      act="silu")
    params = init_moe(cfg, jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.RandomState(seed).randn(1, t, 16), jnp.float32)
    out, aux = moe_ffn(params, cfg, x, capacity_factor=None)
    assert out.shape == (1, t, 16)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.99  # E * sum f_e p_e >= 1 by Cauchy-Schwarz


@given(seq=st.integers(1, 40), window=st.integers(4, 16),
       seed=st.integers(0, 500))
@settings(max_examples=30, deadline=None)
def test_ring_cache_keeps_last_window(seq, window, seed):
    """After prefill, the ring cache contains exactly the last
    min(seq, window) keys at slots pos % window."""
    import dataclasses
    from repro.configs.base import ModelConfig
    from repro.models.attention import gqa_prefill, init_gqa
    from repro.models.common import rope_cos_sin
    cfg = ModelConfig(num_heads=2, num_kv_heads=2, head_dim=8, d_model=16,
                      sliding_window=window)
    params = init_gqa(cfg, jax.random.PRNGKey(seed))
    x = jnp.asarray(np.random.RandomState(seed).randn(1, seq, 16), jnp.float32)
    cos, sin = rope_cos_sin(jnp.arange(seq)[None], 8, 1e4)
    _, cache = gqa_prefill(params, cfg, x, cos, sin, cache_len=window,
                           q_chunk=8)
    assert cache["k"].shape[1] == window
    live = min(seq, window)
    # recompute keys directly and compare the ring slots
    from repro.models.attention import _proj_qkv
    from repro.models.common import apply_rope
    _, k, _ = _proj_qkv(params, cfg, x)
    k = apply_rope(k, cos, sin)
    for tpos in range(seq - live, seq):
        slot = tpos % window
        np.testing.assert_allclose(np.asarray(cache["k"][0, slot]),
                                   np.asarray(k[0, tpos]), atol=1e-5)
