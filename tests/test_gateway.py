"""Real (non-simulated) Pick-and-Spin path: route -> spin up -> serve.

Measures genuine cold starts (XLA compile) vs warm starts — the
calibration the simulator's constants reference.
"""
import pytest

from conftest import reduced_f32
from repro.core.gateway import Gateway
from repro.core.scoring import PROFILES


@pytest.fixture(scope="module")
def gateway():
    models = {
        "smollm-360m": reduced_f32("smollm-360m"),
        "phi3-medium-14b": reduced_f32("phi3-medium-14b"),
        "command-r-plus-104b": reduced_f32("command-r-plus-104b"),
    }
    return Gateway(models, profile=PROFILES["balanced"], max_seq=96)


def test_routes_and_serves(gateway):
    r = gateway.handle("List the sum of these numbers briefly", max_new_tokens=4)
    assert r.completed and len(r.new_tokens) == 4
    assert r.model in gateway.models
    assert r.latency_s > 0

    r2 = gateway.handle("Prove the theorem step by step rigorously",
                        max_new_tokens=4)
    assert r2.completed
    # quality routing sends reasoning-heavy prompts to a bigger tier
    tiers = {"small": 0, "medium": 1, "large": 2}
    assert tiers[r2.tier] >= tiers[r.tier]


def test_warm_start_faster_than_cold(gateway):
    # first request to a model pays compile; the same (model, backend)
    # afterwards is an already-running engine (cold_start 0)
    r1 = gateway.handle("define the list sum", max_new_tokens=2)
    r2 = gateway.handle("define the list count", max_new_tokens=2)
    if r1.model == r2.model:
        assert r2.cold_start_s == 0.0


def test_scale_to_zero_and_warm_restart(gateway):
    r = gateway.handle("sum the list", max_new_tokens=2)
    m, b = r.model, r.backend
    gateway.scale_to_zero(m, b, keep_warm=True)
    assert gateway.registry.entry(m, b).replicas == 0
    r2 = gateway.handle("sum the list again", max_new_tokens=2)
    assert r2.completed
    # warm restart (params cached) must beat the true cold start
    colds = [c for n, c in gateway.cold_starts if n.endswith("/cold")
             and n.startswith(m)]
    warms = [c for n, c in gateway.cold_starts if n.endswith("/warm")
             and n.startswith(m)]
    if colds and warms:
        assert min(warms) < max(colds)
