"""Roofline module unit tests: HLO collective parser + analytic models."""
import textwrap

from repro.roofline.analysis import (Roofline, analytic_memory_bytes,
                                     analytic_model_flops, parse_collectives,
                                     _shape_bytes)

HLO = textwrap.dedent("""\
    HloModule jit_step, is_scheduled=true

    %wide.body.1 (arg: (f32[8,16])) -> (f32[8,16]) {
      %p = f32[8,16]{1,0} parameter(0)
      %ag = f32[8,16]{1,0} all-gather(%p), dimensions={0}
      ROOT %t = (f32[8,16]{1,0}) tuple(%ag)
    }

    %wide.cond.1 (arg: (f32[8,16])) -> pred[] {
      %c = s32[] constant(5)
      ROOT %lt = pred[] compare(%c, %c), direction=LT
    }

    ENTRY %main.1 (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %ar = f32[8,16]{1,0} all-reduce(%a), to_apply=%add
      %w = (f32[8,16]{1,0}) while(%ar), condition=%wide.cond.1, body=%wide.body.1
      %aa = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %a)
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=0
    }
""")


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4,4]{1,0}, bf16[2,2]{1,0})") == 64 + 8
    assert _shape_bytes("pred[]") == 1


def test_parse_collectives_with_loop_trips():
    st = parse_collectives(HLO)
    # all-reduce once (512B), all-to-all once (2x64B), all-gather x5 trips
    assert st.count_by_op["all-reduce"] == 1
    assert st.count_by_op["all-to-all"] == 1
    assert st.count_by_op["all-gather"] == 5
    assert st.bytes_by_op["all-gather"] == 5 * 8 * 16 * 4
    assert st.bytes_by_op["all-reduce"] == 8 * 16 * 4
    assert st.bytes_by_op["all-to-all"] == 2 * 4 * 4 * 4


def test_roofline_terms_and_dominance():
    r = Roofline(arch="a", shape="train_4k", mesh="m", chips=256,
                 hlo_flops=256 * 197e12,           # exactly 1 s compute
                 hlo_bytes=256 * 819e9 * 0.5,      # 0.5 s memory
                 collective_bytes=256 * 50e9 * 0.1,
                 model_flops=256 * 197e12, scan_corrected=False)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert r.dominant == "compute"
    assert abs(r.useful_flops_frac - 1.0) < 1e-9


def test_analytic_models_scale_sensibly():
    f_train = analytic_model_flops(1e9, "train", 1000)
    f_serve = analytic_model_flops(1e9, "decode", 1000)
    assert f_train == 3 * f_serve            # 6ND vs 2ND
    m_dec = analytic_memory_bytes(2e9, 1e9, "decode", 128, 1024, 32,
                                  cache_bytes=5e9)
    assert m_dec >= 2e9 + 5e9                # weights + cache at least
    m_train = analytic_memory_bytes(1e9, 1e9, "train", 10000, 1024, 32)
    assert m_train > 8 * 1e9                 # params+grads+opt f32 traffic
