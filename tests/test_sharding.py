"""Sharding rule engine: divisibility pruning + per-arch spec coverage.

Uses AbstractMesh so the production (16, 16) topology is testable on a
1-device host without touching jax device state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dep: property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import AbstractMesh, PartitionSpec as P  # noqa: E402

from repro.configs.registry import ARCHS, get_config_for_shape
from repro.distributed.sharding import (PARAM_RULES, prune_spec,
                                        spec_for_param)
from repro.launch.specs import param_specs

MESH = AbstractMesh((16, 16), ("data", "model"))
MESH3 = AbstractMesh((2, 16, 16), ("pod", "data", "model"))


@given(dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       axes=st.lists(st.sampled_from([None, "data", "model", "pod", "bogus"]),
                     min_size=1, max_size=4))
@settings(max_examples=300, deadline=None)
def test_prune_spec_invariants(dims, axes):
    """Pruned specs only use each mesh axis once and always divide."""
    n = min(len(dims), len(axes))
    spec = prune_spec(tuple(dims[:n]), tuple(axes[:n]), MESH3)
    used = []
    for dim, ax in zip(dims, spec):
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            assert a in MESH3.shape
            assert dim % MESH3.shape[a] == 0
            used.append(a)
    assert len(used) == len(set(used))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_every_param_gets_a_valid_spec(arch):
    cfg = ARCHS[arch]
    psds = param_specs(cfg)

    def check(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in path)
        spec = spec_for_param(pstr, tuple(leaf.shape), MESH)
        assert len(spec) <= len(leaf.shape)
        used = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                assert dim % MESH.shape[a] == 0, (pstr, leaf.shape, spec)
                used.append(a)
        assert len(used) == len(set(used)), (pstr, spec)
    jax.tree_util.tree_map_with_path(check, psds)


@pytest.mark.parametrize("arch", ["command-r-plus-104b", "deepseek-v2-236b",
                                  "mamba2-2.7b"])
def test_big_matrices_are_model_sharded(arch):
    """The parallel dim of every large matrix must actually shard (memory)."""
    cfg = ARCHS[arch]
    psds = param_specs(cfg)

    def check(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in path)
        if int(np.prod(leaf.shape)) < 10_000_000:
            return
        spec = spec_for_param(pstr, tuple(leaf.shape), MESH)
        assert any(ax is not None for ax in spec), \
            f"large param {pstr} {leaf.shape} fully replicated"
    jax.tree_util.tree_map_with_path(check, psds)


def test_moe_experts_sharded_over_model():
    cfg = ARCHS["deepseek-v2-236b"]
    spec = spec_for_param("layers/ffn/w_gate", (160, 5120, 1536), MESH)
    assert spec[0] == "model"        # expert parallelism
    spec_d = spec_for_param("layers/ffn/w_down", (160, 1536, 5120), MESH)
    assert spec_d[0] == "model"


def test_kv_head_fallback_to_seq():
    """kv heads that don't divide the model axis fall back to sequence
    sharding of the cache (context-parallel decode)."""
    from repro.distributed.sharding import cache_shardings
    from repro.launch.specs import cache_specs_tree
    cfg = get_config_for_shape("command-r-plus-104b", "decode_32k")  # kv=8
    tree = cache_specs_tree(cfg, 128, 32768)
    shards = cache_shardings(cfg, tree, MESH, 128)
    kspec = shards["stack"]["k"].spec
    # (L, B, S, H, D): batch over data; heads(8) can't take model(16)
    assert kspec[1] == "data"
    assert kspec[2] == "model" or kspec[3] is None


def test_long_context_batch1_context_parallel():
    from repro.distributed.sharding import cache_shardings
    from repro.launch.specs import cache_specs_tree
    cfg = get_config_for_shape("phi3-medium-14b", "long_500k")
    assert cfg.sliding_window == 8192
    tree = cache_specs_tree(cfg, 1, 524288)
    shards = cache_shardings(cfg, tree, MESH, 1)
    kspec = shards["stack"]["k"].spec
    assert kspec[1] is None                     # batch=1 unsharded
    assert kspec[2] is not None                 # seq takes the data axis


def test_multipod_batch_axes():
    from repro.distributed.sharding import batch_shardings
    cfg = ARCHS["smollm-360m"]
    tree = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    sh = batch_shardings(cfg, tree, MESH3)
    spec = sh["tokens"].spec
    flat = []
    for ax in spec:
        if ax:
            flat.extend(ax if isinstance(ax, tuple) else [ax])
    assert "pod" in flat and "data" in flat
