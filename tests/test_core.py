"""Pick-and-Spin control-plane behaviour tests (Alg. 1, Alg. 2, telemetry)."""
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core import (PROFILES, ClusterSimulator, KeywordRouter,
                        LatencyOnlyPolicy, MultiObjectivePolicy, Orchestrator,
                        RandomPolicy, ServiceRegistry, SimConfig, SpinConfig,
                        Telemetry, poisson_arrivals)
from repro.data.benchmarks import generate_corpus

POOL = ["smollm-360m", "phi3-medium-14b", "glm4-9b", "command-r-plus-104b",
        "deepseek-v2-236b"]


def _models(names=POOL):
    return {k: ARCHS[k] for k in names}


# ---------------------------------------------------------------------------
# telemetry


def test_telemetry_window_and_rate():
    tel = Telemetry(window_s=10.0)
    for t in range(20):
        tel.record_request("m", float(t))
    # only the last 10 s of requests count
    assert tel.request_rate("m", 20.0) == pytest.approx(1.0, rel=0.3)
    tel.record_latency("m", 20.0, 2.0)
    tel.record_latency("m", 20.0, 4.0)
    assert tel.avg_latency("m", 20.0) == pytest.approx(3.0)
    # idle time counts from the last REQUEST (t=19), not latency reports
    assert tel.idle_time("m", 25.0) == pytest.approx(6.0)


# ---------------------------------------------------------------------------
# Algorithm 1


def _orch(scale_to_zero=True, cooldown=0.0):
    reg = ServiceRegistry(_models(["smollm-360m", "phi3-medium-14b"]))
    tel = Telemetry(window_s=60.0)
    cfg = SpinConfig(cooldown_s=cooldown, idle_tau_s=30.0,
                     scale_to_zero=scale_to_zero, tick_s=5.0)
    return reg, tel, Orchestrator(reg, tel, cfg)


def test_alg1_scales_up_under_load():
    reg, tel, orch = _orch()
    # burst: 50 req/s with 2 s latency -> Little's law target = ceil(100/16)
    for i in range(500):
        tel.record_request("smollm-360m", 50.0 + i * 0.02)
        tel.record_latency("smollm-360m", 50.0 + i * 0.02, 2.0)
    dec = orch.tick(60.0)
    assert reg.model_replicas("smollm-360m") >= 2
    assert "smollm-360m" in dec


def test_alg1_scale_to_zero_when_idle():
    reg, tel, orch = _orch()
    tel.record_request("phi3-medium-14b", 0.0)
    orch.tick(1.0)
    # large idle gap -> scaled to the warm floor (warm pool medium = 1)
    dec = orch.tick(500.0)
    assert reg.model_replicas("phi3-medium-14b") <= 1
    # a model never requested scales to zero floor
    assert reg.model_replicas("smollm-360m") <= 1


def test_alg1_cooldown_blocks_flapping():
    reg, tel, orch = _orch(cooldown=100.0)
    for i in range(300):
        tel.record_request("smollm-360m", float(i) * 0.01)
        tel.record_latency("smollm-360m", float(i) * 0.01, 5.0)
    orch.tick(5.0)
    r1 = reg.model_replicas("smollm-360m")
    for i in range(600):
        tel.record_request("smollm-360m", 5.0 + i * 0.01)
        tel.record_latency("smollm-360m", 5.0 + i * 0.01, 50.0)
    orch.tick(10.0)   # inside cooldown -> no further scale-up
    assert reg.model_replicas("smollm-360m") == r1


def test_alg1_active_set():
    reg, tel, orch = _orch()
    assert orch.active_models() == set()
    reg.entry("smollm-360m", "trt").replicas = 1
    assert orch.active_models() == {"smollm-360m"}


# ---------------------------------------------------------------------------
# Algorithm 2 selection


def test_multi_objective_prefers_tier_match_on_quality():
    reg = ServiceRegistry(_models())
    for e in reg.entries():
        e.replicas = 1
    pol = MultiObjectivePolicy(reg, seed=0)
    router = KeywordRouter()
    hi = router.route("Prove the theorem step by step and derive bounds")
    lo = router.route("List the sum of these numbers")
    sel_hi = pol.select(hi, 64, 128, PROFILES["quality"])
    sel_lo = pol.select(lo, 16, 16, PROFILES["cost"])
    assert sel_hi.entry.tier == "large"
    assert sel_lo.entry.tier in ("small", "medium")
    assert 0.0 <= sel_hi.score <= 1.0


def test_cost_profile_prefers_cheaper_than_quality():
    reg = ServiceRegistry(_models())
    for e in reg.entries():
        e.replicas = 1
    router = KeywordRouter()
    d = router.route("a generic medium request about the dataset")
    cost_sel = MultiObjectivePolicy(reg, seed=0).select(d, 64, 64, PROFILES["cost"])
    qual_sel = MultiObjectivePolicy(reg, seed=0).select(d, 64, 64, PROFILES["quality"])
    assert cost_sel.pred_cost <= qual_sel.pred_cost + 1e-9


# ---------------------------------------------------------------------------
# simulator end-to-end trends (the paper's headline orderings)


def _run(policy_cls, prompts, decisions, static=False, rate=4.0, seed=0):
    reg = ServiceRegistry(_models())
    sim = ClusterSimulator(reg, policy_cls(reg, seed=0), PROFILES["balanced"],
                           SimConfig(seed=seed, static=static))
    arr = poisson_arrivals(prompts, rate, seed=seed)
    return sim.run([(t, p, d) for (t, p), d in zip(arr, decisions)])


@pytest.fixture(scope="module")
def corpus():
    prompts = generate_corpus(400, seed=0)
    decisions = KeywordRouter().route_many([p.text for p in prompts])
    return prompts, decisions


def test_all_requests_accounted(corpus):
    prompts, decisions = corpus
    rep = _run(MultiObjectivePolicy, prompts, decisions)
    assert len(rep.requests) == len(prompts)
    for r in rep.requests:
        assert r.timed_out or r.finish >= r.arrival


def test_multi_objective_beats_random_on_success(corpus):
    prompts, decisions = corpus
    r_rand = _run(RandomPolicy, prompts, decisions, static=True)
    r_multi = _run(MultiObjectivePolicy, prompts, decisions, static=True)
    assert r_multi.success_rate() > r_rand.success_rate() + 0.02


def test_latency_only_is_fast_but_less_accurate(corpus):
    prompts, decisions = corpus
    r_lat = _run(LatencyOnlyPolicy, prompts, decisions, static=True)
    r_multi = _run(MultiObjectivePolicy, prompts, decisions, static=True)
    assert r_lat.mean_latency() <= r_multi.mean_latency() * 1.5
    assert r_multi.success_rate() >= r_lat.success_rate() - 0.02


def test_dynamic_cheaper_than_static_with_idle(corpus):
    """The paper's cost win comes from scale-to-zero during idle: a bursty
    workload with a long gap (the regime Table 4 targets). A short
    saturated burst is static's best case and is NOT the claim."""
    prompts, decisions = corpus
    reg_kwargs = {}
    arr = []
    half = len(prompts) // 2
    arr += [(i * 0.25, p, d) for i, (p, d)
            in enumerate(zip(prompts[:half], decisions[:half]))]
    gap = half * 0.25 + 900.0
    arr += [(gap + i * 0.25, p, d) for i, (p, d)
            in enumerate(zip(prompts[half:], decisions[half:]))]
    from repro.core import SimConfig
    reg_s = ServiceRegistry(_models())
    r_static = ClusterSimulator(reg_s, MultiObjectivePolicy(reg_s, seed=0),
                                PROFILES["balanced"],
                                SimConfig(seed=0, static=True)).run(arr)
    reg_d = ServiceRegistry(_models())
    r_dyn = ClusterSimulator(reg_d, MultiObjectivePolicy(reg_d, seed=0),
                             PROFILES["balanced"],
                             SimConfig(seed=0, static=False)).run(arr)
    assert r_dyn.usd_total < r_static.usd_total
