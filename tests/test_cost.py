"""Cost & capacity attribution plane: chip-second ledger, HBM/KV byte
accounting, and the anomaly flight recorder.

Two tiers of tests:

  * pure host-side units (no jax): ledger interval chaining, the
    shared-batch attribution split, the conservation identity on a
    hand-built timeline, and the flight recorder's triggers/cooldown;
  * live-plane integration (real reduced engines through the full
    ``ServeFrontend`` path): every completed response carries a metered
    ``Usage.chip_seconds``/``cost_usd``, the pool-wide conservation
    invariant holds within 1%, resident-memory gauges are grounded in
    real array bytes, and an induced shed storm lands an automatic
    flight dump (schema-valid JSONL) without being asked.
"""
import json

import pytest

from conftest import reduced_f32
from repro.core.costmodel import USD_PER_CHIP_HOUR, chip_seconds_usd
from repro.core.gateway import ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.scoring import PROFILES
from repro.obs import (CostLedger, EventLog, FlightConfig, FlightRecorder,
                       MetricsRegistry, Observability, dtype_nbytes,
                       param_bytes)

SMOL = "smollm-360m"
KEY = (SMOL, "trt")


# ---------------------------------------------------------------------------
# ledger units: attribution math on a hand-built timeline (no jax)


def _ledger(registry=None, rate=3600.0):
    # rate 3600 $/chip-hour => 1 $/chip-second: costs readable by eye
    return CostLedger(registry=registry, usd_per_chip_hour=rate)


def test_step_attribution_splits_evenly_across_batch():
    led = _ledger()
    m = led.replica_up("m", "trt", chips=2, cold_s=1.0, t=0.0)
    led.on_step(m, 0.0, 1.0, [1, 2])       # 1s x 2 chips shared by 2 uids
    assert m.busy_chip_s == pytest.approx(2.0)
    assert led._live == {1: pytest.approx(1.0), 2: pytest.approx(1.0)}
    led.on_step(m, 2.0, 3.0, [1])          # gap [1,2] is idle; uid 1 solo
    assert m.idle_chip_s == pytest.approx(2.0)
    assert led.attributed_chip_s == pytest.approx(4.0)
    chip_s, usd = led.close_request(1, "m")
    assert chip_s == pytest.approx(3.0)
    assert usd == pytest.approx(3.0)                  # 1 $/chip-second
    assert led.close_request(99, "m") is None         # never ran a step
    assert led.cost_per_query_usd("m") == pytest.approx(3.0)


def test_empty_step_accrues_idle_not_busy():
    led = _ledger()
    m = led.replica_up("m", "trt", chips=1, cold_s=0.0, t=0.0)
    led.on_step(m, 0.0, 0.5, [])
    assert m.busy_chip_s == 0.0 and m.idle_chip_s == pytest.approx(0.5)
    assert led.attributed_chip_s == 0.0


def test_conservation_identity_exact_on_hand_timeline():
    led = _ledger()
    m = led.replica_up("m", "trt", chips=2, cold_s=1.0, t=0.0)
    led.on_step(m, 0.0, 1.0, [1, 2])
    led.on_step(m, 2.0, 3.0, [1])
    t = led.totals(now=5.0)
    # total recomputed from lifetime stamps: (5-0 + cold 1.0) x 2 chips
    assert t["total_chip_s"] == pytest.approx(12.0)
    assert t["cold_chip_s"] == pytest.approx(2.0)
    # idle = inter-step gap (2 chip-s) + pending tail [3,5] (4 chip-s)
    assert t["idle_chip_s"] == pytest.approx(6.0)
    assert led.conservation_error(now=5.0) == pytest.approx(0.0, abs=1e-12)


def test_replica_down_closes_tail_idempotently():
    led = _ledger()
    m = led.replica_up("m", "trt", chips=1, cold_s=0.0, t=0.0)
    led.on_step(m, 0.0, 1.0, [7])
    led.replica_down(m, 4.0)
    assert m.down_t == 4.0
    assert m.idle_chip_s == pytest.approx(3.0)        # tail [1,4]
    led.replica_down(m, 9.0)                          # no-op: already down
    assert m.down_t == 4.0 and m.idle_chip_s == pytest.approx(3.0)
    # retired replicas stop accruing in totals() regardless of `now`
    assert led.conservation_error(now=100.0) == pytest.approx(0.0, abs=1e-12)


def test_totals_default_now_stays_in_ledger_domain():
    """Regression (servelint SL001 audit): ``totals(now=None)`` used to
    fall back to ``time.perf_counter()``, injecting a huge phantom idle
    tail into simulated-clock ledgers.  The fallback is now the newest
    stamp the ledger itself observed, so the no-arg form stays in
    whatever time domain the callers stamp with."""
    led = _ledger()
    m = led.replica_up("m", "trt", chips=1, cold_s=0.0, t=0.0)
    led.on_step(m, 0.0, 1.0, [1])
    led.on_step(m, 2.0, 3.0, [1])
    t = led.totals()                       # no `now`: sim domain preserved
    assert t["total_chip_s"] == pytest.approx(3.0)    # end == newest mark
    assert t["idle_chip_s"] == pytest.approx(1.0)     # gap [1,2] only
    assert led.conservation_error() == pytest.approx(0.0, abs=1e-12)
    led.replica_down(m, 4.0)
    assert led.totals()["total_chip_s"] == pytest.approx(4.0)  # down stamp


def test_close_request_publishes_registry_metrics():
    reg = MetricsRegistry()
    led = _ledger(registry=reg)
    m = led.replica_up("m", "trt", chips=1, cold_s=0.0, t=0.0)
    led.on_step(m, 0.0, 2.0, [1])
    led.on_step(m, 2.0, 4.0, [2])
    led.close_request(1, "m", t=4.0)
    led.close_request(2, "m", t=4.0)
    assert reg.value("cost_per_query_usd", "m") == pytest.approx(2.0)
    assert reg.histogram("request_chip_seconds", "m").count == 2


def test_usd_conversion_matches_costmodel():
    led = CostLedger(registry=None)                  # pick up the real rate
    m = led.replica_up("m", "trt", chips=1, cold_s=0.0, t=0.0)
    led.on_step(m, 0.0, 7.2, [1])
    _, usd = led.close_request(1, "m")
    assert usd == pytest.approx(chip_seconds_usd(7.2))
    assert usd == pytest.approx(7.2 * USD_PER_CHIP_HOUR / 3600.0)


def test_param_bytes_from_config_accounting():
    cfg = reduced_f32(SMOL)
    assert dtype_nbytes("float32") == 4 and dtype_nbytes("int8") == 1
    assert param_bytes(cfg) == cfg.param_count() * 4
    # narrower resident dtype -> proportionally smaller footprint
    import dataclasses
    assert param_bytes(dataclasses.replace(cfg, dtype="bfloat16")) \
        == cfg.param_count() * 2


# ---------------------------------------------------------------------------
# flight recorder units


def test_shed_storm_trigger_and_cooldown(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    events = EventLog()
    fl = FlightRecorder(FlightConfig(min_admissions=8, shed_rate=0.5,
                                     cooldown_s=100.0, path=path),
                        events=events)
    fl.record_step("m", t=0.5, active=3, pending_tokens=12)
    events.append("shed", t=0.9, model="m", uid=1)
    for i in range(7):
        fl.note_admission(shed=True, t=1.0 + i * 0.01)
    assert not fl.dumps                       # below min_admissions
    fl.note_admission(shed=True, t=2.0)       # 8/8 shed -> storm
    assert len(fl.dumps) == 1
    assert fl.dumps[0]["reason"] == "shed_storm"
    assert fl.dumps[0]["shed_rate"] == pytest.approx(1.0)
    # window cleared on dump + cooldown: an immediate repeat is silent
    for i in range(8):
        fl.note_admission(shed=True, t=2.1 + i * 0.01)
    assert len(fl.dumps) == 1
    # the JSONL sink holds the dump header, the ring, and the event tail
    recs = [json.loads(ln) for ln in open(path)]
    kinds = [r["record"] for r in recs]
    assert kinds == ["dump", "step", "event"]
    assert recs[1]["active"] == 3 and recs[2]["event"] == "shed"


def test_expiry_burst_trigger_windowed():
    fl = FlightRecorder(FlightConfig(expiry_burst=3, expiry_window_s=1.0,
                                     cooldown_s=0.0))
    fl.note_expiry(0.0)
    fl.note_expiry(10.0)                      # first one aged out
    fl.note_expiry(10.1)
    assert not fl.dumps
    fl.note_expiry(10.2)                      # 3 within the window
    assert [d["reason"] for d in fl.dumps] == ["expiry_burst"]


def test_engine_exception_always_dumps():
    fl = FlightRecorder(FlightConfig(cooldown_s=0.0))
    fl.note_exception("m", RuntimeError("boom"), t=3.0)
    assert fl.dumps[0]["reason"] == "engine_exception"
    assert "RuntimeError: boom" in fl.dumps[0]["error"]


def test_step_ring_is_bounded():
    fl = FlightRecorder(FlightConfig(capacity=4))
    for i in range(10):
        fl.record_step("m", t=float(i))
    assert len(fl.steps) == 4
    assert [s["t"] for s in fl.steps] == [6.0, 7.0, 8.0, 9.0]


# ---------------------------------------------------------------------------
# scheduler clock regression (stub plane): the shed path must stamp the
# caller's simulated clock, not fall back to perf_counter mid-call


def test_shed_event_stamped_with_simulated_now():
    from test_obs import _Pool, _Reg, _Eng, _req
    from repro.core.telemetry import Telemetry
    from repro.serving.scheduler import RequestScheduler, SchedulerConfig
    obs = Observability()
    eng = _Eng()
    eng.free_slots = lambda: 0
    sched = RequestScheduler(
        _Pool(eng), _Reg(), Telemetry(),
        cfg=SchedulerConfig(max_queue_depth=0, spin_on_demand=False),
        obs=obs)
    assert not sched.enqueue("m", "trt", _req(0), now=123.0)
    shed = obs.events.of("shed")[0]
    assert shed["t"] == 123.0                 # sim clock, not perf_counter


# ---------------------------------------------------------------------------
# live plane: real engines through the full frontend


@pytest.fixture(scope="module")
def fe():
    spin = SpinConfig(window_s=20.0, cooldown_s=0.0, idle_tau_s=0.5,
                      tick_s=3600.0, max_replicas=2,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    return ServeFrontend({SMOL: reduced_f32(SMOL)},
                         profile=PROFILES["balanced"], max_seq=96, spin=spin)


def test_live_requests_carry_measured_cost(fe):
    handles = [fe.submit(f"sum the numbers {i}", max_new_tokens=6)
               for i in range(3)]
    fe.serve_all()
    for h in handles:
        u = h.response.usage
        assert u.chip_seconds > 0.0
        assert u.cost_usd == pytest.approx(chip_seconds_usd(u.chip_seconds))
        assert u.kv_peak_bytes > 0
    assert fe.obs.registry.value("cost_per_query_usd", SMOL) > 0.0
    # the span mirrors the settled attribution
    span = fe.obs.tracer.finished[-1]
    assert span.chip_seconds > 0.0 and span.cost_usd > 0.0


def test_live_conservation_within_one_percent(fe):
    fe.serve_all()
    totals = fe.obs.ledger.totals()
    assert totals["total_chip_s"] > 0.0
    assert totals["attributed_chip_s"] > 0.0
    assert fe.obs.ledger.conservation_error() < 0.01


def test_memory_gauges_grounded_in_real_bytes(fe):
    fe.serve_all()
    fe.pool.scale(*KEY, 1)
    reg = fe.obs.registry
    eng = fe.pool.replicas(*KEY)[0]
    # hbm gauge == the live replica's params + KV cache (real array bytes)
    assert reg.value("hbm_resident_bytes", SMOL) == eng.resident_bytes()
    assert eng.resident_bytes() > eng._cache_bytes > 0
    used, free = fe.pool.kv_bytes(SMOL)
    assert used + free > 0
    # the scheduler publishes the same split as composite-label gauges
    h = fe.submit("sum the numbers", max_new_tokens=2)
    fe.serve_all()
    assert h.response.completed
    state_used = reg.value("kv_pool_bytes", f"{SMOL}|state=used")
    state_free = reg.value("kv_pool_bytes", f"{SMOL}|state=free")
    assert state_used + state_free > 0
    # scale-to-zero retires the bytes from the resident gauge
    fe.pool.scale(*KEY, 0)
    assert reg.value("hbm_resident_bytes", SMOL) == 0.0
    fe.pool.scale(*KEY, 1)


def test_memory_gauge_stamped_with_scale_clock(fe):
    """Regression (servelint SL001 audit): ``_update_memory_gauges``
    stamped ``hbm_resident_bytes`` with ``time.perf_counter()`` even
    when the scale driver ran on a simulated clock.  The gauge must
    carry the caller's ``now``."""
    reg = fe.obs.registry
    fe.pool.scale(*KEY, 0, now=1234.5)
    assert reg.gauge("hbm_resident_bytes", SMOL).stamp == 1234.5
    fe.pool.scale(*KEY, 1, now=2345.5)
    assert reg.gauge("hbm_resident_bytes", SMOL).stamp == 2345.5


def test_shed_storm_triggers_automatic_flight_dump(fe, tmp_path):
    fe.serve_all()
    path = str(tmp_path / "flight.jsonl")
    fl = fe.obs.flight
    fl.config.path = path
    fl._last_dump_t = None                    # isolate from prior tests
    n_dumps = len(fl.dumps)
    assert len(fl.steps) > 0                  # serve loop fed the ring
    depth0 = fe.scheduler.cfg.max_queue_depth
    fe.scheduler.cfg.max_queue_depth = 0
    try:
        # saturate the slots, then flood: every admission past capacity
        # sheds, tripping the storm trigger without any manual dump call
        handles = [fe.submit(f"count items {i}", max_new_tokens=4)
                   for i in range(fl.config.min_admissions + 8)]
    finally:
        fe.scheduler.cfg.max_queue_depth = depth0
    assert sum(h.shed for h in handles) >= fl.config.min_admissions
    assert len(fl.dumps) == n_dumps + 1
    assert fl.dumps[-1]["reason"] == "shed_storm"
    recs = [json.loads(ln) for ln in open(path)]
    kinds = {r["record"] for r in recs}
    assert kinds == {"dump", "step", "event"}
    assert any(r["record"] == "step" and r["model"] == SMOL for r in recs)
    assert any(r["record"] == "event" and r["event"] == "shed"
               for r in recs)
    fe.serve_all()                            # drain the survivors
