"""Checkpoint roundtrip (incl. bf16 leaves and nested/list structures)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import load_pytree, save_pytree


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
              "d": [jnp.asarray([1, 2, 3], jnp.int32),
                    jnp.asarray(7, jnp.int32)]},
    }
    p = str(tmp_path / "ckpt.zst")
    save_pytree(tree, p)
    out = load_pytree(tree, p)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_missing_leaf_raises(tmp_path):
    p = str(tmp_path / "c.zst")
    save_pytree({"a": jnp.zeros(2)}, p)
    try:
        load_pytree({"a": jnp.zeros(2), "b": jnp.zeros(3)}, p)
        assert False, "should raise"
    except KeyError:
        pass
