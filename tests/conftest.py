import os
import sys

# NOTE: no XLA_FLAGS here on purpose — tests must see the single real CPU
# device; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import pytest

from repro.configs.registry import ARCHS


def reduced_f32(arch: str):
    """Reduced smoke config in f32 (exact-parity friendly)."""
    return dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
