"""Continuous-batching core: chunked prefill + the unified token-budget
step.

The acceptance bar: chunked prefill is greedy token-for-token equivalent
to whole-prompt prefill on BOTH cache disciplines (so the scheduling
rewrite changed no arithmetic), a request's sampled tokens never depend
on batch composition, and long-prompt interference no longer stalls
in-flight decodes (the TTFT/ITL regression the refactor exists to fix).
"""
import time

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.core.gateway import ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.models import init_model
from repro.serving import (InferenceEngine, PagedInferenceEngine, Request,
                           SamplingParams, get_backend)

SMOL = "smollm-360m"
KEY = (SMOL, "trt")


@pytest.fixture(scope="module")
def stack():
    cfg = reduced_f32(SMOL)
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, get_backend("trt")


def _reqs(cfg, lengths, max_new=6, seed=3, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i, tokens=list(rng.randint(0, cfg.vocab_size, L)),
                    sampling=SamplingParams(max_new_tokens=max_new), **kw)
            for i, L in enumerate(lengths)]


# ---------------------------------------------------------------------------
# equivalence: the schedule changed, the arithmetic did not


LENGTHS = [5, 8, 16, 32, 64, 7, 16]     # pow2-safe: no dense truncation


def test_dense_chunked_matches_dense_whole_greedy(stack):
    cfg, params, bk = stack
    whole = InferenceEngine(cfg, params, bk, max_seq=96)
    chunked = InferenceEngine(cfg, params, bk, max_seq=96,
                              chunk_tokens=8, step_token_budget=16)
    rw = {r.uid: r.new_tokens for r in whole.run(_reqs(cfg, LENGTHS))}
    rc = {r.uid: r for r in chunked.run(_reqs(cfg, LENGTHS))}
    assert rw == {u: r.new_tokens for u, r in rc.items()}
    # the 64-token prompt genuinely amortized: ceil(64 / 8) chunks
    assert rc[4].prefill_chunks == 8
    assert all(r.completed for r in rc.values())


def test_paged_chunked_matches_dense_whole_greedy(stack):
    cfg, params, bk = stack
    dense = InferenceEngine(cfg, params, bk, max_seq=96)
    paged = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=16,
                                 chunk_tokens=8, step_token_budget=16)
    rd = {r.uid: r.new_tokens for r in dense.run(_reqs(cfg, LENGTHS))}
    rp = {r.uid: r.new_tokens for r in paged.run(_reqs(cfg, LENGTHS))}
    assert rd == rp
    # every request's blocks were freed on reap
    assert paged.pool.num_free + len(paged.prefix) == paged.num_blocks


def test_chunked_prefix_hit_still_skips_and_matches(stack):
    # a chunked engine keeps the radix-cache contract: the repeat of a
    # prompt reuses its full blocks and the tokens don't change
    cfg, params, bk = stack
    paged = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=16,
                                 chunk_tokens=16)
    rng = np.random.RandomState(11)
    prompt = list(rng.randint(0, cfg.vocab_size, 40))
    sp = SamplingParams(max_new_tokens=4)
    r1 = paged.run([Request(uid=900, tokens=prompt, sampling=sp)])[0]
    h0 = paged.hit_tokens
    r2 = paged.run([Request(uid=901, tokens=prompt, sampling=sp)])[0]
    assert paged.hit_tokens - h0 == 32          # 2 x 16 full blocks of 40
    assert r2.cached_tokens == 32
    assert r1.new_tokens == r2.new_tokens
    # the hit collapsed prefill to one chunk of the uncached suffix
    assert r2.prefill_chunks < r1.prefill_chunks


def test_twin_prompts_share_blocks_chunk_by_chunk(stack):
    # progressive registration: a twin admitted in the SAME step reuses
    # the first prompt's blocks as its chunks land — it never waits for
    # the whole prefill to finish
    cfg, params, bk = stack
    paged = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=16,
                                 chunk_tokens=16)
    rng = np.random.RandomState(29)
    prompt = list(rng.randint(0, cfg.vocab_size, 64))
    sp = SamplingParams(max_new_tokens=4)
    res = {r.uid: r for r in paged.run(
        [Request(uid=1, tokens=list(prompt), sampling=sp),
         Request(uid=2, tokens=list(prompt), sampling=sp)])}
    assert res[2].cached_tokens > 0
    assert res[1].new_tokens == res[2].new_tokens


# ---------------------------------------------------------------------------
# sampling: a request's stream is independent of batch composition


def _tokens_alone_and_batched(cfg, params, bk, sampling):
    rng = np.random.RandomState(9)
    pa = list(rng.randint(0, cfg.vocab_size, 16))
    pb = list(rng.randint(0, cfg.vocab_size, 16))
    hot = SamplingParams(temperature=10.0, max_new_tokens=8)

    alone_eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8)
    alone = alone_eng.run([Request(uid=0, tokens=pa, sampling=sampling)])[0]
    batch_eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8)
    batched = {r.uid: r for r in batch_eng.run(
        [Request(uid=0, tokens=pa, sampling=sampling),
         Request(uid=1, tokens=pb, sampling=hot),
         Request(uid=2, tokens=pb, sampling=SamplingParams(max_new_tokens=8))]
    )}
    return alone, batched


def test_greedy_tokens_independent_of_batch_composition(stack):
    cfg, params, bk = stack
    alone, batched = _tokens_alone_and_batched(
        cfg, params, bk, SamplingParams(max_new_tokens=8))
    assert alone.new_tokens == batched[0].new_tokens


def test_seeded_sampling_independent_of_batch_composition(stack):
    # the regression the per-uid PRNG streams fix: the old engine split
    # one engine-global key in sampling-group iteration order, so WHO
    # shared your batch changed WHICH key your tokens were drawn with
    cfg, params, bk = stack
    sp = SamplingParams(temperature=1.0, top_k=8, max_new_tokens=8)
    alone, batched = _tokens_alone_and_batched(cfg, params, bk, sp)
    assert alone.new_tokens == batched[0].new_tokens
    # distinct uids draw from distinct streams (not all-identical)
    assert batched[0].new_tokens != batched[1].new_tokens


# ---------------------------------------------------------------------------
# the point of the refactor: long-prompt interference


def _mk_engine(cfg, params, bk, chunk, budget):
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=512,
                               chunk_tokens=chunk, step_token_budget=budget)
    rng = np.random.RandomState(3)
    eng.run([Request(uid=99,                     # compile outside timing
                     tokens=list(rng.randint(0, cfg.vocab_size, 448)),
                     sampling=SamplingParams(max_new_tokens=2))])
    return eng


def _interference_run(eng, cfg, seed):
    """Max step wall-time while a 448-token prompt lands mid-decode."""
    rng = np.random.RandomState(seed)
    victims = [Request(uid=10 + i,
                       tokens=list(rng.randint(0, cfg.vocab_size, 16)),
                       sampling=SamplingParams(max_new_tokens=24))
               for i in range(2)]
    victim_tokens = {v.uid: 0 for v in victims}

    def count(deltas):
        for uid, _tok in deltas:
            if uid in victim_tokens:
                victim_tokens[uid] += 1

    for v in victims:
        eng.submit(v)
    for _ in range(2):                           # victims mid-decode
        eng.step()
        count(eng.drain_deltas())
    eng.submit(Request(uid=50,
                       tokens=list(rng.randint(0, cfg.vocab_size, 448)),
                       sampling=SamplingParams(max_new_tokens=2)))
    walls = []
    results = []
    while eng.has_work():
        t0 = time.perf_counter()
        results.extend(eng.step())
        walls.append(time.perf_counter() - t0)
        count(eng.drain_deltas())
    return max(walls), {r.uid: r for r in results}, victim_tokens


def test_chunked_prefill_amortizes_long_prompt(stack):
    # structural: the long prompt takes ceil(448/64) prefill passes and
    # the victims keep decoding THROUGH them — under whole-prompt
    # prefill the same arrival is one monolithic pass
    cfg, params, bk = stack
    eng = _mk_engine(cfg, params, bk, 64, 128)
    _, res, victim_tokens = _interference_run(eng, cfg, seed=7)
    assert res[50].prefill_chunks == 7
    assert all(n == 24 for n in victim_tokens.values())
    assert res[50].completed


def test_itl_regression_under_long_prompt_interference(stack):
    # the victims' worst inter-token gap (== worst step wall) must drop
    # materially once prefill is chunked. Spikes are systematic (the
    # long prefill runs every repetition) while scheduler noise is not,
    # so min-of-3 isolates the real effect; measured headroom is ~3x,
    # gated at 1.5x for slow CI
    cfg, params, bk = stack
    eng_w = _mk_engine(cfg, params, bk, None, None)
    eng_c = _mk_engine(cfg, params, bk, 64, 128)
    worst_w, worst_c = [], []
    for rep in range(3):                 # fresh prompts: no radix reuse
        ww, res_w, _ = _interference_run(eng_w, cfg, seed=20 + rep)
        wc, res_c, _ = _interference_run(eng_c, cfg, seed=20 + rep)
        assert res_w[50].new_tokens == res_c[50].new_tokens  # same math
        worst_w.append(ww)
        worst_c.append(wc)
    assert min(worst_w) >= 1.5 * min(worst_c)


# ---------------------------------------------------------------------------
# token budget + backlog accounting


def test_step_token_budget_bounds_prefill_per_step(stack):
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=512,
                               chunk_tokens=64, step_token_budget=80)
    rng = np.random.RandomState(31)
    for i in range(3):
        eng.submit(Request(uid=i,
                           tokens=list(rng.randint(0, cfg.vocab_size, 128)),
                           sampling=SamplingParams(max_new_tokens=2)))
    filled_before = [0, 0, 0]
    while eng.has_work():
        eng.step()
        filled_now = [s.filled for s in eng._slots[:3]]
        spent = sum(max(0, a - b)
                    for a, b in zip(filled_now, filled_before))
        assert spent <= 80               # prefill tokens per step <= budget
        filled_before = filled_now


def test_pending_tokens_tracks_queue_and_cursors(stack):
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=512,
                               chunk_tokens=32, step_token_budget=32)
    rng = np.random.RandomState(37)
    reqs = [Request(uid=i,
                    tokens=list(rng.randint(0, cfg.vocab_size, 128)),
                    sampling=SamplingParams(max_new_tokens=2))
            for i in range(6)]
    for r in reqs:
        eng.submit(r)
    assert eng.pending_tokens() == 6 * 128
    eng.step()                           # some admitted, one chunk ran
    drained = 6 * 128 - eng.pending_tokens()
    assert 0 < drained <= 32             # exactly the budgeted chunk work
    eng.run([])                          # drain
    assert eng.pending_tokens() == 0


def test_deadline_aborts_mid_prefill(stack):
    # a long prompt whose deadline lapses BETWEEN chunks is reaped at the
    # chunk boundary without burning budget on the rest of its prefill
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=512,
                               chunk_tokens=32, step_token_budget=32)
    rng = np.random.RandomState(41)
    req = Request(uid=0, tokens=list(rng.randint(0, cfg.vocab_size, 256)),
                  sampling=SamplingParams(max_new_tokens=4), deadline_s=1e-9)
    res = eng.run([req])[0]
    assert res.timed_out and not res.completed
    assert res.new_tokens == []          # never reached its first token
    assert res.prefill_chunks <= 1
    assert eng.pool.num_free + len(eng.prefix) == eng.num_blocks


# ---------------------------------------------------------------------------
# serve plane: token-aware queue bounds + usage surfacing


@pytest.fixture(scope="module")
def fe():
    spin = SpinConfig(window_s=20.0, cooldown_s=0.0, idle_tau_s=3600.0,
                      tick_s=3600.0, max_replicas=1,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    return ServeFrontend({SMOL: reduced_f32(SMOL)}, max_seq=96, spin=spin,
                         paged=True, chunk_tokens=16, step_token_budget=64)


def test_usage_reports_prefill_chunks(fe):
    h = fe.submit("x" * 80, max_new_tokens=2)    # ~80 byte-tokens, chunk 16
    fe.serve_all()
    assert h.response.completed
    assert h.response.usage.prefill_chunks >= 2


def test_queue_bound_in_tokens_sheds(fe):
    fe.serve_all()
    eng = fe.pool.replicas(*KEY)[0]
    tok0 = fe.scheduler.cfg.max_queue_tokens
    fe.scheduler.cfg.max_queue_tokens = 64
    try:
        # saturate the slots, then queue long prompts: the TOKEN bound
        # trips long before the 64-request depth bound would
        blockers = [fe.submit(f"sum items {i}", max_new_tokens=24)
                    for i in range(eng.max_batch)]
        shed0 = fe.scheduler.stats.shed_tokens
        handles = [fe.submit("y" * 60, max_new_tokens=2) for _ in range(4)]
        assert sum(h.shed for h in handles) >= 1
        assert fe.scheduler.stats.shed_tokens > shed0
        assert fe.scheduler.queued_tokens() <= 64 + 60
        fe.serve_all()
        assert all(b.response.completed for b in blockers)
    finally:
        fe.scheduler.cfg.max_queue_tokens = tok0


def test_token_bound_preemption_evicts_enough_and_stays_bounded(fe):
    # a high-priority long prompt may displace SEVERAL queued low-
    # priority chat turns (one seat != enough tokens), and the queue
    # token total must respect the bound afterwards; an arrival no
    # eviction can fit is shed without punishing anyone already queued
    from repro.api import Priority
    fe.serve_all()
    eng = fe.pool.replicas(*KEY)[0]
    tok0 = fe.scheduler.cfg.max_queue_tokens
    fe.scheduler.cfg.max_queue_tokens = 100
    try:
        blockers = [fe.submit(f"sum items {i}", max_new_tokens=24)
                    for i in range(eng.max_batch)]
        low = [fe.submit("z" * 30, max_new_tokens=2,
                         priority=Priority.BATCH) for _ in range(3)]
        assert fe.scheduler.queued_tokens() == 90
        pre0 = fe.scheduler.stats.preempted
        hi = fe.submit("y" * 80, max_new_tokens=2,
                       priority=Priority.INTERACTIVE)
        assert not hi.done()                     # admitted to the queue
        assert fe.scheduler.stats.preempted - pre0 >= 2   # several victims
        assert fe.scheduler.queued_tokens() <= 100
        # an arrival too big for ANY eviction set: rejected, queue intact
        q_before = len(fe.scheduler._queues[KEY])
        huge = fe.submit("w" * 200, max_new_tokens=2,
                         priority=Priority.INTERACTIVE)
        assert huge.shed
        assert len(fe.scheduler._queues[KEY]) == q_before
        fe.serve_all()                   # victim sheds surface next step
        assert sum(h.shed for h in low) >= 2
        assert hi.response.completed
        assert all(b.response.completed for b in blockers)
    finally:
        fe.scheduler.cfg.max_queue_tokens = tok0


def test_scheduler_reports_token_gauges(fe):
    fe.serve_all()
    assert fe.telemetry.gauge(SMOL, "queue_tokens") == 0.0
    assert fe.telemetry.gauge(SMOL, "backlog_tokens") >= 0.0


def test_repeat_prompt_never_evicts_its_own_prefix(stack):
    # regression: admission-time gating must count the prefix hit — a
    # worst-case bound on a tight pool both refused the admission the
    # old flow accepted AND let the eviction pass reclaim exactly the
    # blocks this prompt was about to reuse
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=16,
                               num_blocks=6, chunk_tokens=16)
    rng = np.random.RandomState(43)
    prompt = list(rng.randint(0, cfg.vocab_size, 64))
    sp = SamplingParams(max_new_tokens=4)
    eng.run([Request(uid=1, tokens=prompt, sampling=sp)])
    # pool now: 4 cache-held blocks + 2 free — a worst-case 5-block
    # demand would trigger eviction of the prompt's own prefix
    r2 = eng.run([Request(uid=2, tokens=prompt, sampling=sp)])[0]
    assert r2.completed
    assert r2.cached_tokens >= 48        # the prefix survived readmission


def test_chunk_tokens_zero_means_whole_prompt(stack):
    # regression: a raw 0 reaching the chunk sizing stalled the prefill
    # cursor forever; the engine now folds it to the launcher's "0 =
    # whole prompt" convention
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=0,
                          step_token_budget=0)
    assert eng.chunk_tokens is None and eng.step_token_budget is None
    res = eng.run(_reqs(cfg, [16], max_new=4), max_steps=50)
    assert len(res) == 1 and res[0].completed


def test_engine_queue_is_deque(stack):
    # O(1) admission: the old list.pop(0) was O(n) per admitted request
    from collections import deque
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96)
    assert isinstance(eng._queue, deque)
