"""Serving engine behaviour: continuous batching, slots, deadlines."""
import time

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import init_model
from repro.serving import (BACKENDS, InferenceEngine, Request,
                           SamplingParams, get_backend)


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_f32("smollm-360m")
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, InferenceEngine(cfg, params, get_backend("trt"), max_seq=96)


def _reqs(cfg, n, max_new=6, seed=0, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    tokens=list(rng.randint(0, cfg.vocab_size,
                                            rng.randint(5, 30))),
                    sampling=SamplingParams(max_new_tokens=max_new), **kw)
            for i in range(n)]


def test_engine_serves_all(engine):
    cfg, eng = engine
    res = eng.run(_reqs(cfg, 7))
    assert len(res) == 7
    for r in res:
        assert r.completed and len(r.new_tokens) == 6
        assert 0 < r.ttft <= r.latency


def test_engine_more_requests_than_slots(engine):
    cfg, eng = engine
    # trt backend has 4 slots; 9 requests must queue and still finish
    res = eng.run(_reqs(cfg, 9, seed=1))
    assert len(res) == 9 and all(r.completed for r in res)


def test_engine_deadline_marks_timeout(engine):
    cfg, eng = engine
    res = eng.run(_reqs(cfg, 2, max_new=8, seed=2, deadline_s=1e-9))
    assert all(r.timed_out and not r.completed for r in res)


def test_greedy_deterministic(engine):
    cfg, eng = engine
    r1 = eng.run(_reqs(cfg, 1, seed=3))[0]
    r2 = eng.run(_reqs(cfg, 1, seed=3))[0]
    assert r1.new_tokens == r2.new_tokens


def test_backend_profiles_are_distinct():
    names = set()
    for b in BACKENDS.values():
        names.add((b.max_batch, b.q_chunk, b.batch_wait_s))
    assert len(names) == 3    # genuinely different execution configs


def test_prompt_bucketing():
    assert InferenceEngine._bucket(5) == 8
    assert InferenceEngine._bucket(8) == 8
    assert InferenceEngine._bucket(9) == 8
    assert InferenceEngine._bucket(16) == 16
    assert InferenceEngine._bucket(250) == 128
