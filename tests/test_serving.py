"""Serving engine behaviour: continuous batching, slots, deadlines."""
import time

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import init_model
from repro.serving import (BACKENDS, InferenceEngine, Request,
                           SamplingParams, get_backend)


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_f32("smollm-360m")
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, InferenceEngine(cfg, params, get_backend("trt"), max_seq=96)


def _reqs(cfg, n, max_new=6, seed=0, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    tokens=list(rng.randint(0, cfg.vocab_size,
                                            rng.randint(5, 30))),
                    sampling=SamplingParams(max_new_tokens=max_new), **kw)
            for i in range(n)]


def test_engine_serves_all(engine):
    cfg, eng = engine
    res = eng.run(_reqs(cfg, 7))
    assert len(res) == 7
    for r in res:
        assert r.completed and len(r.new_tokens) == 6
        assert 0 < r.ttft <= r.latency


def test_engine_more_requests_than_slots(engine):
    cfg, eng = engine
    # trt backend has 4 slots; 9 requests must queue and still finish
    res = eng.run(_reqs(cfg, 9, seed=1))
    assert len(res) == 9 and all(r.completed for r in res)


def test_engine_deadline_marks_timeout(engine):
    cfg, eng = engine
    res = eng.run(_reqs(cfg, 2, max_new=8, seed=2, deadline_s=1e-9))
    assert all(r.timed_out and not r.completed for r in res)


def test_greedy_deterministic(engine):
    cfg, eng = engine
    r1 = eng.run(_reqs(cfg, 1, seed=3))[0]
    r2 = eng.run(_reqs(cfg, 1, seed=3))[0]
    assert r1.new_tokens == r2.new_tokens


def test_per_slot_sampling_in_mixed_batches(engine):
    # regression: batched decode used to sample EVERY active slot with the
    # first active slot's SamplingParams, so a greedy request sharing a
    # batch with a high-temperature one got random tokens
    cfg, eng = engine
    rng = np.random.RandomState(9)
    prompt_a = list(rng.randint(0, cfg.vocab_size, 12))
    prompt_b = list(rng.randint(0, cfg.vocab_size, 12))
    greedy = SamplingParams(max_new_tokens=8)
    hot = SamplingParams(temperature=10.0, max_new_tokens=8)

    def run(sampling_a):
        res = eng.run([Request(uid=0, tokens=prompt_a, sampling=sampling_a),
                       Request(uid=1, tokens=prompt_b, sampling=greedy)])
        return {r.uid: r for r in res}

    # B is greedy in both runs; slot 0's params must not leak onto it
    r_hot, r_greedy = run(hot), run(greedy)
    assert r_hot[1].new_tokens == r_greedy[1].new_tokens
    assert all(r.completed for r in (*r_hot.values(), *r_greedy.values()))


def test_per_slot_eos_in_mixed_batches(engine):
    # each request's eos_id is honored individually inside a shared batch
    cfg, eng = engine
    ref = eng.run([Request(uid=0, tokens=[5, 6, 7],
                           sampling=SamplingParams(max_new_tokens=6))])[0]
    eos = ref.new_tokens[2]           # greedy token #3 becomes req-1's EOS
    res = {r.uid: r for r in eng.run([
        Request(uid=0, tokens=[5, 6, 7],
                sampling=SamplingParams(max_new_tokens=6)),
        Request(uid=1, tokens=[5, 6, 7],
                sampling=SamplingParams(max_new_tokens=6, eos_id=eos))])}
    assert len(res[0].new_tokens) == 6                 # no eos -> runs full
    assert res[1].new_tokens[-1] == eos                # stopped at ITS eos
    assert len(res[1].new_tokens) < 6
    assert res[1].completed


def test_first_token_respects_limits(engine):
    # the token sampled from prefill logits counts against the limits:
    # max_new_tokens=1 returns exactly one token, and a first token that
    # IS the eos stops generation immediately
    cfg, eng = engine
    one = eng.run([Request(uid=0, tokens=[9, 10, 11],
                           sampling=SamplingParams(max_new_tokens=1))])[0]
    assert one.completed and len(one.new_tokens) == 1
    eos_first = eng.run([Request(
        uid=1, tokens=[9, 10, 11],
        sampling=SamplingParams(max_new_tokens=6,
                                eos_id=one.new_tokens[0]))])[0]
    assert eos_first.completed and eos_first.new_tokens == one.new_tokens


def test_engine_free_slots(engine):
    cfg, eng = engine
    assert eng.free_slots() == eng.max_batch
    eng.submit(_reqs(cfg, 1)[0])
    assert eng.free_slots() == eng.max_batch - 1       # queued counts
    eng.run([])                                        # drain
    assert eng.free_slots() == eng.max_batch


def test_backend_profiles_are_distinct():
    names = set()
    for b in BACKENDS.values():
        names.add((b.max_batch, b.q_chunk, b.batch_wait_s))
    assert len(names) == 3    # genuinely different execution configs


def test_prompt_bucketing():
    assert InferenceEngine._bucket(5) == 8
    assert InferenceEngine._bucket(8) == 8
    assert InferenceEngine._bucket(9) == 8
    assert InferenceEngine._bucket(16) == 16
    assert InferenceEngine._bucket(250) == 128
