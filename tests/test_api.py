"""Serving API v2: typed protocol, streaming handles, cancellation,
sessions, priorities — the gateway facade and the concurrent frontend
speaking one language over REAL engines (reduced smollm on CPU).
"""
import dataclasses

import pytest

from conftest import reduced_f32
from repro.api import CompletionRequest, FinishReason, Priority
from repro.core.gateway import Gateway, GatewayConfig, ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.router import HybridRouter, KeywordRouter
from repro.core.scoring import PROFILES

SMOL = "smollm-360m"
KEY = (SMOL, "trt")


@pytest.fixture(scope="module")
def fe():
    # paged engines so cancellation/session tests can watch the block
    # pool; huge tick so the Spin loop can't retire replicas mid-assert
    spin = SpinConfig(window_s=20.0, cooldown_s=0.0, idle_tau_s=3600.0,
                      tick_s=3600.0, max_replicas=1,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    return ServeFrontend({SMOL: reduced_f32(SMOL)},
                         profile=PROFILES["balanced"], max_seq=96, spin=spin,
                         paged=True)


def _engine(fe):
    return fe.pool.replicas(*KEY)[0]


# ---------------------------------------------------------------------------
# streaming


def test_stream_yields_exactly_the_final_tokens(fe):
    h = fe.submit("add the numbers now please", max_new_tokens=12)
    events = list(h.tokens())
    assert events[-1].kind == "done"
    assert events[-1].finish_reason == FinishReason.LENGTH
    streamed = [ev.token for ev in events if ev.kind == "token"]
    assert streamed == h.response.new_tokens
    assert len(streamed) == 12
    assert [ev.index for ev in events] == list(range(len(events)))


def test_stream_is_incremental_per_decode_iteration(fe):
    h = fe.submit("count the items quickly", max_new_tokens=8)
    it = h.tokens()
    first = next(it)
    assert first.kind == "token"
    assert not h.done()                  # mid-generation, not buffered-at-end
    rest = list(it)
    assert h.done()
    assert [first.token] + [e.token for e in rest
                            if e.kind == "token"] == h.response.new_tokens


# ---------------------------------------------------------------------------
# cancellation


def test_cancel_in_flight_frees_slot_and_kv_blocks(fe):
    fe.serve_all()
    eng = _engine(fe)
    eng.prefix.clear()                   # drop cache leases -> true baseline
    base_free = eng.pool.num_free
    assert base_free == eng.num_blocks and eng.idle_slots() == eng.max_batch
    h = fe.submit("list everything at great length", max_new_tokens=64)
    fe.step()
    fe.step()                            # prefilled + decoding in a slot
    assert eng.idle_slots() == eng.max_batch - 1
    assert eng.pool.num_free < base_free
    assert h.cancel()
    assert h.response.finish_reason == FinishReason.CANCELLED
    assert not h.response.completed and len(h.response.new_tokens) >= 1
    # slot free the same call; blocks back once the admit-time prefix
    # registration (evictable, refcounted) is dropped
    assert eng.idle_slots() == eng.max_batch
    assert eng.kv_free_frac() == 1.0
    eng.prefix.clear()
    assert eng.pool.num_free == base_free
    assert not fe.has_work()
    assert not h.cancel()                # second cancel is a no-op


def test_cancel_queued_request_never_touches_a_slot(fe):
    fe.serve_all()
    eng = _engine(fe)
    blockers = [fe.submit(f"sum the items {i}", max_new_tokens=24)
                for i in range(eng.max_batch)]
    victim = fe.submit("count this later", max_new_tokens=4)
    assert victim.uid in {r.uid for r in fe.scheduler._queues[KEY]}
    dispatched0 = fe.scheduler.stats.dispatched
    assert victim.cancel()
    assert victim.response.finish_reason == FinishReason.CANCELLED
    assert victim.response.new_tokens == []          # never decoded a token
    assert fe.scheduler.stats.cancelled >= 1
    assert fe.registry.entry(*KEY).queued == 0
    fe.serve_all()
    # only the blockers were ever dispatched; the victim never got a slot
    assert fe.scheduler.stats.dispatched == dispatched0
    assert all(b.response.completed for b in blockers)


# ---------------------------------------------------------------------------
# priorities


def test_priority_dispatch_order_high_first(fe):
    fe.serve_all()
    eng = _engine(fe)
    blockers = [fe.submit(f"sum the items {i}", max_new_tokens=30)
                for i in range(eng.max_batch)]
    fe.step()                            # all slots busy
    low = fe.submit("low priority batch work", max_new_tokens=8,
                    priority=Priority.BATCH)
    hi = fe.submit("interactive arrives later", max_new_tokens=8,
                   priority=Priority.INTERACTIVE)
    assert blockers[0].cancel()          # free exactly one slot
    fe.step()                            # dispatch: priority beats FIFO
    live = {s.req.uid for s in eng._slots if not s.done and s.req} \
        | {r.uid for r in eng._queue}
    assert hi.uid in live
    assert low.uid in {r.uid for r in fe.scheduler._queues[KEY]}
    fe.serve_all()
    assert hi.response.completed and low.response.completed


def test_priority_shed_low_before_high_under_pressure(fe):
    fe.serve_all()
    eng = _engine(fe)
    depth0 = fe.scheduler.cfg.max_queue_depth
    fe.scheduler.cfg.max_queue_depth = 1
    try:
        blockers = [fe.submit(f"sum the items {i}", max_new_tokens=24)
                    for i in range(eng.max_batch)]
        low = fe.submit("queued batch work", max_new_tokens=2,
                        priority=Priority.BATCH)
        assert not low.done()            # admitted into the queue
        # equal class cannot preempt: NORMAL is rejected, low keeps its spot
        normal = fe.submit("queued normal work", max_new_tokens=2,
                           priority=Priority.BATCH)
        assert normal.shed
        # higher class evicts the queued low-priority request instead of
        # being rejected — shed low before high, as a structured result
        hi = fe.submit("urgent interactive", max_new_tokens=2,
                       priority=Priority.INTERACTIVE)
        assert not hi.done()
        preempted0 = fe.scheduler.stats.preempted
        assert preempted0 >= 1
        fe.serve_all()
        assert low.response.finish_reason == FinishReason.SHED
        assert not low.response.ok
        assert hi.response.completed
        assert all(b.response.completed for b in blockers)
    finally:
        fe.scheduler.cfg.max_queue_depth = depth0


# ---------------------------------------------------------------------------
# sessions


def test_session_turn2_hits_prefix_cache(fe):
    fe.serve_all()
    r1 = fe.submit(CompletionRequest(
        prompt="you are a terse assistant; count apples pears and plums",
        max_new_tokens=4, session_id="conv-a")).result()
    assert r1.completed and r1.session_id == "conv-a"
    r2 = fe.submit(CompletionRequest(
        prompt=" now add two more fruits", max_new_tokens=4,
        session_id="conv-a")).result()
    # the service is pinned and the turn-1 history (prompt + completion)
    # is served out of cached KV blocks, not re-prefilled
    assert (r2.model, r2.backend) == (r1.model, r1.backend)
    assert r2.usage.prompt_tokens > len(" now add two more fruits")
    assert r2.usage.cached_tokens >= _engine(fe).block_size
    sess = fe._sessions["conv-a"]
    assert sess.turns == 2


def test_overlapping_session_turn_cannot_clobber_history(fe):
    fe.serve_all()
    t1 = fe.submit(CompletionRequest(prompt="count the apples here now",
                                     max_new_tokens=4, session_id="conv-b"))
    # turn 2 submitted BEFORE turn 1 resolves: it is served, but it was
    # not built on turn 1's history, so it must not extend the chain
    t2 = fe.submit(CompletionRequest(prompt=" and the pears",
                                     max_new_tokens=4, session_id="conv-b"))
    fe.serve_all()
    assert t1.response.completed and t2.response.completed
    sess = fe._sessions["conv-b"]
    assert sess.turns == 1               # only one turn won the chain
    assert sess.tokens[-4:] in (t1.response.new_tokens,
                                t2.response.new_tokens)
    assert fe.end_session("conv-b") and not fe.end_session("conv-b")


def test_sessions_are_lru_bounded(fe):
    fe.serve_all()
    keep0 = fe.config.session_retention
    fe.config.session_retention = 3
    try:
        handles = [fe.submit(CompletionRequest(
            prompt=f"sum the numbers {i}", max_new_tokens=2,
            session_id=f"one-shot-{i}")) for i in range(6)]
        fe.serve_all()
        assert all(h.response.completed for h in handles)
        assert len(fe._sessions) <= 3
        assert "one-shot-5" in fe._sessions      # newest survive
    finally:
        fe.config.session_retention = keep0


# ---------------------------------------------------------------------------
# facade equivalence + cold-start attribution


def test_sync_facade_equals_concurrent_plane_under_greedy(fe):
    fe.serve_all()
    _engine(fe).prefix.clear()           # same cold-cache start both planes
    prompt = "count the items here: ".ljust(32, "x")   # pow2: no truncation
    r_conc = fe.submit(prompt, max_new_tokens=6).result()
    gw = Gateway({SMOL: reduced_f32(SMOL)}, profile=PROFILES["balanced"],
                 max_seq=96, paged=True)
    r_sync = gw.handle(prompt, max_new_tokens=6)
    assert isinstance(gw.frontend, ServeFrontend)
    assert r_sync.new_tokens == r_conc.new_tokens      # greedy, same plane
    assert (r_sync.model, r_sync.backend) == (r_conc.model, r_conc.backend)
    # facade cold start is real and attributed; the live plane's is zero
    assert r_sync.cold_start_s > 0.0
    assert r_conc.cold_start_s == 0.0


def test_one_construction_path_no_duplicated_setup(fe):
    gw = Gateway({SMOL: reduced_f32(SMOL)}, max_seq=96)
    # the facade owns NO plane state — registry/policy/pool/scheduler are
    # the frontend's, reached through passthroughs
    assert gw.registry is gw.frontend.registry
    assert gw.policy is gw.frontend.policy
    assert gw.pool is gw.frontend.pool
    assert gw.scheduler is gw.frontend.scheduler
    cfg = gw.frontend.config
    assert isinstance(cfg, GatewayConfig) and cfg.autoscale is False


# ---------------------------------------------------------------------------
# bounded results + structured shed


def test_result_retention_is_bounded_and_drained(fe):
    fe.serve_all()
    fe.drain()
    keep0 = fe.config.result_retention
    fe.config.result_retention = 4
    try:
        handles = [fe.submit(f"sum the numbers {i}", max_new_tokens=2)
                   for i in range(7)]
        fe.serve_all()                   # nobody polls; buffer must bound
        assert len(fe._recent) <= 4
        drained = fe.drain()
        assert len(drained) <= 4 and fe._recent == {}
        # per-request handles still hold every result (no loss for
        # callers that kept theirs)
        assert all(h.response is not None for h in handles)
    finally:
        fe.config.result_retention = keep0


# ---------------------------------------------------------------------------
# router satellite: frozen decisions, no in-place rewrites


def test_route_decision_is_frozen():
    d = KeywordRouter().route("prove the theorem rigorously")
    with pytest.raises(dataclasses.FrozenInstanceError):
        d.mode = "hybrid"


def test_hybrid_route_many_returns_fresh_decisions():
    hr = HybridRouter(semantic=None)     # clear-cut prompts never fall through
    texts = ["prove the theorem step by step rigorously",
             "briefly sum the list"]
    kw = hr.kw.route_many(texts)
    out = hr.route_many(texts)
    assert [d.tier for d in out] == [d.tier for d in kw]
    assert all(d.mode == "hybrid" for d in out)
    assert all(k.mode == "keyword" for k in kw)        # sources untouched
    assert all(o is not k for o, k in zip(out, kw))
