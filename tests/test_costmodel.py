"""Cost-model sanity: physics-grounded serving constants per arch."""
import pytest

from repro.configs.registry import ARCHS, MODEL_TIERS
from repro.core.costmodel import (HBM_BYTES, instance_cost, predict_cost,
                                  predict_latency)
from repro.serving.backend import BACKENDS


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_instance_fits_and_is_positive(arch):
    ic = instance_cost(ARCHS[arch], BACKENDS["trt"])
    # replica actually fits its weights in HBM with headroom
    assert ic.hbm_bytes <= ic.chips * HBM_BYTES * 0.65 * 1.01
    assert ic.tokens_per_s_single > 0
    assert ic.cold_start_s > ic.warm_start_s
    assert ic.usd_per_s > 0


def test_bigger_models_cost_more_and_decode_slower():
    small = instance_cost(ARCHS["smollm-360m"], BACKENDS["trt"])
    large = instance_cost(ARCHS["command-r-plus-104b"], BACKENDS["trt"])
    assert large.chips > small.chips
    assert large.usd_per_s > small.usd_per_s
    assert large.cold_start_s > small.cold_start_s


def test_moe_decodes_cheaper_than_dense_at_same_size():
    """deepseek-v2 (236B total, 21B active) must beat a dense 104B on
    single-stream decode speed per chip-normalized step."""
    moe = instance_cost(ARCHS["deepseek-v2-236b"], BACKENDS["trt"])
    dense = instance_cost(ARCHS["command-r-plus-104b"], BACKENDS["trt"])
    assert moe.tokens_per_s_single * moe.chips > 0
    # active-params streaming: v2 moves 42GB/step vs command-r 208GB
    assert (moe.tokens_per_s_single / moe.chips >
            dense.tokens_per_s_single / dense.chips * 0.5)


def test_latency_monotone_in_tokens():
    ic = instance_cost(ARCHS["glm4-9b"], BACKENDS["vllm"])
    l1 = predict_latency(ic, 128, 32)
    l2 = predict_latency(ic, 128, 320)
    l3 = predict_latency(ic, 1280, 32)
    assert l2 > l1 and l3 > l1
    assert predict_cost(ic, l2) > predict_cost(ic, l1)


def test_tier_assignment_tracks_size():
    sizes = {t: [] for t in ("small", "medium", "large")}
    for a, t in MODEL_TIERS.items():
        sizes[t].append(ARCHS[a].param_count())
    assert max(sizes["small"]) < min(sizes["large"])
