"""Device-resident decode hot path: fused sample-in-step, decode bursts,
and the transfer/retrace guards.

The acceptance bar: the fused in-step sampler draws token-for-token what
the host-side per-request sampler drew (greedy + seeded stochastic,
across batch compositions), a K-deep decode burst emits exactly the
stepwise token streams, the steady-state decode step moves ONLY token
ids across the host boundary (no logits materialization), and ``step()``
never silently retraces.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import init_model
from repro.obs import Observability
from repro.serving import (InferenceEngine, PagedInferenceEngine, Request,
                           SamplingParams, get_backend)
from repro.serving.sampling import sample, sample_rows

SMOL = "smollm-360m"


@pytest.fixture(scope="module")
def stack():
    cfg = reduced_f32(SMOL)
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, get_backend("trt")


def _reqs(cfg, lengths, max_new=6, seed=3, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i, tokens=list(rng.randint(0, cfg.vocab_size, L)),
                    sampling=SamplingParams(max_new_tokens=max_new, **kw))
            for i, L in enumerate(lengths)]


# ---------------------------------------------------------------------------
# fused sampler == host sampler (the PR-4 per-request path)


def test_sample_rows_matches_host_sampler_per_row():
    # every row of one fused dispatch must draw exactly the token the
    # host-side sample(logits_row[None], sp, key) path drew — greedy and
    # stochastic rows mixed, top-k/top-p on and off
    rng = np.random.RandomState(0)
    sps = [SamplingParams(),
           SamplingParams(temperature=1.0),
           SamplingParams(temperature=0.7, top_k=5),
           SamplingParams(temperature=1.3, top_p=0.8),
           SamplingParams(temperature=1.0, top_k=8, top_p=0.9),
           SamplingParams(temperature=0.0, top_k=4),
           SamplingParams(temperature=2.0, top_k=1),
           SamplingParams(temperature=0.5, top_p=0.5)]
    logits = jnp.asarray(rng.randn(len(sps), 41).astype(np.float32) * 3)
    base = jax.random.PRNGKey(7)
    keys = jnp.stack([jax.random.fold_in(base, i) for i in range(len(sps))])
    host = [int(sample(logits[i][None], sp, keys[i])[0])
            for i, sp in enumerate(sps)]
    fused = sample_rows(
        logits,
        jnp.asarray([sp.temperature for sp in sps], jnp.float32),
        jnp.asarray([sp.top_k for sp in sps], jnp.int32),
        jnp.asarray([sp.top_p for sp in sps], jnp.float32), keys)
    assert host == list(np.asarray(fused))


def test_fused_streams_independent_of_batch_composition(stack):
    # the fused sampler keeps the per-uid PRNG stream contract: same
    # request, same seed -> same tokens whether it runs alone or packed
    # with different neighbours (and regardless of its slot row)
    cfg, params, bk = stack
    sp = SamplingParams(temperature=1.0, top_k=8, max_new_tokens=8)
    rng = np.random.RandomState(9)
    pa = list(rng.randint(0, cfg.vocab_size, 16))
    pb = list(rng.randint(0, cfg.vocab_size, 16))
    alone = InferenceEngine(cfg, params, bk, max_seq=96).run(
        [Request(uid=0, tokens=pa, sampling=sp)])[0]
    batched = {r.uid: r for r in InferenceEngine(
        cfg, params, bk, max_seq=96).run(
        [Request(uid=5, tokens=pb,
                 sampling=SamplingParams(temperature=9.0, max_new_tokens=8)),
         Request(uid=0, tokens=pa, sampling=sp),
         Request(uid=7, tokens=pb, sampling=SamplingParams(max_new_tokens=8))]
    )}
    assert alone.new_tokens == batched[0].new_tokens


# ---------------------------------------------------------------------------
# burst == stepwise, token for token


LENGTHS = [5, 8, 16, 32, 7]


def _run(cls, cfg, params, bk, burst, reqs, **kw):
    eng = cls(cfg, params, bk, max_seq=96, chunk_tokens=8,
              decode_burst=burst, **kw)
    return {r.uid: r.new_tokens for r in eng.run(reqs)}, eng


@pytest.mark.parametrize("cls,kw", [(InferenceEngine, {}),
                                    (PagedInferenceEngine,
                                     {"block_size": 16})])
def test_burst_matches_stepwise_greedy(stack, cls, kw):
    cfg, params, bk = stack
    step, _ = _run(cls, cfg, params, bk, 1, _reqs(cfg, LENGTHS, max_new=10),
                   **kw)
    burst, eng = _run(cls, cfg, params, bk, 4,
                      _reqs(cfg, LENGTHS, max_new=10), **kw)
    assert step == burst
    assert eng.fns.trace_counts["fused_burst"] >= 1   # the burst path ran


def test_burst_matches_stepwise_seeded_stochastic(stack):
    cfg, params, bk = stack
    mk = lambda: _reqs(cfg, LENGTHS, max_new=9, temperature=1.0, top_k=8)
    step, _ = _run(InferenceEngine, cfg, params, bk, 1, mk())
    burst, _ = _run(InferenceEngine, cfg, params, bk, 8, mk())
    assert step == burst


def test_burst_respects_eos_on_device(stack):
    # pick a token the greedy stream emits mid-stream, replay with it as
    # eos_id: both modes must truncate at the same point and complete
    cfg, params, bk = stack
    probe, _ = _run(InferenceEngine, cfg, params, bk, 1,
                    _reqs(cfg, [16], max_new=10))
    eos = probe[0][3]
    cut = probe[0].index(eos)
    step, _ = _run(InferenceEngine, cfg, params, bk, 1,
                   _reqs(cfg, [16], max_new=10, eos_id=eos))
    burst, eng = _run(InferenceEngine, cfg, params, bk, 8,
                      _reqs(cfg, [16], max_new=10, eos_id=eos))
    assert step == burst
    assert len(burst[0]) == cut + 1 and burst[0][-1] == eos


def test_burst_deltas_flush_per_burst(stack):
    # one burst step streams K tokens per active slot through the delta
    # buffer (the per-step streaming contract, K-deep)
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                          decode_burst=4)
    for r in _reqs(cfg, [8, 8], max_new=9):
        eng.submit(r)
    while eng.has_work():
        eng.step()
        deltas = eng.drain_deltas()
        per_uid = {}
        for uid, _t in deltas:
            per_uid[uid] = per_uid.get(uid, 0) + 1
        assert all(n <= 4 + 1 for n in per_uid.values())  # K (+first token)
        if per_uid and max(per_uid.values()) > 1:
            break
    else:
        pytest.fail("no burst step produced multi-token deltas")
    eng.run([])


# ---------------------------------------------------------------------------
# transfer guard: decode moves token ids, never logits


@pytest.mark.parametrize("instrumented", [False, True],
                         ids=["plain", "with-obs"])
def test_decode_step_moves_only_token_ids(stack, monkeypatch, instrumented):
    # with-obs: the PR-6 observability hooks (metrics registry +
    # lifecycle tracer) are host-side bookkeeping on the existing replay
    # path — tracing ON must not add a single device->host transfer
    cfg, params, bk = stack
    obs = bundle = None
    if instrumented:
        # full PR-7 plane: registry + tracer + chip-second ledger (live
        # meter attached, as the replica pool wires it) + flight ring —
        # the whole stack must stay host-side under the guard
        import time
        bundle = Observability()
        obs = bundle.engine_obs(SMOL, "trt")
        obs.meter = bundle.ledger.replica_up(SMOL, "trt", chips=1,
                                             cold_s=0.0,
                                             t=time.perf_counter())
    eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                          obs=obs)
    for r in _reqs(cfg, [16, 8, 5], max_new=16):
        eng.submit(r)
    while any(s.prefilling for s in eng._slots) or eng._queue:
        eng.step()                       # admission + prefill off-guard
    assert any(not s.done for s in eng._slots)

    pulled = []
    real_get = jax.device_get

    def spy_get(x):
        jax.tree_util.tree_map(lambda a: pulled.append(a), x)
        return real_get(x)

    monkeypatch.setattr(jax, "device_get", spy_get)
    # any implicit device->host transfer (e.g. np.asarray on the logits)
    # raises under the guard; the engine's explicit token pull is exempt
    with jax.transfer_guard_device_to_host("disallow"):
        with jax.transfer_guard_host_to_device("disallow"):
            for _ in range(3):
                eng.step()
    monkeypatch.undo()
    assert pulled, "decode steps pulled nothing?"
    for arr in pulled:
        assert np.asarray(arr).dtype == np.int32
        assert np.asarray(arr).size <= eng.max_batch
    if instrumented:
        # the guarded steps really were traced (ITL per decode token,
        # step-duration histogram) — from host stamps only
        assert obs.registry.histogram("itl_s", SMOL).count > 0
        assert obs.registry.histogram("engine_step_s", SMOL).count >= 3
        # ...and metered: the ledger attributed chip-seconds to the
        # active uids and the flight ring snapshotted each guarded step,
        # without tripping the transfer guard (zero new syncs)
        assert bundle.ledger.attributed_chip_s > 0.0
        assert len(bundle.flight.steps) >= 3
        assert all(s["model"] == SMOL for s in bundle.flight.steps)
    eng.run([])


# ---------------------------------------------------------------------------
# compile-count regression guard: step() must not retrace per step


def test_decode_step_does_not_retrace(stack):
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8)
    eng.run(_reqs(cfg, [8, 5], max_new=12, seed=1))          # warm
    n0 = eng.fns.trace_counts["fused_step"]
    assert n0 >= 1
    # wildly different batch compositions, lengths and sampling params
    # must all hit the same executable
    eng.run(_reqs(cfg, [5, 7, 16, 32, 8], max_new=4, seed=2))
    eng.run(_reqs(cfg, [16], max_new=20, seed=3, temperature=1.0, top_k=4))
    assert eng.fns.trace_counts["fused_step"] == n0


def test_burst_retrace_bounded_per_k(stack):
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96, chunk_tokens=8,
                          decode_burst=4)
    eng.run(_reqs(cfg, [8, 5], max_new=12, seed=1))
    n0 = eng.fns.trace_counts["fused_burst"]
    eng.run(_reqs(cfg, [5, 7, 16], max_new=9, seed=2))
    assert eng.fns.trace_counts["fused_burst"] == n0         # one trace per K


# ---------------------------------------------------------------------------
# batched first-token sampling (the _sample_one slow path is gone)


def test_first_tokens_batched_one_dispatch(stack):
    cfg, params, bk = stack
    assert not hasattr(InferenceEngine, "_sample_one")
    assert not hasattr(InferenceEngine, "_sample_batch")
    # several prompts completing prefill in the SAME step still respect
    # limits: max_new_tokens=1 returns exactly one token each
    eng = InferenceEngine(cfg, params, bk, max_seq=96)
    res = eng.run(_reqs(cfg, [8, 8, 8, 8], max_new=1))
    assert all(len(r.new_tokens) == 1 and r.completed for r in res)


# ---------------------------------------------------------------------------
# O(1) cancel index


def test_cancel_queued_is_tombstoned_o1(stack):
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96)
    reqs = _reqs(cfg, [8] * (eng.max_batch + 4), max_new=12)
    for r in reqs:
        eng.submit(r)
    victim = reqs[-2]                    # deep in the queue: no deque scan
    res = eng.cancel(victim.uid)
    assert res is not None and res.cancelled
    assert victim.cancelled              # tombstone, swept at admission
    assert eng.cancel(victim.uid) is None
    # backlog accounting excludes the tombstone immediately
    assert eng._queued() == len(reqs) - 1
    done = {r.uid for r in eng.run([])}
    assert victim.uid not in done
    assert done == {r.uid for r in reqs} - {victim.uid}
    assert not eng._by_uid               # index fully drained


def test_cancel_inflight_via_index_frees_blocks(stack):
    cfg, params, bk = stack
    eng = PagedInferenceEngine(cfg, params, bk, max_seq=96, block_size=16,
                               chunk_tokens=8)
    reqs = _reqs(cfg, [32, 16], max_new=24)
    for r in reqs:
        eng.submit(r)
    for _ in range(6):
        eng.step()
    assert isinstance(eng._by_uid[0], object) and 0 in eng._by_uid
    res = eng.cancel(0)
    assert res is not None and res.cancelled and not res.completed
    eng.run([])
    assert eng.pool.num_free + len(eng.prefix) == eng.num_blocks
    assert not eng._by_uid


def test_cancel_unknown_uid_returns_none(stack):
    cfg, params, bk = stack
    eng = InferenceEngine(cfg, params, bk, max_seq=96)
    assert eng.cancel(12345) is None
