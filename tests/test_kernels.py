"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles.

All kernels run in interpret mode on CPU (the kernel body executes as
traced JAX), which validates indexing, masking, accumulator and BlockSpec
logic — everything except Mosaic codegen itself.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.RandomState(0)


def _rand(shape, dtype):
    x = RNG.randn(*shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 2, 2, 32, 32, 32),      # MHA, square
    (2, 4, 2, 64, 64, 64),      # GQA 2:1
    (1, 8, 1, 32, 64, 32),      # MQA, Sq != Skv
    (2, 6, 2, 96, 96, 128),     # non-pow2 heads, MXU-width head dim
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24), (False, None)])
def test_flash_attention(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype):
    q = _rand((B, Hq, Sq, D), dtype)
    k = _rand((B, Hkv, Skv, D), dtype)
    v = _rand((B, Hkv, Skv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=16, block_k=16, interpret=True)
    want = ref.ref_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 64, 32),
    (3, 8, 2, 128, 64),
    (2, 16, 1, 64, 128),
])
@pytest.mark.parametrize("ring", [False, True])
def test_decode_attention(B, Hq, Hkv, S, D, ring, dtype):
    q = _rand((B, Hq, D), dtype)
    kc = _rand((B, Hkv, S, D), dtype)
    vc = _rand((B, Hkv, S, D), dtype)
    # mix of partially-filled and overflowing (ring) valid lengths
    vl = jnp.asarray(RNG.randint(1, 2 * S, size=(B,)), jnp.int32) if ring \
        else jnp.asarray(RNG.randint(1, S + 1, size=(B,)), jnp.int32)
    out = ops.decode_attention(q, kc, vc, vl, ring=ring, block_k=16,
                               interpret=True)
    want = ref.ref_decode_attention(q, kc, vc, vl, ring=ring)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,D,BS,NBseq,NB", [
    (1, 4, 4, 32, 16, 4, 8),        # MHA, small pool
    (3, 8, 2, 64, 16, 4, 24),       # GQA 4:1, tables permute the pool
    (2, 16, 1, 128, 32, 2, 6),      # MQA, MXU-width head dim
    (4, 6, 2, 32, 8, 6, 32),        # non-pow2 heads, more blocks than used
])
def test_paged_decode_attention(B, Hq, Hkv, D, BS, NBseq, NB, dtype):
    q = _rand((B, Hq, D), dtype)
    k_pool = _rand((NB, BS, Hkv, D), dtype)
    v_pool = _rand((NB, BS, Hkv, D), dtype)
    # each sequence leases distinct blocks scattered through the pool;
    # overlapping leases (shared prefix) are exercised by reusing seq 0's
    # first block for every sequence
    tables = np.stack([RNG.permutation(NB)[:NBseq] for _ in range(B)])
    tables[:, 0] = tables[0, 0]
    tables = jnp.asarray(tables, jnp.int32)
    vl = jnp.asarray(RNG.randint(1, NBseq * BS + 1, size=(B,)), jnp.int32)
    out = ops.paged_decode_attention(q, k_pool, v_pool, tables, vl,
                                     interpret=True)
    want = ref.ref_paged_decode_attention(q, k_pool, v_pool, tables, vl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Sb,Hq,Hkv,D,BS,NBctx,NB,start,s_real", [
    (16, 4, 4, 32, 16, 4, 8, 48, 16),     # MHA, full chunk, deep context
    (32, 8, 2, 64, 16, 4, 24, 24, 20),    # GQA 4:1, padded chunk, ragged ctx
    (8, 16, 1, 128, 32, 2, 6, 0, 5),      # MQA, NO cached context yet
    (16, 6, 2, 32, 8, 6, 32, 41, 16),     # non-pow2 heads, mid-block start
])
def test_paged_prefill_attention(Sb, Hq, Hkv, D, BS, NBctx, NB, start,
                                 s_real, dtype):
    q = _rand((Sb, Hq, D), dtype)
    k_pool = _rand((NB, BS, Hkv, D), dtype)
    v_pool = _rand((NB, BS, Hkv, D), dtype)
    k_new = _rand((Sb, Hkv, D), dtype)
    v_new = _rand((Sb, Hkv, D), dtype)
    table = jnp.asarray(RNG.permutation(NB)[:NBctx], jnp.int32)
    out = ops.paged_prefill_attention(q, k_pool, v_pool, k_new, v_new,
                                      table, start, s_real, interpret=True)
    want = ref.ref_paged_prefill_attention(q, k_pool, v_pool, k_new, v_new,
                                           table, start, s_real)
    # pad rows (>= s_real) are garbage by contract; compare live rows
    np.testing.assert_allclose(np.asarray(out, np.float32)[:s_real],
                               np.asarray(want, np.float32)[:s_real],
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_chunked_prefill_iterates_to_full_attention():
    """Appending a sequence chunk by chunk — each chunk attending the
    blocks written so far plus itself — reproduces whole-prompt causal
    attention exactly. This is the engine's chunked-prefill contract."""
    S, Hq, Hkv, D, BS, chunk = 64, 4, 2, 32, 16, 16
    q = _rand((S, Hq, D), jnp.float32)
    k = _rand((S, Hkv, D), jnp.float32)
    v = _rand((S, Hkv, D), jnp.float32)
    NB = S // BS + 1
    k_pool = jnp.zeros((NB, BS, Hkv, D), jnp.float32)
    v_pool = jnp.zeros((NB, BS, Hkv, D), jnp.float32)
    table = jnp.asarray(RNG.permutation(NB - 1) + 1, jnp.int32)  # 0 unused
    outs = []
    for start in range(0, S, chunk):
        sl = slice(start, start + chunk)
        outs.append(ops.paged_prefill_attention(
            q[sl], k_pool, v_pool, k[sl], v[sl], table, start, chunk,
            interpret=True))
        # scatter the chunk's KV into its blocks for the next iteration
        flat = table[(start + np.arange(chunk)) // BS] * BS \
            + (start + np.arange(chunk)) % BS
        k_pool = k_pool.reshape(NB * BS, Hkv, D).at[flat].set(k[sl]) \
            .reshape(NB, BS, Hkv, D)
        v_pool = v_pool.reshape(NB * BS, Hkv, D).at[flat].set(v[sl]) \
            .reshape(NB, BS, Hkv, D)
    got = jnp.concatenate(outs, axis=0)                  # (S, Hq, D)
    want = ref.ref_attention(q.transpose(1, 0, 2)[None],
                             k.transpose(1, 0, 2)[None],
                             v.transpose(1, 0, 2)[None], causal=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want[0].transpose(1, 0, 2)),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_matches_dense_decode():
    """A paged cache whose block table is the identity equals the dense
    decode kernel on the same data — the paging is layout, not math."""
    B, Hq, Hkv, D, BS, NBseq = 2, 8, 2, 64, 16, 4
    S = BS * NBseq
    q = _rand((B, Hq, D), jnp.float32)
    kc = _rand((B, Hkv, S, D), jnp.float32)
    vc = _rand((B, Hkv, S, D), jnp.float32)
    vl = jnp.asarray([S - 5, 17], jnp.int32)
    # (B, Hkv, S, D) -> per-sequence blocks stacked into one pool
    def to_pool(c):
        blocks = jnp.moveaxis(c, 1, 2).reshape(B, NBseq, BS, Hkv, D)
        return blocks.reshape(B * NBseq, BS, Hkv, D)
    tables = jnp.arange(B * NBseq, dtype=jnp.int32).reshape(B, NBseq)
    out = ops.paged_decode_attention(q, to_pool(kc), to_pool(vc), tables, vl,
                                     interpret=True)
    want = ops.decode_attention(q, kc, vc, vl, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,L,H,P,N,chunk", [
    (1, 32, 2, 8, 8, 8),
    (2, 64, 3, 16, 16, 16),
    (1, 128, 2, 32, 32, 32),
    (2, 48, 4, 16, 8, 16),      # L not a multiple of a larger chunk
])
def test_ssd_scan(B, L, H, P, N, chunk):
    x = _rand((B, L, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, L, H)) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.randn(H)) + 0.3, jnp.float32)
    Bm = _rand((B, L, H, N), jnp.float32)
    Cm = _rand((B, L, H, N), jnp.float32)
    y, st = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, str_ = ref.ref_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=3e-5, rtol=3e-5)


def test_ssd_scan_matches_model_chunked():
    """Pallas kernel == the pure-jnp chunked SSD used by the model trunk."""
    from repro.models.ssm import ssd_chunked
    B, L, H, P, N = 2, 64, 2, 16, 8
    x = _rand((B, L, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, L, H)) * 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.randn(H)) + 0.3, jnp.float32)
    Bm = _rand((B, L, H, N), jnp.float32)
    Cm = _rand((B, L, H, N), jnp.float32)
    y1, s1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    y2, s2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# kernel-dispatch registry: the Pallas kernels ARE the engine hot path


def test_kernel_mode_defaults_to_reference_on_cpu():
    assert jax.default_backend() != "tpu"
    assert ops.kernel_mode() == "reference"
    with ops.kernel_dispatch("interpret"):
        assert ops.kernel_mode() == "interpret"
    assert ops.kernel_mode() == "reference"
    with pytest.raises(ValueError):
        ops.set_kernel_mode("vulkan")


def test_engine_dispatches_pallas_kernels_token_for_token():
    """A paged engine traced under ``interpret`` dispatch runs the real
    Pallas kernel bodies for BOTH chunk prefill and decode, and emits
    exactly the reference trunk's greedy tokens — the contract that lets
    TPU swap in Mosaic without touching the engine."""
    import dataclasses

    from repro.configs.registry import ARCHS
    from repro.models import init_model
    from repro.serving import (PagedInferenceEngine, Request, SamplingParams,
                               get_backend)
    cfg = dataclasses.replace(ARCHS["smollm-360m"].reduced(), dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    bk = get_backend("trt")

    def run(mode, burst=1):
        rng = np.random.RandomState(3)
        reqs = [Request(uid=i, tokens=list(rng.randint(0, cfg.vocab_size, L)),
                        sampling=SamplingParams(max_new_tokens=5))
                for i, L in enumerate([5, 16, 33])]
        with ops.kernel_dispatch(mode):        # read at trace time
            eng = PagedInferenceEngine(cfg, params, bk, max_seq=96,
                                       block_size=16, chunk_tokens=8,
                                       decode_burst=burst)
            return {r.uid: r.new_tokens for r in eng.run(reqs)}

    reference = run("reference")
    assert run("interpret") == reference
    assert run("interpret", burst=4) == reference
