"""Training substrate: optimizer, chunked CE, loss goes down."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.data.pipeline import lm_batches
from repro.models import init_model
from repro.training.loss import chunked_cross_entropy, cross_entropy
from repro.training.optimizer import (AdamWConfig, adamw_update, global_norm,
                                      init_adamw, schedule_lr)
from repro.training.trainer import Trainer


def test_chunked_ce_equals_full_ce():
    rng = np.random.RandomState(0)
    B, S, d, V = 2, 24, 16, 64
    hidden = jnp.asarray(rng.randn(B, S, d), jnp.float32)
    w = jnp.asarray(rng.randn(V, d), jnp.float32)
    labels = jnp.asarray(rng.randint(0, V, (B, S)))
    full_logits = hidden @ w.T
    l_full, m_full = cross_entropy(full_logits, labels)
    for chunk in (5, 8, 24, 64):
        l_chunk, m_chunk = chunked_cross_entropy(hidden, w, labels, chunk=chunk)
        np.testing.assert_allclose(float(l_chunk), float(l_full), rtol=1e-5)
        np.testing.assert_allclose(float(m_chunk["token_acc"]),
                                   float(m_full["token_acc"]), rtol=1e-6)


def test_chunked_ce_grads_match():
    rng = np.random.RandomState(1)
    B, S, d, V = 2, 16, 8, 32
    hidden = jnp.asarray(rng.randn(B, S, d), jnp.float32)
    w = jnp.asarray(rng.randn(V, d), jnp.float32)
    labels = jnp.asarray(rng.randint(0, V, (B, S)))
    g_full = jax.grad(lambda h: cross_entropy(h @ w.T, labels)[0])(hidden)
    g_chunk = jax.grad(lambda h: chunked_cross_entropy(h, w, labels, chunk=8)[0])(hidden)
    np.testing.assert_allclose(np.asarray(g_chunk), np.asarray(g_full),
                               atol=1e-5, rtol=1e-4)


def test_adamw_moves_toward_minimum():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                      warmup_steps=0, total_steps=100, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_adamw(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw (w^2)
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                      schedule="constant", weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    opt = init_adamw(params)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(cfg, grads, opt, params)
    assert float(metrics["grad_norm"]) > 1e5     # reported pre-clip


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine", min_lr_frac=0.1)
    assert float(schedule_lr(cfg, jnp.int32(0))) < 0.2
    assert float(schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=0.1)
    assert float(schedule_lr(cfg, jnp.int32(99))) == pytest.approx(0.1, rel=0.2)


def test_loss_decreases_end_to_end():
    cfg = reduced_f32("smollm-360m")
    params = init_model(cfg, jax.random.PRNGKey(0))
    tr = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=25),
                 params, log_every=100)
    batches = lm_batches(cfg, 4, 32, n_prompts=100)
    first = next(batches)
    it = itertools.chain([first], batches)
    stats = tr.fit(it, steps=25, log=None)
    assert stats["loss"] < 5.0
    assert len(tr.history) >= 1
