"""Quickstart: the Pick-and-Spin public API in ~60 lines.

1. Pick a model pool (assigned archs, reduced variants so this runs on CPU).
2. Route prompts with the keyword router.
3. Let the multi-objective policy (Algorithm 2) pick (model x backend).
4. Serve through the real gateway: cold starts, warm pools, scale-to-zero.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import ARCHS
from repro.core.gateway import Gateway
from repro.core.scoring import PROFILES


def reduced(arch):
    return dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")


def main():
    # a small / medium / large pool from the assigned architectures
    pool = {name: reduced(name) for name in
            ("smollm-360m", "glm4-9b", "command-r-plus-104b")}
    # quality profile: relevance dominates, so tiers spread across the pool
    # (under `balanced`, cold-start-priced latency+cost keep traffic on the
    # small model until the big ones are warm — also correct behaviour)
    gw = Gateway(pool, profile=PROFILES["quality"], max_seq=96)

    prompts = [
        "List the sum of the first ten integers briefly",          # low
        "Summarize the dataset in the standard way",               # medium
        "Prove rigorously, step by step, that the bound holds",    # high
        "Define the term state machine in one line",               # low
    ]
    print(f"{'tier':7s} {'model':22s} {'backend':7s} {'cold(s)':>8s} "
          f"{'latency(s)':>11s} prompt")
    for p in prompts:
        r = gw.handle(p, max_new_tokens=8)
        print(f"{r.tier:7s} {r.model:22s} {r.backend:7s} "
              f"{r.cold_start_s:8.2f} {r.latency_s:11.3f} {p[:38]!r}")

    # Spin: scale the large model to zero, then watch the warm restart
    big = [m for m in pool if "command" in m][0]
    gw.scale_to_zero(big, "trt", keep_warm=True)
    r = gw.handle("Prove the theorem rigorously step by step",
                  max_new_tokens=4)
    print(f"\nafter scale-to-zero: {r.model} warm-restart "
          f"cold_start={r.cold_start_s:.2f}s (params were cached)")
    print("\nmeasured lifecycle events:", gw.cold_starts)


if __name__ == "__main__":
    main()
