"""End-to-end training driver: train a ~100M-param member of an assigned
architecture family for a few hundred steps on the synthetic corpus.

Default: smollm-family dense model scaled to ~100M params (d_model 512,
8 layers). Any assigned arch works via --arch (reduced variant).

Run: PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import ARCHS
from repro.data.pipeline import lm_batches
from repro.models import init_model
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def hundred_m_config():
    """~100M-param dense config of the smollm family."""
    return dataclasses.replace(
        ARCHS["smollm-360m"],
        name="smollm-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=49152,
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS),
                    help="train this arch's reduced variant instead")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_lm.ckpt")
    args = ap.parse_args()

    if args.arch:
        cfg = dataclasses.replace(ARCHS[args.arch].reduced(), dtype="float32")
    else:
        cfg = hundred_m_config()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.family}), {args.steps} steps")

    params = init_model(cfg, jax.random.PRNGKey(0))
    tr = Trainer(cfg,
                 AdamWConfig(lr=6e-4, warmup_steps=max(10, args.steps // 10),
                             total_steps=args.steps),
                 params, log_every=max(1, args.steps // 25))
    stats = tr.fit(lm_batches(cfg, args.batch, args.seq), steps=args.steps)
    print({k: round(float(v), 4) for k, v in stats.items()})
    from repro.checkpoint.checkpoint import save_pytree
    save_pytree(tr.params, args.ckpt)
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
