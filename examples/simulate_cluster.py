"""Cluster-scale what-if: run the paper's full experiment loop in the
discrete-event simulator with the FULL assigned architectures (104B/236B
in the pool) and compare operator profiles.

Run: PYTHONPATH=src python examples/simulate_cluster.py [--prompts 2000]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import ARCHS
from repro.core import (PROFILES, ClusterSimulator, KeywordRouter,
                        MultiObjectivePolicy, ServiceRegistry, SimConfig,
                        poisson_arrivals)
from repro.data.benchmarks import generate_corpus

POOL = ["smollm-360m", "zamba2-1.2b", "phi3-medium-14b", "glm4-9b",
        "command-r-plus-104b", "deepseek-v2-236b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompts", type=int, default=2000)
    ap.add_argument("--rate", type=float, default=8.0)
    args = ap.parse_args()

    prompts = generate_corpus(args.prompts, seed=21)
    decisions = KeywordRouter().route_many([p.text for p in prompts])
    arr = poisson_arrivals(prompts, args.rate, seed=21)
    workload = [(t, p, d) for (t, p), d in zip(arr, decisions)]
    models = {k: ARCHS[k] for k in POOL}

    print(f"pool: {', '.join(POOL)}")
    print(f"{'profile':10s} {'succ%':>7s} {'lat(s)':>8s} {'ttft_p50':>9s} "
          f"{'cost/q$':>9s} {'util%':>6s}")
    for pname, profile in PROFILES.items():
        reg = ServiceRegistry(models)
        sim = ClusterSimulator(reg, MultiObjectivePolicy(reg, seed=0),
                               profile, SimConfig(seed=0))
        rep = sim.run(workload)
        s = rep.summary()
        print(f"{pname:10s} {100*s['success_rate']:7.1f} "
              f"{s['mean_latency_s']:8.2f} {s['ttft_p50']:9.2f} "
              f"{s['cost_per_query_usd']:9.4f} "
              f"{100*s['gpu_utilization']:6.1f}")


if __name__ == "__main__":
    main()
