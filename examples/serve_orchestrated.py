"""End-to-end serving driver: batched requests through the full
Pick-and-Spin stack with the REAL engine (continuous batching, ragged
decode) — the paper's Figure-1 loop on live models, spoken entirely in
serving API v2 (``repro.api``).

Trains nothing, simulates nothing: routing -> Algorithm-2 selection ->
engine spin-up -> iteration-level batched decode, with telemetry flowing
back into the registry normalizers. With ``--concurrent``, requests
arrive open-loop (Poisson) into the ``ServeFrontend`` — replica pools,
priority-ordered bounded admission queues, and the live Algorithm-1 Spin
loop — instead of being served one at a time.

Run: PYTHONPATH=src python examples/serve_orchestrated.py [--requests 24]
     PYTHONPATH=src python examples/serve_orchestrated.py --concurrent --rate 8
     PYTHONPATH=src python examples/serve_orchestrated.py --shared-prefix
     PYTHONPATH=src python examples/serve_orchestrated.py --smoke   # CI gate
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import CompletionRequest, Priority
from repro.configs.registry import ARCHS
from repro.core.gateway import Gateway, ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES
from repro.data.benchmarks import generate_corpus
from repro.obs import write_metrics_dump


def _dump(frontend, path):
    """--metrics-dump: write the exposition + events + spans artifacts."""
    if not path or getattr(frontend, "obs", None) is None:
        return
    obs = frontend.obs
    paths = write_metrics_dump(path, obs.registry, events=obs.events,
                               tracer=obs.tracer)
    print(f"metrics dump: {', '.join(paths)}")


def _smol_pool():
    return {"smollm-360m":
            dataclasses.replace(ARCHS["smollm-360m"].reduced(),
                                dtype="float32")}


def shared_prefix_demo(args):
    """Multi-turn conversations as API-v2 SESSIONS: each conversation
    submits only its new turn with a ``session_id``; the frontend chains
    the token history, and the paged engines underneath lease cached
    system-prompt/history blocks instead of re-prefilling them —
    ``usage.cached_tokens`` shows it per response."""
    system = ("you are a terse assistant for arithmetic and list "
              "questions; answer with the number only. ")
    fe = ServeFrontend(_smol_pool(), router=KeywordRouter(),
                       profile=PROFILES[args.profile], max_seq=256,
                       spin=SpinConfig(tick_s=3600.0, max_replicas=1),
                       paged=True)
    turns = ["sum the numbers 3 5 8", "now add 11", "now subtract 4",
             "count the items apple pear plum"]
    convs = max(2, args.requests // len(turns))
    print(f"{convs} conversations x {len(turns)} turns, shared system "
          f"prompt ({len(system)} chars)\n")
    for t, turn in enumerate(turns):
        handles = [fe.submit(CompletionRequest(
            prompt=(system if t == 0 else "") + f"user {c}: {turn} ",
            max_new_tokens=6, session_id=f"conv-{c}"))
            for c in range(convs)]
        fe.serve_all()
        served = [h.response for h in handles if not h.shed]
        cached = sum(r.usage.cached_tokens for r in served)
        prompt = sum(r.usage.prompt_tokens for r in served)
        stats = fe.pool.kv_stats("smollm-360m") or {}
        print(f"turn {t}: served {len(served)}/{len(handles)}  "
              f"cached {cached}/{prompt} prompt tokens  "
              f"kv hit-rate={stats.get('kv_hit_rate', 0.0):.1%}  "
              f"pool occupancy={stats.get('kv_occupancy', 0.0):.1%}")
    eng = fe.pool.replicas("smollm-360m", "trt")[0]
    print(f"\nprefix cache: {eng.hit_tokens}/{eng.prompt_tokens} prompt "
          f"tokens served from cached KV blocks "
          f"({eng.prefix_hit_rate():.1%}) — the shared history was "
          f"prefilled once per turn, then leased by refcount")


def smoke(args):
    """CI gate over the public API surface: one pass each through
    streaming, sessions, priorities, cancellation and the sync facade.
    Exits non-zero if any contract breaks."""
    fe = ServeFrontend(_smol_pool(), router=KeywordRouter(), max_seq=96,
                       spin=SpinConfig(tick_s=3600.0, max_replicas=1),
                       paged=True,
                       flight_record=args.flight_record or None)
    # streaming: token events reproduce the final sequence exactly
    h = fe.submit("sum the numbers 3 5 8", max_new_tokens=6)
    streamed = [ev.token for ev in h.tokens() if ev.kind == "token"]
    assert streamed == h.response.new_tokens, (streamed, h.response)
    print(f"stream      ok: {len(streamed)} token events == new_tokens")
    # session: turn 2 rides the radix prefix cache
    r1 = fe.submit(CompletionRequest(prompt="count the items apple pear "
                                     "plum fig date", max_new_tokens=4,
                                     session_id="s")).result()
    r2 = fe.submit(CompletionRequest(prompt=" now add two more",
                                     max_new_tokens=4,
                                     session_id="s")).result()
    assert r2.usage.cached_tokens > 0, r2
    print(f"session     ok: turn-2 reused {r2.usage.cached_tokens} cached "
          f"prompt tokens (turn-1 model={r1.model})")
    # cancellation: slot + KV blocks come back
    hc = fe.submit("list everything at length", max_new_tokens=64)
    fe.step(), fe.step()
    assert hc.cancel() and hc.response.finish_reason == "cancelled"
    fe.serve_all()
    eng = fe.pool.replicas("smollm-360m", "trt")[0]
    assert eng.idle_slots() == eng.max_batch
    assert eng.kv_free_frac() == 1.0
    print(f"cancel      ok: slot + {eng.num_blocks} blocks back "
          f"({len(hc.response.new_tokens)} tokens were decoded)")
    # priority: a full queue sheds the queued BATCH request to admit the
    # INTERACTIVE arrival (low before high, structured shed result)
    fe.scheduler.cfg.max_queue_depth = 1
    blockers = [fe.submit(f"block {i}", max_new_tokens=16)
                for i in range(eng.max_batch)]        # fill every slot
    low = fe.submit("low priority work", max_new_tokens=2,
                    priority=Priority.BATCH)          # fills the queue
    hi = fe.submit("now please", max_new_tokens=2,
                   priority=Priority.INTERACTIVE)     # evicts `low`
    assert not hi.done()                              # admitted, in queue
    fe.serve_all()
    assert low.response.finish_reason == "shed"
    assert hi.response.ok
    assert all(b.response is not None for b in blockers)
    print("priority    ok: queued BATCH shed, INTERACTIVE served")
    # sync facade returns the same typed responses
    gw = Gateway(_smol_pool(), router=KeywordRouter(), max_seq=96)
    r = gw.handle("sum the numbers 3 5 8", max_new_tokens=6)
    assert r.completed and len(r.new_tokens) == 6 and r.cold_start_s > 0
    print(f"facade      ok: completed via {r.model}/{r.backend} "
          f"(cold_start={r.cold_start_s:.2f}s)")
    # observability: every completed request carries a full lifecycle
    # span, and the registry answers per-service tail quantiles live
    reg = fe.obs.registry
    assert reg.quantile("ttft_s", "smollm-360m", 0.95) > 0
    done = [s for s in fe.obs.tracer.finished if s.outcome in
            ("stop", "length")]
    assert done and all(s.complete() for s in done)
    print(f"obs         ok: {len(done)} complete spans, ttft p95="
          f"{reg.quantile('ttft_s', 'smollm-360m', 0.95):.3f}s")
    # cost attribution: every served request carries measured chip-
    # seconds and the ledger conserves them against the metered pool
    cost = reg.value("cost_per_query_usd", "smollm-360m")
    assert cost > 0, "no measured cost per query"
    assert r2.usage.chip_seconds > 0 and r2.usage.kv_peak_bytes > 0, r2
    err = fe.obs.ledger.conservation_error()
    assert err < 0.01, f"chip-second conservation broken: {err:.2%}"
    print(f"cost        ok: ${cost:.6f}/query measured, "
          f"conservation err {err:.3%}")
    _dump(fe, args.metrics_dump)
    if args.flight_record:
        p = fe.obs.flight.dump("on-demand", t=time.perf_counter())
        print(f"flight record: {p} ({len(fe.obs.flight.dumps)} dump(s))")
    print("\nAPI v2 smoke: all surfaces pass")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--profile", default="quality",
                    choices=sorted(PROFILES))
    ap.add_argument("--concurrent", action="store_true",
                    help="serve via the concurrent ServeFrontend plane")
    ap.add_argument("--rate", type=float, default=6.0,
                    help="open-loop arrival rate, rps (--concurrent)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="multi-turn session demo: conversations chain "
                         "via session_id, so the paged engines' radix "
                         "prefix cache skips most of each prefill")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate over the public API surface "
                         "(streaming, sessions, priorities, cancel, "
                         "sync facade)")
    ap.add_argument("--metrics-dump", default="",
                    help="write Prometheus exposition to PATH plus "
                         "PATH.events.jsonl and PATH.spans.jsonl")
    ap.add_argument("--flight-record", default="",
                    help="flight-recorder JSONL sink (anomaly dumps + "
                         "one on-demand dump at smoke exit)")
    args = ap.parse_args()

    if args.smoke:
        return smoke(args)
    if args.shared_prefix:
        return shared_prefix_demo(args)

    pool = {name: dataclasses.replace(ARCHS[name].reduced(), dtype="float32")
            for name in ("smollm-360m", "zamba2-1.2b", "phi3-medium-14b",
                         "command-r-plus-104b")}
    prompts = generate_corpus(max(args.requests, 64), seed=11)[:args.requests]

    if args.concurrent:
        if args.rate <= 0:
            ap.error("--rate must be > 0 (open-loop arrivals per second)")
        spin = SpinConfig(window_s=60.0, cooldown_s=0.5, idle_tau_s=2.0,
                          tick_s=0.2, max_replicas=4)
        fe = ServeFrontend(pool, router=KeywordRouter(),
                           profile=PROFILES[args.profile], max_seq=96,
                           spin=spin)
        rng = np.random.RandomState(5)
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                             size=len(prompts)))
        reqs = [CompletionRequest(prompt=p.text, max_new_tokens=8,
                                  deadline_s=120.0) for p in prompts]
        handles, wall = fe.serve_open_loop(reqs, arrivals)
        fe.settle(timeout_s=spin.idle_tau_s + 1.0)
        results = [h.response for h in handles if not h.shed]
        gw = fe
    else:
        gw = Gateway(pool, router=KeywordRouter(),
                     profile=PROFILES[args.profile], max_seq=96)
        t0 = time.perf_counter()
        results = [gw.handle(p.text, max_new_tokens=8, deadline_s=120.0)
                   for p in prompts]
        wall = time.perf_counter() - t0

    by_model = {}
    for r in results:
        by_model.setdefault(r.model, []).append(r)
    print(f"\nserved {len(results)} requests in {wall:.1f}s "
          f"(profile={args.profile})")
    print(f"{'model':22s} {'n':>4s} {'tiers':18s} {'mean_lat(s)':>11s} "
          f"{'completed':>9s}")
    for m, rs in sorted(by_model.items()):
        tiers = ",".join(sorted({r.tier for r in rs}))
        lat = np.mean([r.latency_s for r in rs])
        done = sum(r.completed for r in rs)
        print(f"{m:22s} {len(rs):4d} {tiers:18s} {lat:11.3f} "
              f"{done:6d}/{len(rs)}")
    colds = [c for _, c in gw.cold_starts]
    print(f"\ncold starts paid: {len(colds)} "
          f"(total {sum(colds):.1f}s, max {max(colds):.1f}s) — "
          f"Spin amortizes these across the workload")
    if args.concurrent:
        print("\nlive Spin decisions (Algorithm 1 on real engines):")
        for e in gw.orch_events:
            print(f"  {e}")
    _dump(gw, args.metrics_dump)       # Gateway proxies .obs too


if __name__ == "__main__":
    main()
