"""End-to-end serving driver: batched requests through the full
Pick-and-Spin stack with the REAL engine (continuous batching, ragged
decode) — the paper's Figure-1 loop on live models.

Trains nothing, simulates nothing: routing -> Algorithm-2 selection ->
engine spin-up -> iteration-level batched decode, with telemetry flowing
back into the registry normalizers. With ``--concurrent``, requests
arrive open-loop (Poisson) into the AsyncGateway serve plane — replica
pools, bounded admission queues, and the live Algorithm-1 Spin loop —
instead of being served one at a time.

Run: PYTHONPATH=src python examples/serve_orchestrated.py [--requests 24]
     PYTHONPATH=src python examples/serve_orchestrated.py --concurrent --rate 8
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import ARCHS
from repro.core.gateway import AsyncGateway, Gateway, serve_open_loop
from repro.core.orchestrator import SpinConfig
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES
from repro.data.benchmarks import generate_corpus


def shared_prefix_demo(args):
    """Multi-turn conversations through the AsyncGateway: the paged
    engines underneath lease cached system-prompt/history blocks instead
    of re-prefilling them, and the pool's hit-rate shows it."""
    system = ("you are a terse assistant for arithmetic and list "
              "questions; answer with the number only. ")
    pool = {"smollm-360m":
            dataclasses.replace(ARCHS["smollm-360m"].reduced(),
                                dtype="float32")}
    gw = AsyncGateway(pool, router=KeywordRouter(),
                      profile=PROFILES[args.profile], max_seq=256,
                      spin=SpinConfig(tick_s=3600.0, max_replicas=1),
                      paged=True)
    turns = ["sum the numbers 3 5 8", "now add 11", "now subtract 4",
             "count the items apple pear plum"]
    convs = max(2, args.requests // len(turns))
    print(f"{convs} conversations x {len(turns)} turns, shared system "
          f"prompt ({len(system)} chars)\n")
    history = {c: system + f"user {c}: " for c in range(convs)}
    for t, turn in enumerate(turns):
        uids = {}
        for c in range(convs):
            history[c] += turn + " "
            uids[c] = gw.submit(history[c], max_new_tokens=6)
        gw.serve_all()
        served = 0
        for c, u in uids.items():
            r = gw.poll(u) if u is not None else None   # u None => shed
            if r is None:
                continue
            served += 1
            history[c] += "".join(chr(max(32, tok % 95 + 32))
                                  for tok in r.new_tokens) + " "
        stats = gw.pool.kv_stats("smollm-360m") or {}
        print(f"turn {t}: served {served}/{len(uids)}  "
              f"kv hit-rate={stats.get('kv_hit_rate', 0.0):.1%}  "
              f"pool occupancy={stats.get('kv_occupancy', 0.0):.1%}")
    eng = gw.pool.replicas("smollm-360m", "trt")[0]
    print(f"\nprefix cache: {eng.hit_tokens}/{eng.prompt_tokens} prompt "
          f"tokens served from cached KV blocks "
          f"({eng.prefix_hit_rate():.1%}) — the shared system prompt was "
          f"prefilled once, then leased by refcount")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--profile", default="quality",
                    choices=sorted(PROFILES))
    ap.add_argument("--concurrent", action="store_true",
                    help="serve via the concurrent AsyncGateway plane")
    ap.add_argument("--rate", type=float, default=6.0,
                    help="open-loop arrival rate, rps (--concurrent)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="multi-turn demo: every request shares a system "
                         "prompt, so the paged engines' radix prefix "
                         "cache skips most of each prefill (watch the "
                         "kv-cache log lines)")
    args = ap.parse_args()

    if args.shared_prefix:
        return shared_prefix_demo(args)

    pool = {name: dataclasses.replace(ARCHS[name].reduced(), dtype="float32")
            for name in ("smollm-360m", "zamba2-1.2b", "phi3-medium-14b",
                         "command-r-plus-104b")}
    prompts = generate_corpus(max(args.requests, 64), seed=11)[:args.requests]

    if args.concurrent:
        if args.rate <= 0:
            ap.error("--rate must be > 0 (open-loop arrivals per second)")
        spin = SpinConfig(window_s=60.0, cooldown_s=0.5, idle_tau_s=2.0,
                          tick_s=0.2, max_replicas=4)
        gw = AsyncGateway(pool, router=KeywordRouter(),
                          profile=PROFILES[args.profile], max_seq=96,
                          spin=spin)
        rng = np.random.RandomState(5)
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                             size=len(prompts)))
        jobs = [(p.text, dict(max_new_tokens=8, deadline_s=120.0))
                for p in prompts]
        uids, wall = serve_open_loop(gw, jobs, arrivals)
        gw.settle(timeout_s=spin.idle_tau_s + 1.0)
        results = [r for r in (gw.poll(u) for u in uids if u is not None)
                   if r is not None]
    else:
        gw = Gateway(pool, router=KeywordRouter(),
                     profile=PROFILES[args.profile], max_seq=96)
        t0 = time.perf_counter()
        results = [gw.handle(p.text, max_new_tokens=8, deadline_s=120.0)
                   for p in prompts]
        wall = time.perf_counter() - t0

    by_model = {}
    for r in results:
        by_model.setdefault(r.model, []).append(r)
    print(f"\nserved {len(results)} requests in {wall:.1f}s "
          f"(profile={args.profile})")
    print(f"{'model':22s} {'n':>4s} {'tiers':18s} {'mean_lat(s)':>11s} "
          f"{'completed':>9s}")
    for m, rs in sorted(by_model.items()):
        tiers = ",".join(sorted({r.tier for r in rs}))
        lat = np.mean([r.latency_s for r in rs])
        done = sum(r.completed for r in rs)
        print(f"{m:22s} {len(rs):4d} {tiers:18s} {lat:11.3f} "
              f"{done:6d}/{len(rs)}")
    colds = [c for _, c in gw.cold_starts]
    print(f"\ncold starts paid: {len(colds)} "
          f"(total {sum(colds):.1f}s, max {max(colds):.1f}s) — "
          f"Spin amortizes these across the workload")
    if args.concurrent:
        print("\nlive Spin decisions (Algorithm 1 on real engines):")
        for e in gw.orch_events:
            print(f"  {e}")


if __name__ == "__main__":
    main()
