"""End-to-end serving driver: batched requests through the full
Pick-and-Spin stack with the REAL engine (continuous batching, ragged
decode) — the paper's Figure-1 loop on live models.

Trains nothing, simulates nothing: routing -> Algorithm-2 selection ->
engine spin-up -> iteration-level batched decode, with telemetry flowing
back into the registry normalizers.

Run: PYTHONPATH=src python examples/serve_orchestrated.py [--requests 24]
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import ARCHS
from repro.core.gateway import Gateway
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES
from repro.data.benchmarks import generate_corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--profile", default="quality",
                    choices=sorted(PROFILES))
    args = ap.parse_args()

    pool = {name: dataclasses.replace(ARCHS[name].reduced(), dtype="float32")
            for name in ("smollm-360m", "zamba2-1.2b", "phi3-medium-14b",
                         "command-r-plus-104b")}
    gw = Gateway(pool, router=KeywordRouter(),
                 profile=PROFILES[args.profile], max_seq=96)

    prompts = generate_corpus(max(args.requests, 64), seed=11)[:args.requests]
    t0 = time.perf_counter()
    results = [gw.handle(p.text, max_new_tokens=8, deadline_s=120.0)
               for p in prompts]
    wall = time.perf_counter() - t0

    by_model = {}
    for r in results:
        by_model.setdefault(r.model, []).append(r)
    print(f"\nserved {len(results)} requests in {wall:.1f}s "
          f"(profile={args.profile})")
    print(f"{'model':22s} {'n':>4s} {'tiers':18s} {'mean_lat(s)':>11s} "
          f"{'completed':>9s}")
    for m, rs in sorted(by_model.items()):
        tiers = ",".join(sorted({r.tier for r in rs}))
        lat = np.mean([r.latency_s for r in rs])
        done = sum(r.completed for r in rs)
        print(f"{m:22s} {len(rs):4d} {tiers:18s} {lat:11.3f} "
              f"{done:6d}/{len(rs)}")
    colds = [c for _, c in gw.cold_starts]
    print(f"\ncold starts paid: {len(colds)} "
          f"(total {sum(colds):.1f}s, max {max(colds):.1f}s) — "
          f"Spin amortizes these across the workload")


if __name__ == "__main__":
    main()
