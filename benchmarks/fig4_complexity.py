"""Paper Fig. 4: complexity distributions, keyword vs DistilBERT routing.

Reports the tier distribution each router assigns, its agreement with the
ground truth, and the separation (total-variation distance) between the
two routers' distributions — the paper's "clear separation supports
relevance-driven routing" claim.
"""
from __future__ import annotations

import time
from collections import Counter

import numpy as np

from common import BenchTimer, corpus, routers, save_result
from repro.data.benchmarks import TIERS
from typing import Optional


def run(n_prompts: int = 1500, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=7)
    texts = [p.text for p in prompts]
    gold = Counter(p.complexity for p in prompts)
    rts = routers()
    t0 = time.perf_counter()
    dists, accs = {}, {}
    for name in ("keyword", "distilbert"):
        ds = rts[name].route_many(texts)
        dists[name] = Counter(d.tier for d in ds)
        accs[name] = float(np.mean([d.tier == p.complexity
                                    for d, p in zip(ds, prompts)]))
    wall = time.perf_counter() - t0

    n = len(prompts)
    print("\n== Fig 4: complexity distributions ==")
    print(f"{'tier':8s} {'gold%':>7s} {'keyword%':>9s} {'distilbert%':>12s}")
    tv = 0.0
    for t in TIERS:
        kw = dists["keyword"][t] / n
        db = dists["distilbert"][t] / n
        tv += 0.5 * abs(kw - db)
        print(f"{t:8s} {100*gold[t]/n:7.1f} {100*kw:9.1f} {100*db:12.1f}")
    print(f"tier accuracy: keyword={100*accs['keyword']:.1f}% "
          f"distilbert={100*accs['distilbert']:.1f}%; "
          f"TV distance between routers = {tv:.3f}")
    save_result("fig4_complexity", {
        "gold": {t: gold[t] / n for t in TIERS},
        **{name: {t: dists[name][t] / n for t in TIERS} for name in dists},
        "accuracy": accs, "tv_distance": tv})
    if timer:
        timer.add("fig4_complexity", 2 * n, wall,
                  f"kw_acc={accs['keyword']:.3f};db_acc={accs['distilbert']:.3f}")
    return accs


if __name__ == "__main__":
    run()
