"""Paper Figs. 10-11: TTFT (median + P50/P95/P99) per routing strategy.

The paper's headline: DistilBERT routing adds ~23.5% median TTFT over
keyword routing (classification hop + heavier tiers) but buys semantic
relevance. Measured under identical load via the simulator.
"""
from __future__ import annotations

import time

from typing import Optional

from common import (BenchTimer, PROFILES, corpus, make_workload, routers,
                    run_sim, save_result)


def run(n_prompts: int = 1500, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=8)
    texts = [p.text for p in prompts]
    rts = routers()
    results = {}
    print("\n== Fig 10/11: TTFT percentiles ==")
    print(f"{'strategy':12s} {'median':>8s} {'p50':>8s} {'p95':>8s} {'p99':>8s}")
    from repro.core import SimConfig, SpinConfig
    for name in ("keyword", "distilbert"):
        decisions = rts[name].route_many(texts)
        # constrained capacity so queueing dominates TTFT (the regime the
        # paper measured: tens of seconds median on a small GPU fleet)
        workload = make_workload(prompts, decisions, rate=30.0, seed=8)
        t0 = time.perf_counter()
        rep, _ = run_sim("multi_objective", PROFILES["balanced"], workload,
                         seed=8, sim_cfg=SimConfig(
                             seed=8, spin=SpinConfig(max_replicas=2)))
        wall = time.perf_counter() - t0
        ss = rep.steady_state()              # exclude cold-start warmup
        pct = ss.ttft_percentiles()
        results[name] = {"median": ss.median_ttft(), **pct}
        print(f"{name:12s} {ss.median_ttft():8.2f} {pct['p50']:8.2f} "
              f"{pct['p95']:8.2f} {pct['p99']:8.2f}")
        if timer:
            timer.add(f"ttft_{name}", len(prompts), wall,
                      f"p50={pct['p50']:.2f}s;p99={pct['p99']:.2f}s")
    kw, db = results["keyword"]["median"], results["distilbert"]["median"]
    if kw > 0:
        print(f"\nderived: distilbert median TTFT {100*(db/kw-1):+.1f}% vs "
              f"keyword (paper: +23.5%, 45.5s -> 56.2s)")
    save_result("fig_ttft", results)
    return results


if __name__ == "__main__":
    run()
