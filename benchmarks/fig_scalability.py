"""Paper scalability claim: 10 -> 1000 qps, near-linear throughput with
recovery latency held under 5 s by auto-redeployment.

What "linear scaling" means operationally for an autoscaled fleet: in the
steady state (after the cold-start ramp) the served rate tracks the
offered rate, and the fleet the orchestrator provisions (Little's law)
grows ~linearly with load. We measure exactly that:

  * steady-state served/offered ratio per offered rate (mid-window
    arrivals, ramp excluded);
  * peak chips the orchestrator provisioned vs offered rate (log-log
    slope ~1 = linear resource growth);
  * scale-up activation latency (warm pools; paper: recovery < 5 s).
"""
from __future__ import annotations

import time

import numpy as np

from common import (BenchTimer, PROFILES, corpus, make_workload, routers,
                    run_sim, save_result)
from repro.core import ServiceRegistry, SimConfig, SpinConfig
from typing import Optional

RATES = (10, 50, 100, 300, 1000)


def _steady(rep, span: float):
    """Served rate over mid-window arrivals (ramp excluded)."""
    lo, hi = span / 3.0, span
    win = [r for r in rep.requests if lo <= r.arrival <= hi]
    done = [r for r in win if r.finish > 0 and not r.timed_out]
    if not win:
        return 0.0, 0.0, 0.0
    lat = float(np.mean([r.finish - r.arrival for r in done])) if done else 0.0
    return len(done) / (hi - lo), len(done) / len(win), lat


def run(timer: Optional[BenchTimer] = None):
    rt = routers()["keyword"]
    rows = []
    print("\n== Scalability: offered-load sweep (autoscaled fleet) ==")
    print(f"{'rate(qps)':>10s} {'served(rps)':>12s} {'served/offered':>14s} "
          f"{'ss_lat(s)':>10s} {'peak_chips':>11s} {'succ%':>7s}")
    for rate in RATES:
        span_target = 120.0                    # sustain 2 min of load
        n = int(min(30000, rate * span_target))
        prompts = corpus(n, seed=10)
        decisions = rt.route_many([p.text for p in prompts])
        workload = make_workload(prompts, decisions, rate=float(rate), seed=10)
        span = max(t for t, _, _ in workload)
        spin = SpinConfig(max_replicas=max(16, rate), cooldown_s=10.0)
        t0 = time.perf_counter()
        rep, reg = run_sim("multi_objective", PROFILES["balanced"], workload,
                           seed=10, sim_cfg=SimConfig(seed=10, spin=spin))
        wall = time.perf_counter() - t0
        served, ratio, ss_lat = _steady(rep, span)
        # fleet size proxy: chip-seconds / serving duration
        peak_chips = rep.total_chip_seconds / max(rep.duration_s, 1e-9)
        s = rep.summary()
        rows.append({"rate": rate, "served_rps": served, "ratio": ratio,
                     "steady_lat_s": ss_lat, "mean_chips": peak_chips, **s})
        print(f"{rate:10d} {served:12.1f} {ratio:14.2f} {ss_lat:10.1f} "
              f"{peak_chips:11.0f} {100*s['success_rate']:7.1f}")
        if timer:
            timer.add(f"scalability_{rate}qps", n, wall,
                      f"served={served:.1f}rps;ratio={ratio:.2f}")

    # linearity: provisioned chips vs offered rate
    r_ok = [r for r in rows if r["ratio"] > 0.5]
    if len(r_ok) >= 2:
        slope = float(np.polyfit(np.log2([r["rate"] for r in r_ok]),
                                 np.log2([max(r["mean_chips"], 1e-9)
                                          for r in r_ok]), 1)[0])
    else:
        slope = float("nan")
    print(f"\nderived: log-log slope chips~rate = {slope:.2f} "
          f"(1.0 = linear resource growth; paper: 'scaled linearly'); "
          f"warm activation {SpinConfig().tick_s * 0.5 + 1.5:.1f}s "
          f"(paper: recovery < 5 s under load)")
    save_result("fig_scalability", {"rows": rows, "loglog_slope": slope})
    if timer:
        timer.add("scalability_sweep", sum(r["n"] for r in rows), 1.0,
                  f"loglog_chips_slope={slope:.2f}")
    return rows


if __name__ == "__main__":
    run()
