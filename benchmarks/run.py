"""Benchmark driver — one benchmark per paper table/figure.

Prints the ``name,us_per_call,derived`` CSV contract at the end, after the
per-table human-readable reports. JSON payloads land in
benchmarks/artifacts/results/.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from common import BenchTimer  # noqa: E402

import beyond_bandit  # noqa: E402
import engine_bench  # noqa: E402
import fig4_complexity  # noqa: E402
import fig_scalability  # noqa: E402
import fig_ttft  # noqa: E402
import roofline_report  # noqa: E402
import table1_baseline  # noqa: E402
import table2_routing  # noqa: E402
import table3_matrix  # noqa: E402
import table4_scaling  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI mode)")
    ap.add_argument("--skip-engine", action="store_true")
    args = ap.parse_args()
    n = 600 if args.fast else 1500

    timer = BenchTimer()
    table1_baseline.run(n_prompts=max(n, 1200), timer=timer)
    table2_routing.run(n_prompts=n, timer=timer)
    table3_matrix.run(n_prompts=n, timer=timer)
    table4_scaling.run(n_prompts=n, timer=timer)
    fig4_complexity.run(n_prompts=n, timer=timer)
    fig_ttft.run(n_prompts=n, timer=timer)
    fig_scalability.run(timer=timer)
    beyond_bandit.run(n_prompts=min(4000, 3 * n), timer=timer)
    roofline_report.run(timer=timer)
    if not args.skip_engine:
        engine_bench.run(timer=timer)

    print("\n== CSV (name,us_per_call,derived) ==")
    timer.emit()


if __name__ == "__main__":
    main()
