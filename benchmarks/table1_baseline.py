"""Paper Table 1: baseline inference completion per benchmark.

Setup matches the paper's: a single static default deployment (no
orchestration, no routing — every prompt to the default medium model's
default backend), success = valid completion within time/token limits.
Reported next to the paper's numbers.
"""
from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from common import (BenchTimer, DEFAULT_MODEL, PROFILES, corpus,
                    make_workload, run_sim, save_result)
from repro.core import KeywordRouter
from repro.data.benchmarks import BENCHMARK_STATS
from typing import Optional

PAPER = {k: v["base_success"] for k, v in BENCHMARK_STATS.items()}


def run(n_prompts: int = 2000, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=1)
    decisions = KeywordRouter().route_many([p.text for p in prompts])
    workload = make_workload(prompts, decisions, rate=6.0, seed=1)
    t0 = time.perf_counter()
    # static single-model deployment: restrict the pool to the default
    rep, _ = run_sim("random", PROFILES["balanced"], workload, static=True,
                     pool=[DEFAULT_MODEL], seed=1)
    wall = time.perf_counter() - t0

    by_bench = defaultdict(list)
    for r in rep.requests:
        by_bench[r.prompt.benchmark].append(r.success)
    rows = []
    print(f"\n== Table 1: baseline completion (n={len(rep.requests)}) ==")
    print(f"{'benchmark':12s} {'n':>6s} {'success%':>9s} {'paper%':>7s}")
    for bench, stats in BENCHMARK_STATS.items():
        ours = float(np.mean(by_bench[bench])) if by_bench[bench] else 0.0
        rows.append({"benchmark": bench, "n": len(by_bench[bench]),
                     "success": ours, "paper": PAPER[bench]})
        print(f"{bench:12s} {len(by_bench[bench]):6d} {100*ours:9.1f} "
              f"{100*PAPER[bench]:7.1f}")
    total = rep.success_rate()
    print(f"{'TOTAL':12s} {len(rep.requests):6d} {100*total:9.1f}    77.1")
    save_result("table1_baseline", {"rows": rows, "total": total,
                                    "paper_total": 0.771})
    if timer:
        timer.add("table1_baseline", len(rep.requests), wall,
                  f"success={total:.3f};paper=0.771")
    return total


if __name__ == "__main__":
    run()
