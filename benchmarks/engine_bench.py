"""Real-engine microbenchmarks (CPU, reduced models): per-backend decode
step time, prefill time, and measured cold vs warm start — the calibration
source for the simulator's small-arch constants.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from common import BenchTimer, save_result
from repro.configs.registry import ARCHS
from repro.models import init_model
from repro.serving import (BACKENDS, InferenceEngine, Request,
                           SamplingParams)


def run(timer: BenchTimer = None, arch: str = "smollm-360m"):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    rng = np.random.RandomState(0)
    results = {}
    print(f"\n== Engine microbench ({cfg.name}, CPU) ==")
    print(f"{'backend':8s} {'cold(s)':>8s} {'ttft(ms)':>9s} "
          f"{'decode(ms/tok)':>15s} {'tok/s':>7s}")
    params = init_model(cfg, jax.random.PRNGKey(0))
    for bname, backend in BACKENDS.items():
        t0 = time.perf_counter()
        eng = InferenceEngine(cfg, params, backend, max_seq=96)
        # cold start = build + first compile
        warm = eng.run([Request(uid=-1, tokens=[1, 2, 3],
                                sampling=SamplingParams(max_new_tokens=2))])
        cold_s = time.perf_counter() - t0
        reqs = [Request(uid=i,
                        tokens=list(rng.randint(0, cfg.vocab_size, 24)),
                        sampling=SamplingParams(max_new_tokens=12))
                for i in range(backend.max_batch)]
        t0 = time.perf_counter()
        res = eng.run(reqs)
        wall = time.perf_counter() - t0
        n_tok = sum(len(r.new_tokens) for r in res)
        ttft = float(np.mean([r.ttft for r in res]))
        per_tok = wall / max(n_tok, 1)
        results[bname] = {"cold_s": cold_s, "ttft_ms": 1e3 * ttft,
                          "decode_ms_per_tok": 1e3 * per_tok,
                          "tok_per_s": n_tok / wall}
        print(f"{bname:8s} {cold_s:8.2f} {1e3*ttft:9.1f} "
              f"{1e3*per_tok:15.2f} {n_tok/wall:7.1f}")
        if timer:
            timer.add(f"engine_{bname}", n_tok, wall,
                      f"tok/s={n_tok/wall:.1f};cold={cold_s:.2f}s")
    save_result("engine_bench", results)
    return results


if __name__ == "__main__":
    run()
