"""Real-engine microbenchmarks (CPU, reduced models): per-backend decode
step time, prefill time, and measured cold vs warm start — the calibration
source for the simulator's small-arch constants.

``--decode`` (also run standalone as the CI smoke step) measures the
DEVICE-RESIDENT DECODE HOT PATH: stepwise fused decoding (one dispatch +
one (max_batch,) token pull per token) against ``decode_burst=K`` (K
fused iterations inside one ``lax.scan`` dispatch), on the same reduced
arch, greedy, with token-for-token equivalence asserted. The artifact is
BENCH_decode.json — ``burst_speedup`` is the acceptance gauge (>= 1.3x).

``--spec`` adds the SPECULATIVE DECODING mode: plain fused stepwise vs
draft/verify spec decode at K in {2, 4, 8} on a depth-extended smoke
target (see ``_spec_pair``), per-rep token equality asserted, acceptance
rate and ``spec_speedup`` (>= 1.3x gauge) merged into BENCH_decode.json.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from common import BenchTimer, save_bench, save_result
from repro.configs.registry import ARCHS
from repro.models import init_model
from repro.obs import Observability
from typing import Optional

from repro.serving import (BACKENDS, InferenceEngine, PagedInferenceEngine,
                           Request, SamplingParams, SpecDraft)
from repro.serving.engine import FINISH_EOS, FINISH_MAX_NEW, FINISH_ROOM


def run(timer: Optional[BenchTimer] = None, arch: str = "smollm-360m"):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    rng = np.random.RandomState(0)
    results = {}
    print(f"\n== Engine microbench ({cfg.name}, CPU) ==")
    print(f"{'backend':8s} {'cold(s)':>8s} {'ttft(ms)':>9s} "
          f"{'decode(ms/tok)':>15s} {'tok/s':>7s}")
    params = init_model(cfg, jax.random.PRNGKey(0))
    for bname, backend in BACKENDS.items():
        t0 = time.perf_counter()
        eng = InferenceEngine(cfg, params, backend, max_seq=96)
        # cold start = build + first compile
        warm = eng.run([Request(uid=-1, tokens=[1, 2, 3],
                                sampling=SamplingParams(max_new_tokens=2))])
        cold_s = time.perf_counter() - t0
        reqs = [Request(uid=i,
                        tokens=list(rng.randint(0, cfg.vocab_size, 24)),
                        sampling=SamplingParams(max_new_tokens=12))
                for i in range(backend.max_batch)]
        t0 = time.perf_counter()
        res = eng.run(reqs)
        wall = time.perf_counter() - t0
        n_tok = sum(len(r.new_tokens) for r in res)
        ttft = float(np.mean([r.ttft for r in res]))
        per_tok = wall / max(n_tok, 1)
        results[bname] = {"cold_s": cold_s, "ttft_ms": 1e3 * ttft,
                          "decode_ms_per_tok": 1e3 * per_tok,
                          "tok_per_s": n_tok / wall}
        print(f"{bname:8s} {cold_s:8.2f} {1e3*ttft:9.1f} "
              f"{1e3*per_tok:15.2f} {n_tok/wall:7.1f}")
        if timer:
            timer.add(f"engine_{bname}", n_tok, wall,
                      f"tok/s={n_tok/wall:.1f};cold={cold_s:.2f}s")
    save_result("engine_bench", results)
    return results


def _host_reason(eng, s) -> int:
    """Host replay of the device-side finish bits for the PR-4 baseline
    classes below (the production engines now compute these on device;
    the legacy reconstruction keeps the host rules so its bookkeeping
    matches ``_consume_reason``'s contract)."""
    sp = s.req.sampling
    bits = 0
    if sp.eos_id is not None and s.res.new_tokens[-1] == sp.eos_id:
        bits |= FINISH_EOS
    if len(s.res.new_tokens) >= sp.max_new_tokens:
        bits |= FINISH_MAX_NEW
    if s.pos >= eng.max_seq - 1:
        bits |= FINISH_ROOM
    return bits


class _Pr4StepwisePaged(PagedInferenceEngine):
    """The PR-4 decode iteration, reconstructed around the SAME compiled
    model functions: host ``np`` staging arrays (tokens / positions /
    block tables) rebuilt and re-uploaded every step, a separate decode
    dispatch, then host-side sampling (device argmax + per-step host
    pull). This is the baseline the fused device-resident step replaced
    — kept here so BENCH_decode.json tracks the speedup against it."""

    def _decode_once(self, active):
        import jax.numpy as jnp
        tokens = np.zeros((self.max_batch, 1), np.int32)
        pos = np.full((self.max_batch,), -1, np.int32)
        for i in active:
            s = self._slots[i]
            tokens[i, 0] = (s.res.new_tokens[-1] if s.res.new_tokens
                            else s.req.tokens[-1])
            pos[i] = s.pos
        tables = np.zeros((self.max_batch, self.blocks_per_seq), np.int32)
        for i, s in enumerate(self._slots):
            if not s.done and s.table is not None:
                tables[i] = s.table
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(tables), jnp.asarray(pos))
        am = np.asarray(jnp.argmax(logits, axis=-1))     # greedy bench
        t = time.perf_counter()
        for i in active:
            s = self._slots[i]
            tok = int(am[i])
            s.res.new_tokens.append(tok)
            self._deltas.append((s.req.uid, tok))
            s.pos += 1
            self._consume_reason(s, t, _host_reason(self, s))


class _Pr4StepwiseDense(InferenceEngine):
    """Dense-engine variant of the PR-4 decode iteration (see above)."""

    def _decode_once(self, active):
        import jax.numpy as jnp
        tokens = np.zeros((self.max_batch, 1), np.int32)
        pos = np.full((self.max_batch,), -1, np.int32)
        for i in active:
            s = self._slots[i]
            tokens[i, 0] = (s.res.new_tokens[-1] if s.res.new_tokens
                            else s.req.tokens[-1])
            pos[i] = s.pos
        safe = np.where(pos >= 0, pos, self.max_seq - 1)
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.asarray(safe))
        am = np.asarray(jnp.argmax(logits, axis=-1))     # greedy bench
        t = time.perf_counter()
        for i in active:
            s = self._slots[i]
            tok = int(am[i])
            s.res.new_tokens.append(tok)
            self._deltas.append((s.req.uid, tok))
            s.pos += 1
            self._consume_reason(s, t, _host_reason(self, s))


def _decode_reqs(cfg, n, prompt_len, max_new, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    tokens=list(rng.randint(0, cfg.vocab_size, prompt_len)),
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i in range(n)]


def _measure(make_engine, cfg, n, prompt_len, max_new, reps):
    """Returns (best wall, tokens that wall produced, per-rep streams,
    the engine). min-of-N walls: dispatch overhead is systematic,
    scheduler noise is not — the same discipline mixed_bench uses. Token
    streams are kept PER REP so the equivalence check compares like with
    like; the engine comes back for post-hoc counters (spec acceptance)."""
    eng = make_engine()
    eng.run(_decode_reqs(cfg, n, prompt_len, 2, seed=99))     # compile
    best, streams = None, {}
    for rep in range(reps):
        reqs = _decode_reqs(cfg, n, prompt_len, max_new, seed=rep)
        t0 = time.perf_counter()
        res = eng.run(reqs)
        wall = time.perf_counter() - t0
        n_tok = sum(len(r.new_tokens) for r in res)
        streams[rep] = {r.uid: r.new_tokens for r in res}
        if best is None or wall < best[0]:
            best = (wall, n_tok)
    return best + (streams, eng)


def decode_run(arch: str = "smollm-360m", burst: int = 16,
               batch: Optional[int] = None,
               prompt_len: int = 16, max_new: int = 64, reps: int = 3,
               backend: str = "trt", paged: bool = True, spec: bool = False):
    """Burst vs stepwise decode throughput on one engine config."""
    cfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    bk = BACKENDS[backend]
    params = init_model(cfg, jax.random.PRNGKey(0))
    n = batch or bk.max_batch
    cls = PagedInferenceEngine if paged else InferenceEngine
    pr4 = _Pr4StepwisePaged if paged else _Pr4StepwiseDense
    kw = dict(max_seq=256, chunk_tokens=64)
    if paged:
        kw["block_size"] = 16

    def mk(c, db, instrumented=False):
        def make():
            obs = None
            if instrumented:
                # full bundle: registry + tracer + chip-second ledger +
                # flight recorder, with a live replica meter attached so
                # the per-step cost-attribution hook is on the measured
                # path (same wiring the replica pool performs)
                bundle = Observability()
                obs = bundle.engine_obs(cfg.name, backend)
                obs.meter = bundle.ledger.replica_up(
                    cfg.name, backend, chips=1, cold_s=0.0,
                    t=time.perf_counter())
            return c(cfg, params, bk, decode_burst=db, obs=obs, **kw)
        return make

    print(f"\n== Decode hot path ({cfg.name}, {'paged' if paged else 'dense'} "
          f"x{n}, {max_new} new tokens, burst K={burst}) ==")
    w_pr4, tok_pr4, toks_pr4, _ = _measure(mk(pr4, 1), cfg, n, prompt_len,
                                           max_new, reps)
    w_step, tok_step, toks_step, _ = _measure(mk(cls, 1), cfg, n, prompt_len,
                                              max_new, reps)
    w_burst, tok_burst, toks_burst, _ = _measure(mk(cls, burst), cfg, n,
                                                 prompt_len, max_new, reps)
    # the same fused stepwise engine with full observability attached
    # (metrics registry + lifecycle tracer): its host-side hooks must be
    # decode-step noise, not a tax — the acceptance bound is < 5%
    w_obs, tok_obs, toks_obs, _ = _measure(mk(cls, 1, instrumented=True),
                                           cfg, n, prompt_len, max_new, reps)
    for rep in toks_step:                  # token-for-token, rep by rep
        assert toks_pr4[rep] == toks_step[rep], \
            f"fused != PR-4 tokens (greedy) at rep {rep}"
        assert toks_step[rep] == toks_burst[rep], \
            f"burst != stepwise tokens (greedy) at rep {rep}"
        assert toks_step[rep] == toks_obs[rep], \
            f"instrumented != plain tokens (greedy) at rep {rep}"
    r_pr4 = tok_pr4 / w_pr4
    r_step, r_burst = tok_step / w_step, tok_burst / w_burst
    r_obs = tok_obs / w_obs
    obs_overhead = w_obs / w_step - 1.0
    print(f"{'mode':16s} {'tok/s':>8s} {'ms/tok':>8s} {'vs pr4':>7s}")
    for name, r, w, tk in (("pr4-stepwise", r_pr4, w_pr4, tok_pr4),
                           ("fused-stepwise", r_step, w_step, tok_step),
                           ("fused+metrics", r_obs, w_obs, tok_obs),
                           ("fused-burst", r_burst, w_burst, tok_burst)):
        print(f"{name:16s} {r:8.1f} {1e3*w/tk:8.2f} {r/r_pr4:6.2f}x")
    print(f"burst vs PR-4 stepwise: {r_burst/r_pr4:.2f}x "
          f"(tokens identical across all modes: yes)")
    print(f"observability overhead on the fused stepwise path: "
          f"{100 * obs_overhead:+.1f}% (bound: < 5%)")
    payload = {
        "arch": cfg.name, "backend": backend,
        "paged": paged, "batch": n, "prompt_len": prompt_len,
        "max_new": max_new, "burst_k": burst, "reps": reps,
        "pr4_stepwise_tok_per_s": r_pr4,
        "fused_stepwise_tok_per_s": r_step,
        "burst_tok_per_s": r_burst,
        "pr4_stepwise_ms_per_tok": 1e3 * w_pr4 / tok_pr4,
        "fused_stepwise_ms_per_tok": 1e3 * w_step / tok_step,
        "burst_ms_per_tok": 1e3 * w_burst / tok_burst,
        # the acceptance gauge: burst decode vs the PR-4 stepwise path
        "burst_speedup": r_burst / r_pr4,
        "fused_stepwise_speedup": r_step / r_pr4,
        "burst_speedup_vs_fused_stepwise": r_burst / r_step,
        "greedy_token_equivalent": True,       # asserted above
        # instrumentation cost of the full obs plane on the decode hot
        # path (registry + tracer hooks, host-side only)
        "instrumented_tok_per_s": r_obs,
        "obs_overhead_frac": obs_overhead,
        "obs_overhead_ok": obs_overhead < 0.05,
    }
    if spec:
        payload["spec"] = spec_run(arch=arch, batch=batch,
                                   prompt_len=prompt_len, max_new=max_new,
                                   reps=reps, backend=backend)
    path = save_bench("decode", payload)
    print(f"wrote {path}")
    return payload


def _spec_pair(arch: str, depth_mult: int):
    """(target cfg+params, draft cfg+params) for the spec bench.

    The registry's reduced smoke archs are all the same size, so a real
    small-drafts-for-big pairing isn't available on CPU — and two
    independently random models accept ~nothing. Instead the target IS
    the smoke arch extended with exact-identity residual layers (zeroed
    attention/FFN output projections), emulating the draft/target depth
    gap of a production pairing: target logits equal draft logits, so
    acceptance sits near the all-accept upper bound, while the PLAIN
    baseline is measured on the SAME deepened target — the speedup is
    the engine mechanics (one multi-token verify replacing n_acc+1
    target dispatches), not a model-quality artifact."""
    dcfg = dataclasses.replace(ARCHS[arch].reduced(), dtype="float32")
    dparams = init_model(dcfg, jax.random.PRNGKey(0))
    cfg = dataclasses.replace(dcfg, num_layers=dcfg.num_layers * depth_mult)
    params = init_model(cfg, jax.random.PRNGKey(1))
    layers = jax.tree_util.tree_map(
        lambda t, s: t.at[: s.shape[0]].set(s),
        params["layers"], dparams["layers"])
    nl = dcfg.num_layers
    layers["attn"] = dict(layers["attn"],
                          wo=layers["attn"]["wo"].at[nl:].set(0.0))
    layers["ffn"] = dict(layers["ffn"],
                         w_down=layers["ffn"]["w_down"].at[nl:].set(0.0))
    params = dict(params, embed=dparams["embed"],
                  final_norm=dparams["final_norm"], layers=layers)
    return cfg, params, dcfg, dparams


def spec_run(arch: str = "smollm-360m", batch: Optional[int] = None,
             prompt_len: int = 16, max_new: int = 64, reps: int = 3,
             backend: str = "trt", ks=(2, 4, 8), depth_mult: int = 4):
    """Speculative decoding vs plain fused stepwise on the paged engine.

    Returns the ``spec`` payload merged into BENCH_decode.json: per-K
    tok/s, measured acceptance rate, and ``spec_speedup`` (best K vs
    plain fused stepwise on the same target) — the >= 1.3x acceptance
    gauge. Token equality against the plain stream is asserted per rep:
    the exact-match rule emits only the target's own seeded samples, so
    spec == plain holds token for token whatever the draft proposes."""
    cfg, params, dcfg, dparams = _spec_pair(arch, depth_mult)
    bk = BACKENDS[backend]
    n = batch or bk.max_batch
    kw = dict(max_seq=256, chunk_tokens=64, block_size=16)

    def mk(spec):
        def make():
            return PagedInferenceEngine(cfg, params, bk, spec=spec, **kw)
        return make

    print(f"\n== Speculative decode ({cfg.name} target x{depth_mult} depth, "
          f"{arch} draft, paged x{n}, {max_new} new tokens) ==")
    w_plain, tok_plain, toks_plain, _ = _measure(
        mk(None), cfg, n, prompt_len, max_new, reps)
    r_plain = tok_plain / w_plain
    print(f"{'mode':16s} {'tok/s':>8s} {'vs plain':>9s} {'accept':>7s}")
    print(f"{'fused-stepwise':16s} {r_plain:8.1f} {'1.00x':>9s} {'-':>7s}")
    per_k = {}
    for k in ks:
        draft = SpecDraft(cfg=dcfg, params=dparams, k=k)
        w, tok, toks, eng = _measure(mk(draft), cfg, n, prompt_len,
                                     max_new, reps)
        assert eng.spec is not None, "draft failed to co-reside"
        for rep in toks_plain:             # token-for-token, rep by rep
            assert toks[rep] == toks_plain[rep], \
                f"spec K={k} != plain tokens (greedy) at rep {rep}"
        r = tok / w
        acc = (eng._spec_accepted / eng._spec_drafted
               if eng._spec_drafted else 0.0)
        per_k[k] = {"tok_per_s": r, "speedup": r / r_plain,
                    "accept_rate": acc,
                    "drafted": eng._spec_drafted,
                    "accepted": eng._spec_accepted}
        print(f"{f'spec K={k}':16s} {r:8.1f} {r / r_plain:8.2f}x {acc:7.2f}")
    best_k = max(per_k, key=lambda k: per_k[k]["tok_per_s"])
    return {
        "arch": arch, "backend": backend, "batch": n,
        "prompt_len": prompt_len, "max_new": max_new, "reps": reps,
        "target_depth_mult": depth_mult,
        "plain_stepwise_tok_per_s": r_plain,
        "per_k": {str(k): v for k, v in per_k.items()},
        # the acceptance gauge: best-K spec vs plain fused stepwise on
        # the same target, tokens asserted identical
        "spec_speedup": per_k[best_k]["tok_per_s"] / r_plain,
        "spec_best_k": best_k,
        "spec_accept_rate": per_k[best_k]["accept_rate"],
        "greedy_token_equivalent": True,       # asserted above
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--decode", action="store_true",
                    help="decode hot-path bench only (burst vs stepwise; "
                         "writes BENCH_decode.json)")
    ap.add_argument("--spec", action="store_true",
                    help="add the speculative-decoding mode to the decode "
                         "bench (plain fused stepwise vs spec at K in "
                         "{2,4,8}, token equality asserted)")
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--burst", type=int, default=16)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--dense", action="store_true",
                    help="bench the dense engine instead of paged")
    args = ap.parse_args()
    if args.decode:
        decode_run(arch=args.arch, burst=args.burst, batch=args.batch,
                   max_new=args.max_new, reps=args.reps,
                   paged=not args.dense, spec=args.spec)
    else:
        run(arch=args.arch)
        decode_run(arch=args.arch, burst=args.burst, batch=args.batch,
                   max_new=args.max_new, reps=args.reps,
                   paged=not args.dense, spec=args.spec)
