"""Chaos benchmark: goodput and tail latency of the fault-tolerant serve
plane under a seeded replica-crash schedule.

Three runs over the SAME open-loop Poisson arrival trace:

  * ``baseline``  — fault-free ServeFrontend (the goodput yardstick);
  * ``chaos``     — a seeded ``FaultPlan`` kills replica steps at the
    configured crash rate (plus one deterministic mid-decode kill so the
    smoke run always exercises the path); quarantine + deterministic
    retry + warm replacement contain every failure;
  * ``nocontain`` — the same fault schedule with containment OFF
    (``SchedulerConfig.contain_failures=False``): the first injected
    fault propagates and every unresolved request is lost.

Acceptance (printed, and asserted by the CI chaos smoke):
  * chaos loses ZERO non-shed requests (every handle resolves with a
    structured finish reason);
  * chaos goodput >= 0.9x baseline at a 10% per-step crash rate;
  * the chip-second ledger stays conserved (<1% error) across
    quarantine/replace churn.

Run: PYTHONPATH=src python benchmarks/chaos_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from common import save_bench, save_result
from repro.api import CompletionRequest
from repro.configs.registry import ARCHS
from repro.core.gateway import ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.scoring import PROFILES
from repro.data.benchmarks import generate_corpus
from repro.obs import write_metrics_dump
from repro.serving import FaultPlan, FaultSpec, InjectedFault, SchedulerConfig

MODEL = "smollm-360m"


def _models():
    return {MODEL: dataclasses.replace(ARCHS[MODEL].reduced(),
                                       dtype="float32")}


def _frontend(faults=None, contain=True, flight_record=None):
    spin = SpinConfig(window_s=30.0, cooldown_s=0.3, idle_tau_s=2.0,
                      tick_s=0.25, max_replicas=4,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    return ServeFrontend(
        _models(), profile=PROFILES["balanced"], max_seq=96, spin=spin,
        faults=faults, quarantine_after=1, flight_record=flight_record,
        sched=SchedulerConfig(contain_failures=contain, max_retries=4))


def _drive(gw, reqs, arrivals, max_new: int, settle_s: float = 30.0):
    """Open-loop driver that survives a propagating crash: submit
    ``reqs[i]`` at ``arrivals[i]``, step until every handle resolves (or
    the plane crashes / the settle budget expires). Returns
    (handles, wall_s, crashed)."""
    t0 = time.perf_counter()
    handles, crashed = [], False
    i, n = 0, len(reqs)
    deadline = None
    while True:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            r = reqs[i]
            handles.append(gw.submit(r.prompt, max_new_tokens=max_new,
                                     deadline_s=r.deadline_s))
            i += 1
        try:
            gw.step()
        except InjectedFault:
            crashed = True
            break
        if i >= n and all(h.done() for h in handles):
            break
        if i >= n:
            if deadline is None:
                deadline = time.perf_counter() + settle_s
            elif time.perf_counter() > deadline:
                break  # leaked requests — reported as lost below
    return handles, time.perf_counter() - t0, crashed


def _summarize(handles, wall, crashed, submitted):
    done = [h.response for h in handles if h.done()]
    ok = [r for r in done if r.completed]
    shed = [r for r in done if r.shed]
    failed = [r for r in done if r.finish_reason == "failed"]
    other = len(done) - len(ok) - len(shed) - len(failed)
    lost = submitted - len(handles) + sum(not h.done() for h in handles)
    lats = [r.latency_s for r in ok] or [0.0]
    return {
        "submitted": submitted, "resolved": len(done), "completed": len(ok),
        "shed": len(shed), "failed": len(failed), "other_resolved": other,
        "lost": lost, "crashed": crashed, "wall_s": wall,
        "goodput_rps": len(ok) / wall if wall > 0 else 0.0,
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "recovered": sum(r.usage.retries > 0 for r in ok),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="open-loop arrival rate (rps)")
    ap.add_argument("--crash-rate", type=float, default=0.10,
                    help="per-step replica crash probability")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run for CI (12 requests)")
    ap.add_argument("--metrics-dump", default="BENCH_chaos_metrics.prom",
                    help="Prometheus exposition path for the CHAOS run's "
                         "registry ('' disables)")
    ap.add_argument("--flight-record", default="",
                    help="flight-recorder JSONL sink for the chaos run "
                         "(each injected crash dumps the steps leading "
                         "into it; '' disables)")
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 12)

    prompts = generate_corpus(max(args.requests, 64),
                              seed=args.seed)[: args.requests]
    reqs = [CompletionRequest(prompt=p.text,
                              max_new_tokens=args.max_new_tokens,
                              deadline_s=120.0) for p in prompts]
    rng = np.random.RandomState(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                         size=len(reqs)))
    # the fault schedule: Bernoulli(crash_rate) step kills on every
    # replica, PLUS one deterministic mid-decode kill of the first
    # incarnation so even the tiny smoke run quarantines and retries
    plan = FaultPlan([FaultSpec("step_error", at_step=6, replica=0),
                      FaultSpec("step_error", rate=args.crash_rate)],
                     seed=args.seed)

    print(f"== chaos_bench: {len(reqs)} requests @ {args.rate:.1f} rps, "
          f"crash rate {args.crash_rate:.0%}, seed {args.seed} ==")

    runs = {}
    for name, faults, contain in (("baseline", None, True),
                                  ("chaos", plan, True),
                                  ("nocontain", dataclasses.replace(
                                      plan, fired=[]), False)):
        gw = _frontend(faults=faults, contain=contain,
                       flight_record=(args.flight_record or None)
                       if name == "chaos" else None)
        gw.pool.scale(MODEL, "trt", 2)      # pre-warm: 2 serving replicas
        handles, wall, crashed = _drive(gw, reqs, arrivals,
                                        args.max_new_tokens)
        runs[name] = _summarize(handles, wall, crashed, len(reqs))
        runs[name]["quarantines"] = gw.pool.quarantines
        runs[name]["faults_fired"] = len(faults.fired) if faults else 0
        if gw.obs is not None:
            runs[name]["ledger_conservation_err"] = (
                gw.obs.ledger.conservation_error())
        s = runs[name]
        print(f"\n-- {name} --")
        print(f"wall={s['wall_s']:.1f}s  goodput={s['goodput_rps']:.2f} rps"
              f"  completed={s['completed']}/{s['submitted']}"
              f"  shed={s['shed']}  failed={s['failed']}  lost={s['lost']}"
              f"  p95_lat={s['p95_latency_s']:.3f}s")
        print(f"faults_fired={s['faults_fired']}"
              f"  quarantines={s['quarantines']}"
              f"  recovered={s['recovered']}"
              f"  crashed={s['crashed']}")
        if name == "chaos" and args.metrics_dump and gw.obs is not None:
            dumped = write_metrics_dump(args.metrics_dump, gw.obs.registry,
                                        events=gw.obs.events,
                                        tracer=gw.obs.tracer)
            print(f"metrics dump: {', '.join(dumped)}")

    base, chaos, noc = runs["baseline"], runs["chaos"], runs["nocontain"]
    ratio = chaos["goodput_rps"] / max(base["goodput_rps"], 1e-9)
    zero_lost = chaos["lost"] == 0
    ledger_ok = chaos.get("ledger_conservation_err", 0.0) < 0.01
    print(f"\ngoodput under chaos: {ratio:.2f}x baseline "
          f"({'PASS' if ratio >= 0.9 else 'BELOW 0.9x'})")
    print(f"lost requests under chaos: {chaos['lost']} "
          f"({'PASS' if zero_lost else 'FAIL'})")
    print(f"ledger conservation err: "
          f"{chaos.get('ledger_conservation_err', 0.0):.2%} "
          f"({'PASS' if ledger_ok else 'FAIL'})")
    print(f"no-containment baseline: crashed={noc['crashed']}  "
          f"lost={noc['lost']} "
          f"(containment saved {noc['lost'] - chaos['lost']} requests)")

    payload = {"runs": runs, "goodput_ratio": ratio,
               "zero_lost": zero_lost, "ledger_ok": ledger_ok,
               "requests": len(reqs), "rate_rps": args.rate,
               "crash_rate": args.crash_rate, "seed": args.seed}
    save_result("chaos_bench", payload)
    path = save_bench("chaos", payload)
    print(f"bench artifact: {path}")
    return 0 if (zero_lost and ratio >= 0.9 and ledger_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
