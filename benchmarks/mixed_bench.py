"""Mixed-traffic benchmark: decode inter-token latency under long-prompt
interference — chunked (token-budget) prefill vs whole-prompt prefill.

Workload: a batch of short-prompt, decode-heavy "victim" requests is
mid-generation when long-prompt requests keep arriving. This is the
traffic shape continuous batching exists for:

  * whole-prompt prefill runs each arriving long prompt to completion
    INSIDE one engine step, so every in-flight decode stalls behind it —
    the classic head-of-line ITL spike;
  * chunked prefill spends a bounded token budget per step (decode
    tokens first, then at most ``chunk_tokens`` of pending prefill), so
    the long prompt amortizes across steps and in-flight decodes keep
    their cadence.

Both modes run the SAME engine code on the SAME workload to completion
(equal work, throughput reported), greedy and arithmetically equivalent
— tier-1 asserts chunked==whole token for token — so this measures pure
scheduling effect.

ITL here = wall duration of an engine step in which victims decoded (one
sample per step; every victim in the batch experiences it). Acceptance:
p95 ITL >= 1.5x lower with chunking at comparable throughput. Writes
BENCH_mixed.json at the repo root (CI artifact).

Run: PYTHONPATH=src python benchmarks/mixed_bench.py [--layers 4]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from common import save_bench, save_result

import jax

from repro.configs.registry import ARCHS
from repro.models import init_model
from repro.serving import (PagedInferenceEngine, Request, SamplingParams,
                           get_backend)

MODEL = "smollm-360m"


def build_workload(cfg, n_victims, n_interferers, short_len, long_len,
                   victim_new, interferer_new, seed):
    rng = np.random.RandomState(seed)
    victims = [Request(uid=i,
                       tokens=list(rng.randint(0, cfg.vocab_size, short_len)),
                       sampling=SamplingParams(max_new_tokens=victim_new))
               for i in range(n_victims)]
    interferers = [
        Request(uid=1000 + i,
                tokens=list(rng.randint(0, cfg.vocab_size, long_len)),
                sampling=SamplingParams(max_new_tokens=interferer_new))
        for i in range(n_interferers)]
    return victims, interferers


def run_mode(eng, victims, interferers, inject_every):
    """Serve victims to completion while injecting one long prompt every
    ``inject_every`` steps. Returns (itl step samples, wall_s, tokens)."""
    victim_uids = {v.uid for v in victims}
    live = set(victim_uids)
    for v in victims:
        eng.submit(v)
    # ramp (not measured): get every victim past prefill into decode
    while eng._queue or any(not s.done and s.prefilling
                            for s in eng._slots):
        for r in eng.step():
            live.discard(r.uid)
        eng.drain_deltas()
    pending = list(interferers)
    itl, tokens, step_idx = [], 0, 0
    t_begin = time.perf_counter()
    while live:
        if pending and step_idx % inject_every == 0:
            eng.submit(pending.pop(0))
        t0 = time.perf_counter()
        finished = eng.step()
        dt = time.perf_counter() - t0
        deltas = eng.drain_deltas()
        tokens += len(deltas)
        if any(uid in victim_uids for uid, _ in deltas):
            itl.append(dt)               # every victim in the batch saw dt
        for r in finished:
            live.discard(r.uid)
        step_idx += 1
    wall = time.perf_counter() - t_begin
    while eng.has_work():                # drain interferers (not measured)
        eng.step()
    return itl, wall, tokens


def _stats(itl, wall, tokens):
    return {"steps": len(itl), "wall_s": wall,
            "throughput_tps": tokens / wall,
            "mean_itl_s": float(np.mean(itl)),
            "p50_itl_s": float(np.percentile(itl, 50)),
            "p95_itl_s": float(np.percentile(itl, 95)),
            "max_itl_s": float(np.max(itl))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--victims", type=int, default=6)
    ap.add_argument("--interferers", type=int, default=6)
    ap.add_argument("--short-len", type=int, default=16)
    ap.add_argument("--long-len", type=int, default=320)
    ap.add_argument("--victim-new", type=int, default=48)
    ap.add_argument("--interferer-new", type=int, default=2)
    ap.add_argument("--inject-every", type=int, default=6)
    ap.add_argument("--chunk-tokens", type=int, default=64)
    ap.add_argument("--step-token-budget", type=int, default=128)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--layers", type=int, default=4,
                    help="trunk depth (deeper than the 2-layer smoke "
                         "config so prefill compute, the thing chunking "
                         "amortizes, dominates per-call overhead)")
    ap.add_argument("--seed", type=int, default=23)
    args = ap.parse_args()

    cfg = dataclasses.replace(ARCHS[MODEL].reduced(), dtype="float32",
                              num_layers=args.layers)
    params = init_model(cfg, jax.random.PRNGKey(0))
    bk = get_backend("vllm")             # throughput profile: 16 slots

    def engine(chunked: bool):
        return PagedInferenceEngine(
            cfg, params, bk, max_seq=args.max_seq,
            chunk_tokens=args.chunk_tokens if chunked else None,
            step_token_budget=args.step_token_budget if chunked else None)

    print(f"== mixed_bench: {args.victims} victims (len {args.short_len}, "
          f"{args.victim_new} new) + {args.interferers} interferers "
          f"(len {args.long_len}) every {args.inject_every} steps; "
          f"chunk={args.chunk_tokens}, budget={args.step_token_budget} ==")

    results = {}
    for name, chunked in (("whole", False), ("chunked", True)):
        # warm XLA on a same-shaped workload with different tokens so the
        # measured run times serving, not compile
        warm_v, warm_i = build_workload(
            cfg, args.victims, args.interferers, args.short_len,
            args.long_len, args.victim_new, args.interferer_new,
            args.seed + 1)
        eng = engine(chunked)
        run_mode(eng, warm_v, warm_i, args.inject_every)
        victims, interferers = build_workload(
            cfg, args.victims, args.interferers, args.short_len,
            args.long_len, args.victim_new, args.interferer_new, args.seed)
        itl, wall, tokens = run_mode(eng, victims, interferers,
                                     args.inject_every)
        results[name] = _stats(itl, wall, tokens)
        s = results[name]
        print(f"{name:8s} mean_itl={s['mean_itl_s']*1e3:7.2f}ms  "
              f"p50={s['p50_itl_s']*1e3:7.2f}ms  "
              f"p95={s['p95_itl_s']*1e3:7.2f}ms  "
              f"max={s['max_itl_s']*1e3:7.2f}ms  "
              f"tput={s['throughput_tps']:6.1f} tok/s")

    p95_ratio = results["whole"]["p95_itl_s"] / max(
        results["chunked"]["p95_itl_s"], 1e-9)
    mean_ratio = results["whole"]["mean_itl_s"] / max(
        results["chunked"]["mean_itl_s"], 1e-9)
    tput_ratio = (results["chunked"]["throughput_tps"]
                  / max(results["whole"]["throughput_tps"], 1e-9))
    print(f"\ndecode ITL ratio (whole/chunked): p95 {p95_ratio:.2f}x, "
          f"mean {mean_ratio:.2f}x  |  throughput (chunked/whole): "
          f"{tput_ratio:.2f}x")
    print(f"{'PASS' if p95_ratio >= 1.5 else 'BELOW 1.5x'} "
          f"(acceptance: p95 ITL >= 1.5x lower under chunked prefill)")

    payload = {**{f"{k}_{m}": v for k, s in results.items()
                  for m, v in s.items()},
               "whole": results["whole"], "chunked": results["chunked"],
               "itl_p95_ratio": p95_ratio, "itl_mean_ratio": mean_ratio,
               "throughput_ratio": tput_ratio,
               "victims": args.victims, "interferers": args.interferers,
               "long_len": args.long_len,
               "chunk_tokens": args.chunk_tokens,
               "step_token_budget": args.step_token_budget}
    save_result("mixed_bench", payload)
    path = save_bench("mixed", payload)
    print(f"bench artifact: {path}")
    return p95_ratio


if __name__ == "__main__":
    main()
