"""Beyond-paper: reinforcement-based routing (the paper's future work).

Compares the Thompson-sampling bandit policy (core/bandit.py) against the
static multi-objective policy over a long workload, measuring the
learning curve (success rate per quartile of traffic) and the learned
capability matrix vs the ground-truth structure.
"""
from __future__ import annotations

import time

import numpy as np

from common import (BenchTimer, PROFILES, corpus, make_workload, routers,
                    run_sim, save_result)
from repro.core import ServiceRegistry, SimConfig
from repro.core.bandit import BanditPolicy
from repro.core.policies import MultiObjectivePolicy
from repro.core.router import CAPABILITY
from repro.core.simulator import ClusterSimulator
from common import model_pool
from typing import Optional


def run(n_prompts: int = 4000, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=13)
    decisions = routers()["hybrid"].route_many([p.text for p in prompts])
    workload = make_workload(prompts, decisions, rate=8.0, seed=13)

    results = {}
    print("\n== Beyond-paper: bandit (RL) routing vs static multi-objective ==")
    print(f"{'policy':18s} {'succ_q1%':>9s} {'succ_q2%':>9s} {'succ_q3%':>9s} "
          f"{'succ_q4%':>9s} {'cost/q$':>9s}")
    for pol_cls in (MultiObjectivePolicy, BanditPolicy):
        t0 = time.perf_counter()
        reg = ServiceRegistry(model_pool())
        pol = pol_cls(reg, seed=13)
        sim = ClusterSimulator(reg, pol, PROFILES["balanced"],
                               SimConfig(seed=13, static=True))
        rep = sim.run(workload)
        wall = time.perf_counter() - t0
        reqs = sorted(rep.requests, key=lambda r: r.arrival)
        qs = np.array_split(reqs, 4)
        quart = [float(np.mean([r.success for r in q])) for q in qs]
        results[pol.name] = {
            "quartile_success": quart,
            "cost_per_query": rep.attributed_cost_per_query(),
            "overall": rep.success_rate(),
        }
        print(f"{pol.name:18s} " + " ".join(f"{100*v:9.1f}" for v in quart) +
              f" {rep.attributed_cost_per_query():9.4f}")
        if timer:
            timer.add(f"bandit_{pol.name}", len(reqs), wall,
                      f"q4_success={quart[-1]:.3f}")
        if pol.name == "bandit":
            learned = pol.learned_capability()
            print("\nlearned capability (posterior means) vs ground truth:")
            for arm in ("small", "medium", "large"):
                row = " ".join(
                    f"{t}:{learned.get(arm, {}).get(t, float('nan')):.2f}"
                    f"/{CAPABILITY[arm][t]:.2f}"
                    for t in ("low", "medium", "high"))
                print(f"  {arm:7s} {row}")
            results["learned_capability"] = {
                a: learned.get(a, {}) for a in ("small", "medium", "large")}

    mo_q, bd_q = (results["multi_objective"]["quartile_success"],
                  results["bandit"]["quartile_success"])
    print(f"\nderived: bandit learning curve q1->q4 "
          f"{100*(bd_q[-1]-bd_q[0]):+.1f}pp; final quartile vs "
          f"multi-objective {100*(bd_q[-1]-mo_q[-1]):+.1f}pp")
    save_result("beyond_bandit", results)
    return results


if __name__ == "__main__":
    run()
