"""Paper Table 2 + Fig. 5: keyword vs DistilBERT routing strategies.

Reports tier accuracy uplift over no-routing, latency delta, and GPU
utilization per strategy, plus routing success rate under the full
simulator (multi-objective selection, dynamic scaling).
"""
from __future__ import annotations

import time

import numpy as np

from typing import Optional

from common import (BenchTimer, PROFILES, corpus, make_workload, routers,
                    run_sim, save_result)


def run(n_prompts: int = 1500, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=2)
    texts = [p.text for p in prompts]
    gold = [p.complexity for p in prompts]
    rts = routers()

    results = {}
    print("\n== Table 2: routing strategies ==")
    print(f"{'strategy':12s} {'tier_acc%':>9s} {'succ%':>7s} {'lat(s)':>8s} "
          f"{'ttft_p50':>9s} {'util%':>6s} {'overhead(ms)':>12s}")
    for name in ("keyword", "distilbert", "hybrid"):
        t0 = time.perf_counter()
        decisions = rts[name].route_many(texts)
        route_wall = time.perf_counter() - t0
        tier_acc = float(np.mean([d.tier == g for d, g in zip(decisions, gold)]))
        workload = make_workload(prompts, decisions, rate=6.0, seed=2)
        rep, reg = run_sim("multi_objective", PROFILES["balanced"], workload)
        s = rep.steady_state().summary()
        results[name] = {"tier_accuracy": tier_acc,
                         "route_overhead_ms": 1e3 * route_wall / len(texts),
                         **s}
        print(f"{name:12s} {100*tier_acc:9.1f} {100*s['success_rate']:7.1f} "
              f"{s['mean_latency_s']:8.2f} {s['ttft_p50']:9.2f} "
              f"{100*s['gpu_utilization']:6.1f} "
              f"{1e3*route_wall/len(texts):12.3f}")
        if timer:
            timer.add(f"table2_routing_{name}", len(texts), route_wall,
                      f"tier_acc={tier_acc:.3f};success={s['success_rate']:.3f}")

    kw, db = results["keyword"], results["distilbert"]
    print(f"\nderived: distilbert tier-acc uplift "
          f"{100*(db['tier_accuracy']-kw['tier_accuracy']):+.1f}pp "
          f"(paper: semantic > keyword); "
          f"TTFT overhead {100*(db['ttft_p50']/max(kw['ttft_p50'],1e-9)-1):+.1f}% "
          f"(paper: +23.5%)")
    save_result("table2_routing", results)
    return results


if __name__ == "__main__":
    run()
