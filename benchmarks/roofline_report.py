"""§Roofline report: read the dry-run artifacts, emit the full baseline
table (every arch x shape on the single-pod mesh) and flag the three
hillclimb targets (worst roofline fraction / most collective-bound / most
representative of the paper's serving technique).
"""
from __future__ import annotations

import glob
import json
import os
import time

from common import ART, BenchTimer, save_result
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config_for_shape
from typing import Optional

from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS, \
    analytic_memory_bytes

DRYRUN = os.path.join(ART, "dryrun")


def _recompute(r):
    """Memory term = max(raw HLO bytes, analytic HBM floor); terms and
    dominance recomputed uniformly regardless of artifact vintage."""
    cfg = get_config_for_shape(r["arch"], r["shape"])
    shape = INPUT_SHAPES[r["shape"]]
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    floor = r.get("analytic_memory_bytes") or analytic_memory_bytes(
        cfg.param_count(), cfg.active_param_count(), shape.kind, tokens,
        cfg.d_model, cfg.num_layers, r.get("cache_bytes", 0))
    chips = r["chips"]
    r["memory_s"] = max(r.get("hlo_bytes_raw", r["hlo_bytes"]), floor) \
        / (chips * HBM_BW)
    r["compute_s"] = r["hlo_flops"] / (chips * PEAK_FLOPS)
    r["collective_s"] = r["collective_bytes"] / (chips * ICI_BW)
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    r["dominant"] = max(terms, key=terms.get)
    return r


def load_rows(mesh: str = "pod16x16"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        with open(fn) as f:
            rows.append(_recompute(json.load(f)))
    return rows


def run(timer: Optional[BenchTimer] = None):
    t0 = time.perf_counter()
    rows = load_rows()
    print("\n== Roofline baselines (single pod, 256 chips; seconds/step) ==")
    hdr = (f"{'arch':22s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collective':>11s} {'dominant':>10s} {'useful%':>8s}")
    print(hdr)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        useful = 100 * min(1.0, r.get("useful_flops_frac", 0.0))
        print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:11.4f} "
              f"{r['dominant']:>10s} {useful:8.1f}")

    # hillclimb target selection
    def frac_collective(r):
        tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
        return r["collective_s"] / tot if tot else 0.0

    def roofline_fraction(r):
        """max(term)/sum(terms): 1.0 == perfectly bound by one resource
        (good overlap potential); low == badly mixed."""
        tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
        return max(r["compute_s"], r["memory_s"], r["collective_s"]) / tot \
            if tot else 0.0

    worst = min(rows, key=roofline_fraction)
    most_coll = max(rows, key=frac_collective)
    # most representative of the paper: the serving decode step of the
    # biggest pool model (the Spin cost model's dominant regime)
    decodes = [r for r in rows if r["shape"] == "decode_32k"]
    rep = max(decodes, key=lambda r: r["active_param_count"])
    print(f"\nhillclimb targets:")
    print(f"  worst roofline fraction : {worst['arch']} x {worst['shape']} "
          f"({roofline_fraction(worst):.2f})")
    print(f"  most collective-bound   : {most_coll['arch']} x "
          f"{most_coll['shape']} ({100*frac_collective(most_coll):.0f}% collective)")
    print(f"  paper-representative    : {rep['arch']} x {rep['shape']} "
          f"(largest served decode)")
    save_result("roofline_baselines", {
        "rows": rows,
        "targets": {"worst_fraction": [worst["arch"], worst["shape"]],
                    "most_collective": [most_coll["arch"], most_coll["shape"]],
                    "representative": [rep["arch"], rep["shape"]]}})
    if timer:
        timer.add("roofline_report", len(rows), time.perf_counter() - t0,
                  f"pairs={len(rows)}")
    return rows


if __name__ == "__main__":
    run()
