"""Shared benchmark infrastructure.

Every paper table/figure benchmark pulls its corpus, routers, model pool
and simulator runs from here. The trained classifier is cached under
benchmarks/artifacts/ so repeated benchmark runs don't retrain.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.registry import ARCHS
from repro.core import (PROFILES, ClusterSimulator, HybridRouter,
                        KeywordRouter, SemanticRouter, ServiceRegistry,
                        SimConfig, poisson_arrivals)
from repro.core.classifier import ClassifierConfig, train_classifier
from repro.core.policies import POLICIES
from repro.core.scoring import OperatorProfile
from repro.checkpoint.checkpoint import load_pytree, save_pytree
from repro.core.classifier import init_classifier
from repro.data.benchmarks import generate_corpus, split

ART = os.path.join(os.path.dirname(__file__), "artifacts")
RESULTS = os.path.join(ART, "results")
os.makedirs(RESULTS, exist_ok=True)

# the serving pool: assigned archs spanning the paper's tier structure
POOL = ["smollm-360m", "zamba2-1.2b", "phi3-medium-14b", "glm4-9b",
        "command-r-plus-104b", "deepseek-v2-236b"]
DEFAULT_MODEL = "glm4-9b"          # the paper-style single static default

CLS_CFG = ClassifierConfig()


def model_pool(names=None) -> Dict:
    return {k: ARCHS[k] for k in (names or POOL)}


def corpus(n: int = 1500, seed: int = 0):
    return generate_corpus(n, seed)


def get_classifier(n_train: int = 3000, epochs: int = 5, force: bool = False,
                   log=print) -> Tuple[SemanticRouter, dict]:
    """Train (or load the cached) complexity classifier."""
    ckpt = os.path.join(ART, "classifier.ckpt")
    rep_path = os.path.join(ART, "classifier_report.json")
    if not force and os.path.exists(ckpt) and os.path.exists(rep_path):
        import jax
        template = init_classifier(CLS_CFG, jax.random.PRNGKey(0))
        params = load_pytree(template, ckpt)
        report = json.load(open(rep_path))
        return SemanticRouter(params, CLS_CFG), report
    full = generate_corpus(n_train, seed=0)
    train, val = split(full, val_frac=0.1)
    params, report = train_classifier(train, val, CLS_CFG, epochs=epochs,
                                      log=log)
    save_pytree(params, ckpt)
    json.dump(report, open(rep_path, "w"))
    return SemanticRouter(params, CLS_CFG), report


def routers() -> Dict[str, object]:
    sem, _ = get_classifier()
    return {"keyword": KeywordRouter(), "distilbert": sem,
            "hybrid": HybridRouter(sem)}


def make_workload(prompts, decisions, rate: float, seed: int = 0):
    arr = poisson_arrivals(prompts, rate, seed=seed)
    return [(t, p, d) for (t, p), d in zip(arr, decisions)]


def run_sim(policy_name: str, profile: OperatorProfile, workload,
            static: bool = False, pool=None, seed: int = 0,
            sim_cfg: Optional[SimConfig] = None):
    reg = ServiceRegistry(model_pool(pool))
    cfg = sim_cfg or SimConfig(seed=seed, static=static)
    if sim_cfg is None:
        cfg.static = static
    sim = ClusterSimulator(reg, POLICIES[policy_name](reg, seed=seed),
                           profile, cfg)
    return sim.run(workload), reg


def save_result(name: str, payload: dict) -> None:
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def save_bench(name: str, payload: dict) -> str:
    """Machine-readable perf artifact: BENCH_<name>.json at the repo root
    (CI uploads BENCH_*.json, so the perf trajectory is tracked per PR)."""
    path = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", f"BENCH_{name}.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
    return path


class BenchTimer:
    """Produces the ``name,us_per_call,derived`` CSV contract."""
    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, n_calls: int, wall_s: float, derived: str):
        us = 1e6 * wall_s / max(1, n_calls)
        self.rows.append((name, us, derived))

    def emit(self):
        for name, us, derived in self.rows:
            print(f"{name},{us:.2f},{derived}")
