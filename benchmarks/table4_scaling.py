"""Paper Table 4 + Fig. 8: static vs dynamic deployment.

Cost per query and recovery time for:
  static              — every service always on, no Spin
  Pick and Spin (base)— Alg. 1 scaling, no warm pools, scale-to-zero
  Pick and Spin (auto)— Alg. 1 + warm pools + cooldowns (full Spin)

Recovery = fault-detection + restart-to-serving, measured from the cost
model's cold/warm start for the default medium model plus each mode's
detection latency. Paper: 45 s / 12 s / 4 s; cost 0.021 / 0.016 / 0.014.
"""
from __future__ import annotations

import time

from common import (BenchTimer, DEFAULT_MODEL, PROFILES, corpus,
                    make_workload, model_pool, routers, run_sim, save_result)
from repro.core import SimConfig, SpinConfig
from repro.core.costmodel import instance_cost
from repro.serving.backend import BACKENDS
from typing import Optional

PAPER = {"static": dict(cost=0.021, recovery=45),
         "ps_base": dict(cost=0.016, recovery=12),
         "ps_auto": dict(cost=0.014, recovery=4)}


def _recovery_s(mode: str) -> float:
    ic = instance_cost(model_pool()[DEFAULT_MODEL], BACKENDS["trt"])
    if mode == "static":
        # k8s liveness-probe detection + full pod restart (weights + compile)
        return 10.0 + ic.cold_start_s
    if mode == "ps_base":
        # control-loop detection (tick) + cold start from PVC-resident weights
        return SpinConfig().tick_s + ic.cold_start_s * 0.15 + ic.warm_start_s
    # ps_auto: warm-pool replica takes over after one control tick
    return SpinConfig().tick_s * 0.5 + ic.warm_start_s


def run(n_prompts: int = 1500, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=4)
    decisions = routers()["hybrid"].route_many([p.text for p in prompts])
    # bursty-with-idle traffic (the regime scale-to-zero exists for):
    # three 4-qps bursts separated by 3-minute idle gaps (~50% idle)
    base = make_workload(prompts, decisions, rate=4.0, seed=4)
    third = len(base) // 3
    workload = []
    for i, (t, p, d) in enumerate(base):
        gap = (i // max(third, 1)) * 180.0
        workload.append((t + gap, p, d))

    configs = {
        "static": dict(static=True, spin=None),
        "ps_base": dict(static=False, spin=SpinConfig(
            warm_pool={"small": 0, "medium": 0, "large": 0},
            scale_to_zero=True)),
        "ps_auto": dict(static=False, spin=SpinConfig()),
    }
    results = {}
    print("\n== Table 4: static vs dynamic deployment ==")
    print(f"{'config':10s} {'cost/q$':>9s} {'recovery(s)':>12s} "
          f"{'succ%':>7s}   paper(cost/recovery)")
    for name, c in configs.items():
        t0 = time.perf_counter()
        sim_cfg = SimConfig(seed=4, static=c["static"])
        if c["spin"]:
            sim_cfg.spin = c["spin"]
        rep, _ = run_sim("multi_objective", PROFILES["balanced"], workload,
                         static=c["static"], sim_cfg=sim_cfg, seed=4)
        wall = time.perf_counter() - t0
        rec = _recovery_s(name)
        s = rep.summary()
        results[name] = {**s, "recovery_s": rec}
        p = PAPER[name]
        print(f"{name:10s} {s['cost_per_query_usd']:9.4f} {rec:12.1f} "
              f"{100*s['success_rate']:7.1f}   {p['cost']}/{p['recovery']}s")
        if timer:
            timer.add(f"table4_{name}", len(prompts), wall,
                      f"cost={s['cost_per_query_usd']:.4f};recovery={rec:.1f}s")

    st, au = results["static"], results["ps_auto"]
    print(f"\nderived: PS(auto) vs static: cost "
          f"{100*(1-au['cost_per_query_usd']/max(st['cost_per_query_usd'],1e-12)):-.0f}% "
          f"(paper -33%), recovery {st['recovery_s']:.0f}s -> "
          f"{au['recovery_s']:.0f}s (paper 45s -> 4s)")
    save_result("table4_scaling", results)
    return results


if __name__ == "__main__":
    run()
