"""Multi-turn chat benchmark: dense engine vs paged engine + radix
prefix cache, on the SAME scripted conversation trace.

Workload: C concurrent conversations share one system prompt; each turn
appends a scripted user utterance and a scripted assistant reply, so the
prompt of turn t is a strict extension of turn t-1's prompt (and every
conversation shares the system-prompt prefix). This is the traffic shape
the paged KV plane exists for:

  * the dense engine re-prefills the ENTIRE history every turn (and its
    floor-pow2 bucketing silently truncates the oldest context);
  * the paged engine leases the cached prefix blocks by refcount and
    prefills only the new suffix — the shared system prompt is computed
    once per replica, ever.

Both engines are greedy and arithmetically equivalent (tier-1 asserts
token-for-token equality), so this measures pure serving-plane effect.

Acceptance: paged mean TTFT >= 1.5x lower on this trace, nonzero prefix
hit-rate. Writes BENCH_prefix.json at the repo root (CI artifact).

Run: PYTHONPATH=src python benchmarks/prefix_bench.py [--convs 4]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from common import save_bench, save_result

import jax

from repro.configs.registry import ARCHS
from repro.models import init_model
from repro.serving import (InferenceEngine, PagedInferenceEngine, Request,
                           SamplingParams, get_backend)

import dataclasses

MODEL = "smollm-360m"


def build_trace(convs: int, turns: int, vocab: int, seed: int,
                sys_len: int = 192, user_len: int = 24, reply_len: int = 16):
    """Scripted multi-turn prompts: identical for both engines (replies
    come from the script, not the model, so the trace is engine-free)."""
    rng = np.random.RandomState(seed)
    system = list(rng.randint(0, vocab, sys_len))
    rounds = []
    hist = [list(system) for _ in range(convs)]
    for t in range(turns):
        rnd = []
        for c in range(convs):
            hist[c] = hist[c] + list(rng.randint(0, vocab, user_len))
            rnd.append(list(hist[c]))                  # prompt of (c, t)
            hist[c] = hist[c] + list(rng.randint(0, vocab, reply_len))
        rounds.append(rnd)
    return rounds


def serve_trace(eng, rounds, max_new: int):
    """Round-by-round closed-loop serve; returns per-request TTFTs and
    wall time. Every conversation of a round is in flight concurrently
    (iteration-level batching), mirroring live chat traffic."""
    ttfts, uid = [], 0
    t0 = time.perf_counter()
    for rnd in rounds:
        reqs = [Request(uid=(uid := uid + 1), tokens=p,
                        sampling=SamplingParams(max_new_tokens=max_new))
                for p in rnd]
        for r in eng.run(reqs):
            ttfts.append(r.ttft)
            assert r.completed
    return ttfts, time.perf_counter() - t0


def _stats(ttfts, wall, n):
    return {"n": n, "wall_s": wall, "throughput_rps": n / wall,
            "mean_ttft_s": float(np.mean(ttfts)),
            "p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p95_ttft_s": float(np.percentile(ttfts, 95))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--convs", type=int, default=6)
    ap.add_argument("--turns", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--layers", type=int, default=4,
                    help="trunk depth (deeper than the 2-layer smoke "
                         "config so prefill compute, the thing paging "
                         "saves, dominates per-call overhead)")
    ap.add_argument("--seed", type=int, default=17)
    args = ap.parse_args()

    cfg = dataclasses.replace(ARCHS[MODEL].reduced(), dtype="float32",
                              num_layers=args.layers)
    params = init_model(cfg, jax.random.PRNGKey(0))
    bk = get_backend("vllm")             # throughput profile: 16 slots
    dense = InferenceEngine(cfg, params, bk, max_seq=args.max_seq)
    paged = PagedInferenceEngine(cfg, params, bk, max_seq=args.max_seq)

    trace = build_trace(args.convs, args.turns, cfg.vocab_size, args.seed)
    n = args.convs * args.turns
    print(f"== prefix_bench: {args.convs} conversations x {args.turns} "
          f"turns (shared system prompt), {args.max_new_tokens} new "
          f"tokens, prompts up to {len(trace[-1][0])} tokens ==")

    # warm XLA on a same-shaped trace with different tokens: both engines
    # measure serving, not compile (the paged radix stays cold for the
    # measured trace — different tokens can't hit)
    warm = build_trace(args.convs, args.turns, cfg.vocab_size, args.seed + 1)
    serve_trace(dense, warm, args.max_new_tokens)
    serve_trace(paged, warm, args.max_new_tokens)
    h0, p0 = paged.hit_tokens, paged.prompt_tokens

    td, wd = serve_trace(dense, trace, args.max_new_tokens)
    tp, wp = serve_trace(paged, trace, args.max_new_tokens)
    hit_rate = (paged.hit_tokens - h0) / max(paged.prompt_tokens - p0, 1)

    d, p = _stats(td, wd, n), _stats(tp, wp, n)
    p["prefix_hit_rate"] = hit_rate
    ratio = d["mean_ttft_s"] / max(p["mean_ttft_s"], 1e-9)
    for name, s in (("dense", d), ("paged", p)):
        print(f"{name:6s} mean_ttft={s['mean_ttft_s']*1e3:7.1f}ms  "
              f"p50={s['p50_ttft_s']*1e3:7.1f}ms  "
              f"p95={s['p95_ttft_s']*1e3:7.1f}ms  "
              f"tput={s['throughput_rps']:5.2f} rps")
    print(f"\nprefix hit-rate: {hit_rate:.1%} of prompt tokens reused")
    print(f"mean TTFT ratio (dense/paged): {ratio:.2f}x "
          f"({'PASS' if ratio >= 1.5 and hit_rate > 0 else 'BELOW 1.5x'})")

    payload = {"dense": d, "paged": p, "ttft_ratio": ratio,
               "prefix_hit_rate": hit_rate,
               "convs": args.convs, "turns": args.turns,
               "max_new_tokens": args.max_new_tokens}
    save_result("prefix_bench", payload)
    path = save_bench("prefix", payload)
    print(f"bench artifact: {path}")
    return ratio


if __name__ == "__main__":
    main()
