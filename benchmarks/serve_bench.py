"""Serve-plane benchmark: serial ``Gateway.handle`` loop vs the concurrent
``ServeFrontend`` (replica pools + priority-ordered bounded-queue
scheduler + live Spin control loop), on the SAME mixed-tier workload of
reduced models on CPU. Both planes speak serving API v2.

The serial plane serves one blocking request at a time; the concurrent
plane overlaps requests via iteration-level continuous batching across
the pool, under open-loop Poisson arrivals, with Algorithm 1 ticking
against the live engines (scale-up under load, scale-to-zero when idle).

Reports request throughput (acceptance: concurrent >= 2x serial),
TTFT/latency percentiles, and the real lifecycle event log.

Run: PYTHONPATH=src python benchmarks/serve_bench.py [--requests 48]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from common import save_bench, save_result
from repro.api import CompletionRequest
from repro.configs.registry import ARCHS
from repro.core.gateway import Gateway, ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.policies import MultiObjectivePolicy
from repro.core.registry import ServiceRegistry
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES
from repro.core.simulator import ClusterSimulator, SimConfig
from repro.data.benchmarks import generate_corpus
from repro.obs import write_metrics_dump

POOL = ("smollm-360m", "phi3-medium-14b", "command-r-plus-104b")


def _models():
    return {name: dataclasses.replace(ARCHS[name].reduced(), dtype="float32")
            for name in POOL}


def _stats(ttfts, lats):
    return {"mean_ttft_s": float(np.mean(ttfts)),
            "p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p95_ttft_s": float(np.percentile(ttfts, 95)),
            "mean_latency_s": float(np.mean(lats)),
            "p95_latency_s": float(np.percentile(lats, 95))}


def run_serial(prompts, max_new: int):
    gw = Gateway(_models(), profile=PROFILES["balanced"], max_seq=96)
    for m in POOL:                      # pre-warm: measure serving, not compile
        gw.pool.scale(m, "trt", 1)
    t0 = time.perf_counter()
    results = [gw.handle(p.text, max_new_tokens=max_new, deadline_s=120.0)
               for p in prompts]
    wall = time.perf_counter() - t0
    out = _stats([r.ttft_s for r in results], [r.latency_s for r in results])
    out.update(n=len(results), wall_s=wall,
               throughput_rps=len(results) / wall,
               completed=sum(r.completed for r in results))
    return out


def run_concurrent(prompts, max_new: int, rate: float, seed: int = 0):
    spin = SpinConfig(window_s=30.0, cooldown_s=0.3, idle_tau_s=1.5,
                      tick_s=0.1, max_replicas=3,
                      warm_pool={"small": 0, "medium": 0, "large": 0})
    gw = ServeFrontend(_models(), profile=PROFILES["balanced"], max_seq=96,
                       spin=spin)
    for m in POOL:                      # same pre-warm as the serial plane
        gw.pool.scale(m, "trt", 1)
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(prompts)))
    reqs = [CompletionRequest(prompt=p.text, max_new_tokens=max_new,
                              deadline_s=120.0) for p in prompts]
    handles, wall = gw.serve_open_loop(reqs, arrivals)
    # snapshot paged KV-cache stats before settle retires the engines.
    # This plane runs the trt latency profile (dense cache), so the
    # hit-rate is null unless paged (vllm/tgi) replicas served traffic —
    # prefix_bench.py is the paged plane's dedicated measurement.
    hit_tok = sum(e.hit_tokens for _, e in gw.pool.engines() if e.paged)
    seen_tok = sum(e.prompt_tokens for _, e in gw.pool.engines() if e.paged)
    # let the Spin idle branch fire: real scale-to-zero on live engines
    gw.settle(timeout_s=4.0)
    done = [h.response for h in handles if not h.shed]
    out = _stats([r.ttft_s for r in done] or [0.0],
                 [r.latency_s for r in done] or [0.0])
    out.update(n=len(done), wall_s=wall, throughput_rps=len(done) / wall,
               completed=sum(r.completed for r in done),
               cold_start_s_attributed=float(sum(r.cold_start_s
                                                 for r in done)),
               prefix_hit_rate=(hit_tok / seen_tok if seen_tok else None),
               shed=sum(h.shed for h in handles), offered_rate_rps=rate,
               peak_replicas=max((e.after for e in gw.pool.events),
                                 default=0),
               orch_events=[str(e) for e in gw.orch_events],
               pool_events=[str(e) for e in gw.pool.events])
    # measured attribution from the chip-second ledger: every completed
    # response carries its metered slice of device time (Usage.cost_usd)
    if done and gw.obs is not None:
        out["cost_per_query_usd"] = float(
            np.mean([r.usage.cost_usd for r in done]))
        out["chip_seconds_total"] = float(
            sum(r.usage.chip_seconds for r in done))
        out["ledger_conservation_err"] = gw.obs.ledger.conservation_error()
    return out, gw, arrivals


def simulate_cost(prompts, arrivals, seed: int):
    """Replay the concurrent plane's exact trace through the discrete-event
    ClusterSimulator and return its cost prediction, so BENCH_serve.json
    carries measured cost_per_query_usd next to the simulated figure the
    capacity-planning plane would have quoted for the same workload."""
    reg = ServiceRegistry({m: ARCHS[m] for m in POOL}, ("trt",))
    policy = MultiObjectivePolicy(reg, seed=seed, require_capacity=False)
    router = KeywordRouter()
    workload = [(float(t), p, router.route(p.text))
                for t, p in zip(arrivals, prompts)]
    sim = ClusterSimulator(reg, policy, PROFILES["balanced"],
                           SimConfig(seed=seed))
    rep = sim.run(workload)
    return rep.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="open-loop arrival rate (rps); 0 = 3x serial tput")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--metrics-dump", default="BENCH_serve_metrics.prom",
                    help="Prometheus exposition path for the concurrent "
                         "plane's registry ('' disables); events and "
                         "spans land beside it as .jsonl siblings")
    args = ap.parse_args()

    prompts = generate_corpus(max(args.requests, 64),
                              seed=args.seed)[: args.requests]
    tiers = sorted({p.complexity for p in prompts})
    print(f"== serve_bench: {len(prompts)} prompts (complexities: "
          f"{','.join(tiers)}), {args.max_new_tokens} new tokens ==")

    print("\n-- serial plane (Gateway.handle, one request at a time) --")
    serial = run_serial(prompts, args.max_new_tokens)
    print(f"wall={serial['wall_s']:.1f}s  tput={serial['throughput_rps']:.2f} "
          f"rps  mean_ttft={serial['mean_ttft_s']:.3f}s  "
          f"p95_lat={serial['p95_latency_s']:.3f}s  "
          f"completed={serial['completed']}/{serial['n']}")

    rate = args.rate or 3.0 * serial["throughput_rps"]
    print(f"\n-- concurrent plane (ServeFrontend, open-loop Poisson "
          f"@ {rate:.1f} rps) --")
    conc, gw, arrivals = run_concurrent(prompts, args.max_new_tokens, rate,
                                        args.seed)
    print(f"wall={conc['wall_s']:.1f}s  tput={conc['throughput_rps']:.2f} "
          f"rps  mean_ttft={conc['mean_ttft_s']:.3f}s  "
          f"p95_lat={conc['p95_latency_s']:.3f}s  "
          f"completed={conc['completed']}/{conc['n']}  "
          f"shed={conc['shed']}  peak_replicas={conc['peak_replicas']}")

    print("\nlifecycle events (pool — measured on live engines):")
    for e in gw.pool.events:
        print(f"  {e}")
    print("orchestrator decisions (Algorithm 1 against live engines):")
    for e in gw.orch_events:
        print(f"  {e}")

    ratio = conc["throughput_rps"] / max(serial["throughput_rps"], 1e-9)
    ups = [e for e in gw.orch_events if e.kind == "scale-up"]
    zeros = [e for e in gw.orch_events if e.kind == "scale-to-zero"]
    print(f"\nthroughput ratio (concurrent/serial): {ratio:.2f}x "
          f"({'PASS' if ratio >= 2.0 else 'BELOW 2x'})")
    print(f"orchestrator scale-ups: {len(ups)} "
          f"({'PASS' if ups else 'MISSING'})  "
          f"scale-to-zero: {len(zeros)} "
          f"({'PASS' if zeros else 'MISSING'})")

    payload = {
        "serial": serial, "concurrent": conc, "throughput_ratio": ratio,
        "orch_scale_ups": len(ups), "orch_scale_to_zeros": len(zeros),
        "requests": len(prompts), "max_new_tokens": args.max_new_tokens}

    # measured vs simulated cost/query for the SAME arrival trace: the
    # live ledger's attribution next to the planner's prediction
    sim = simulate_cost(prompts, arrivals, args.seed)
    measured = conc.get("cost_per_query_usd")
    payload["cost_attribution"] = {
        "measured_cost_per_query_usd": measured,
        "simulated_cost_per_query_usd": sim["attr_cost_per_query_usd"],
        "ledger_conservation_err": conc.get("ledger_conservation_err"),
        "simulator": {k: v for k, v in sim.items()
                      if isinstance(v, (int, float))}}
    if measured is not None:
        print(f"\ncost attribution: measured ${measured:.6f}/query "
              f"(ledger, conservation err "
              f"{conc.get('ledger_conservation_err', 0.0):.2%}) vs "
              f"simulated ${sim['attr_cost_per_query_usd']:.6f}/query")
    if args.metrics_dump and gw.obs is not None:
        # registry-side tails for the same run (quantiles from the
        # log-bucketed histograms, vs the exact percentiles above)
        reg = gw.obs.registry
        payload["registry_quantiles"] = {
            m: {"ttft_p95_s": reg.quantile("ttft_s", m, 0.95),
                "itl_p95_s": reg.quantile("itl_s", m, 0.95),
                "e2e_p95_s": reg.quantile("e2e_s", m, 0.95)}
            for m in reg.labels("ttft_s")}
        dumped = write_metrics_dump(args.metrics_dump, reg,
                                    events=gw.obs.events,
                                    tracer=gw.obs.tracer)
        print(f"metrics dump: {', '.join(dumped)}")
    save_result("serve_bench", payload)
    path = save_bench("serve", payload)
    print(f"bench artifact: {path}")
    return ratio


if __name__ == "__main__":
    main()
