"""Paper Table 3: model-backend selection across orchestration strategies.

Random assignment vs latency-only vs the multi-objective matrix policy
(Algorithm 2), on an identical static (all-services-up) deployment so the
comparison isolates SELECTION quality — plus Eq. 9 routing efficiency.
Paper: +21.7% accuracy, -33% latency, -25% cost vs random; eta = 1.43.
"""
from __future__ import annotations

import time

from common import (BenchTimer, PROFILES, corpus, make_workload, routers,
                    run_sim, save_result)
from repro.core import routing_efficiency
from typing import Optional

PAPER = {"random": dict(acc=78.4, lat=63.1, cost=0.020),
         "latency_only": dict(acc=82.9, lat=48.6, cost=0.017),
         "multi_objective": dict(acc=88.3, lat=42.5, cost=0.015)}


def run(n_prompts: int = 1500, timer: Optional[BenchTimer] = None):
    prompts = corpus(n_prompts, seed=3)
    decisions = routers()["hybrid"].route_many([p.text for p in prompts])
    workload = make_workload(prompts, decisions, rate=6.0, seed=3)

    results = {}
    print("\n== Table 3: matrix selection strategies (static pool) ==")
    print(f"{'strategy':16s} {'succ%':>7s} {'lat(s)':>8s} {'cost/q$':>9s} "
          f"{'gain_pp':>8s}   paper(acc/lat/cost)")
    base = None
    for name in ("random", "latency_only", "multi_objective"):
        t0 = time.perf_counter()
        rep, _ = run_sim(name, PROFILES["balanced"], workload, static=True,
                         seed=3)
        wall = time.perf_counter() - t0
        s = rep.steady_state().summary()
        results[name] = s
        if base is None:
            base = s
        gain = 100 * (s["success_rate"] - base["success_rate"])
        p = PAPER[name]
        print(f"{name:16s} {100*s['success_rate']:7.1f} "
              f"{s['mean_latency_s']:8.2f} {s['attr_cost_per_query_usd']:9.4f} "
              f"{gain:8.1f}   {p['acc']}/{p['lat']}/{p['cost']}")
        if timer:
            timer.add(f"table3_{name}", len(prompts), wall,
                      f"success={s['success_rate']:.3f};"
                      f"lat={s['mean_latency_s']:.2f}s")

    mo, rd = results["multi_objective"], results["random"]
    eta = routing_efficiency(mo["success_rate"], rd["success_rate"],
                             max(mo["attr_cost_per_query_usd"], 1e-9),
                             max(rd["attr_cost_per_query_usd"], 1e-9))
    lat_drop = 100 * (1 - mo["mean_latency_s"] / rd["mean_latency_s"])
    cost_drop = 100 * (1 - mo["attr_cost_per_query_usd"]
                       / rd["attr_cost_per_query_usd"])
    print(f"\nderived: multi-objective vs random: "
          f"success {100*(mo['success_rate']-rd['success_rate']):+.1f}pp "
          f"(paper +9.9pp), latency {lat_drop:-.0f}% (paper -33%), "
          f"cost {cost_drop:-.0f}% (paper -25%), eta={eta:.2f} (paper 1.43)")
    results["eta"] = eta
    save_result("table3_matrix", results)
    return results


if __name__ == "__main__":
    run()
