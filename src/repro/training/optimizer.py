"""AdamW optimizer + schedules (from scratch — no optax in this env)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"           # cosine | linear | constant
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init_adamw(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
