"""Train-step builder + loop.

``make_train_step`` returns the jit-able pure function that the launcher
shards with pjit for the production mesh (see launch/train.py and
launch/dryrun.py — the same function lowers for the 512-chip dry-run).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model_forward
from repro.training.loss import chunked_cross_entropy, cross_entropy
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw


def make_loss_fn(cfg: ModelConfig, q_chunk: int = 512, loss_chunk: int = 512,
                 remat: bool = True):
    def loss_fn(params, batch):
        hidden, aux = model_forward(params, cfg, batch, q_chunk=q_chunk,
                                    return_hidden=True, remat=remat)
        labels = batch["labels"]
        # multimodal prefixes (vision/audio embeds) prepend positions that
        # have no labels; score only the trailing text region.
        S = labels.shape[1]
        hidden = hidden[:, -S:]
        w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        loss, metrics = chunked_cross_entropy(hidden, w, labels,
                                              chunk=loss_chunk,
                                              logit_softcap=cfg.logit_softcap)
        total = loss + cfg.router_aux_coef * aux
        metrics = dict(metrics, moe_aux=aux, loss=total)
        return total, metrics
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, q_chunk: int = 512,
                    loss_chunk: int = 512, remat: bool = True):
    loss_fn = make_loss_fn(cfg, q_chunk, loss_chunk, remat)

    def train_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(opt, grads, opt_state, params)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


@dataclass
class Trainer:
    cfg: ModelConfig
    opt: AdamWConfig
    params: dict
    q_chunk: int = 512
    log_every: int = 10
    opt_state: dict = field(default=None)
    history: list = field(default_factory=list)

    def __post_init__(self):
        if self.opt_state is None:
            self.opt_state = init_adamw(self.params)
        self._step_fn = jax.jit(make_train_step(self.cfg, self.opt, self.q_chunk),
                                donate_argnums=(0, 1))

    def fit(self, batches: Iterator[dict], steps: int,
            log: Optional[Callable[[str], None]] = print) -> Dict[str, float]:
        t0 = time.perf_counter()
        metrics = {}
        for i in range(steps):
            batch = next(batches)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            if i % self.log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": i, **m})
                if log:
                    log(f"step {i:5d} loss={m['loss']:.4f} acc={m['token_acc']:.3f} "
                        f"ppl={m['ppl']:.1f} gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e}")
        wall = time.perf_counter() - t0
        return {**{k: float(v) for k, v in metrics.items()},
                "wall_s": wall, "steps_per_s": steps / wall}
