"""Training losses: next-token cross entropy (+ z-loss) + MoE aux."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None, z_loss: float = 0.0
                  ) -> Tuple[jnp.ndarray, dict]:
    """logits: (B, S, V) f32; labels: (B, S) int32; mask: (B, S) {0,1}."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return loss, {"nll": loss, "token_acc": acc,
                  "ppl": jnp.exp(jnp.minimum(loss, 20.0))}


def chunked_cross_entropy(hidden: jnp.ndarray, unembed_w: jnp.ndarray,
                          labels: jnp.ndarray, chunk: int = 512,
                          logit_softcap=None) -> Tuple[jnp.ndarray, dict]:
    """Cross entropy WITHOUT materializing (B, S, V) logits.

    hidden: (B, S, d); unembed_w: (V, d); labels: (B, S).
    Scans over sequence chunks (rematerialized), so live logits are
    (B, chunk, V) — the difference between petabytes and sub-GB at
    global-batch 256 x 4k seq x 256k vocab (DESIGN.md §5).
    """
    B, S, d = hidden.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    nc = (S + pad) // C
    hs = jnp.moveaxis(hidden.reshape(B, nc, C, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, C), 1, 0)
    valid = jnp.moveaxis(
        (jnp.arange(S + pad) < S).reshape(nc, C)[None].repeat(B, 0)
        .reshape(B, nc, C), 1, 0)
    w = unembed_w.astype(jnp.float32)

    def body(carry, inp):
        h_c, l_c, v_c = inp
        logits = h_c.astype(jnp.float32) @ w.T            # (B, C, V)
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        hit = (jnp.argmax(logits, -1) == l_c)
        m = v_c.astype(jnp.float32)
        nll_sum, acc_sum, n = carry
        return (nll_sum + ((lse - gold) * m).sum(),
                acc_sum + (hit * m).sum(), n + m.sum()), None

    body = jax.checkpoint(body)
    (nll_sum, acc_sum, n), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)),
        (hs, ls, valid))
    n = jnp.maximum(n, 1.0)
    loss = nll_sum / n
    return loss, {"nll": loss, "token_acc": acc_sum / n,
                  "ppl": jnp.exp(jnp.minimum(loss, 20.0))}
