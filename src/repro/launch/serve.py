"""Serving launcher: run the full Pick-and-Spin gateway on this host.

Spins a model pool (reduced variants on CPU; the same code drives TPU
deployments with full configs), routes a synthetic request stream, and
prints per-model serving stats + lifecycle events.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --pool smollm-360m,glm4-9b \
      --requests 32 --profile balanced --router hybrid
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.configs.registry import ARCHS
from repro.core.gateway import Gateway
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES
from repro.data.benchmarks import generate_corpus

DEFAULT_POOL = "smollm-360m,phi3-medium-14b,command-r-plus-104b"


def build_router(kind: str):
    if kind == "keyword":
        return KeywordRouter()
    # semantic/hybrid need the trained classifier checkpoint from
    # benchmarks; fall back to keyword with a notice if missing
    try:
        import os
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "../../../benchmarks"))
        from common import get_classifier
        sem, rep = get_classifier(log=None)
        if kind == "distilbert":
            return sem
        from repro.core.router import HybridRouter
        return HybridRouter(sem)
    except Exception as e:  # noqa: BLE001
        print(f"[serve] classifier unavailable ({e!r}); keyword routing")
        return KeywordRouter()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", default=DEFAULT_POOL)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--profile", default="quality", choices=sorted(PROFILES))
    ap.add_argument("--router", default="keyword",
                    choices=("keyword", "distilbert", "hybrid"))
    ap.add_argument("--deadline-s", type=float, default=120.0)
    args = ap.parse_args()

    pool = {}
    for name in args.pool.split(","):
        name = name.strip()
        if name not in ARCHS:
            raise SystemExit(f"unknown arch {name!r}; choose from "
                             f"{sorted(ARCHS)}")
        pool[name] = dataclasses.replace(ARCHS[name].reduced(),
                                         dtype="float32")

    gw = Gateway(pool, router=build_router(args.router),
                 profile=PROFILES[args.profile], max_seq=96)
    prompts = generate_corpus(max(args.requests, 64), seed=17)[: args.requests]

    t0 = time.perf_counter()
    results = [gw.handle(p.text, max_new_tokens=args.max_new_tokens,
                         deadline_s=args.deadline_s) for p in prompts]
    wall = time.perf_counter() - t0

    print(f"\nserved {len(results)} requests in {wall:.1f}s "
          f"(router={args.router}, profile={args.profile})")
    by_model = {}
    for r in results:
        by_model.setdefault((r.model, r.backend), []).append(r)
    print(f"{'service':30s} {'n':>4s} {'mean_ttft(s)':>12s} "
          f"{'mean_lat(s)':>11s} {'ok':>6s}")
    for (m, b), rs in sorted(by_model.items()):
        print(f"{m + '/' + b:30s} {len(rs):4d} "
              f"{np.mean([r.ttft_s for r in rs]):12.3f} "
              f"{np.mean([r.latency_s for r in rs]):11.3f} "
              f"{sum(r.completed for r in rs):3d}/{len(rs)}")
    print("\nlifecycle events (cold/warm starts):")
    for name, secs in gw.cold_starts:
        print(f"  {name:40s} {secs:6.2f}s")


if __name__ == "__main__":
    main()
