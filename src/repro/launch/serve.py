"""Serving launcher: run the full Pick-and-Spin gateway on this host.

Spins a model pool (reduced variants on CPU; the same code drives TPU
deployments with full configs), routes a synthetic request stream, and
prints per-model serving stats + lifecycle events.

Both planes speak serving API v2 (``repro.api``): typed
``CompletionRequest`` in, ``CompletionResponse`` out, shed requests as
structured results.
  * default      — serial ``Gateway`` facade: one blocking request at a
                   time (baseline; each request served to completion).
  * --concurrent — ``ServeFrontend``: open-loop Poisson arrivals
                   (--rate rps) into priority-ordered bounded queues,
                   many requests in flight across replica pools of real
                   engines, with the Algorithm-1 Spin loop ticking live
                   (scale-up under load, scale-to-zero when idle).

Usage:
  # serial baseline
  PYTHONPATH=src python -m repro.launch.serve --pool smollm-360m,glm4-9b \
      --requests 32 --profile balanced --router hybrid
  # concurrent serve plane
  PYTHONPATH=src python -m repro.launch.serve --concurrent --rate 8 \
      --pool smollm-360m,glm4-9b --requests 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.api import CompletionRequest
from repro.configs.registry import ARCHS
from repro.core.gateway import Gateway, ServeFrontend
from repro.core.orchestrator import SpinConfig
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES
from repro.obs import write_metrics_dump
from repro.serving import SchedulerConfig
from repro.data.benchmarks import generate_corpus

DEFAULT_POOL = "smollm-360m,phi3-medium-14b,command-r-plus-104b"


def build_router(kind: str):
    if kind == "keyword":
        return KeywordRouter()
    # semantic/hybrid need the trained classifier checkpoint from
    # benchmarks; fall back to keyword with a notice if missing
    try:
        import os
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                        "../../../benchmarks"))
        from common import get_classifier
        sem, rep = get_classifier(log=None)
        if kind == "distilbert":
            return sem
        from repro.core.router import HybridRouter
        return HybridRouter(sem)
    except Exception as e:  # noqa: BLE001
        print(f"[serve] classifier unavailable ({e!r}); keyword routing")
        return KeywordRouter()


def _print_results(results, wall, args, mode):
    print(f"\nserved {len(results)} requests in {wall:.1f}s "
          f"({mode}, router={args.router}, profile={args.profile}, "
          f"tput={len(results) / max(wall, 1e-9):.2f} rps)")
    by_model = {}
    for r in results:
        by_model.setdefault((r.model, r.backend), []).append(r)
    print(f"{'service':30s} {'n':>4s} {'mean_ttft(s)':>12s} "
          f"{'mean_lat(s)':>11s} {'ok':>6s}")
    for (m, b), rs in sorted(by_model.items()):
        print(f"{m + '/' + b:30s} {len(rs):4d} "
              f"{np.mean([r.ttft_s for r in rs]):12.3f} "
              f"{np.mean([r.latency_s for r in rs]):11.3f} "
              f"{sum(r.completed for r in rs):3d}/{len(rs)}")


def _dump_metrics(frontend, path: str) -> None:
    """--metrics-dump: write the observability artifact set (Prometheus
    exposition + decision events + request spans) and print the tail
    quantiles the registry answers live."""
    obs = frontend.obs
    if not path or obs is None:
        return
    reg = obs.registry
    print("\nper-service latency quantiles (from the metrics registry):")
    for label in reg.labels("ttft_s"):
        p50 = reg.quantile("ttft_s", label, 0.5)
        p95 = reg.quantile("ttft_s", label, 0.95)
        print(f"  {label:22s} ttft p50={p50:.3f}s p95={p95:.3f}s  "
              f"itl p95={reg.quantile('itl_s', label, 0.95):.4f}s  "
              f"e2e p95={reg.quantile('e2e_s', label, 0.95):.3f}s")
    paths = write_metrics_dump(path, reg, events=obs.events,
                               tracer=obs.tracer)
    print("metrics dump: " + ", ".join(paths))
    for label in reg.labels("cost_per_query_usd"):
        print(f"  {label:22s} measured cost/query "
              f"${reg.value('cost_per_query_usd', label):.6f}  "
              f"(conservation err "
              f"{obs.ledger.conservation_error():.2%})")


def run_serial(pool, args) -> None:
    gw = Gateway(pool, router=build_router(args.router),
                 profile=PROFILES[args.profile], max_seq=96)
    prompts = generate_corpus(max(args.requests, 64), seed=17)[: args.requests]

    t0 = time.perf_counter()
    results = [gw.handle(p.text, max_new_tokens=args.max_new_tokens,
                         deadline_s=args.deadline_s) for p in prompts]
    wall = time.perf_counter() - t0

    _print_results(results, wall, args, "serial")
    print("\nlifecycle events (cold/warm starts):")
    for name, secs in gw.cold_starts:
        print(f"  {name:40s} {secs:6.2f}s")
    _dump_metrics(gw.frontend, args.metrics_dump)


def run_concurrent(pool, args) -> None:
    spin = SpinConfig(window_s=60.0, cooldown_s=0.5, idle_tau_s=2.0,
                      tick_s=0.2, max_replicas=4)
    faults = None
    if args.chaos_rate > 0 or args.chaos_kill_step > 0:
        from repro.serving import FaultPlan, FaultSpec
        specs = []
        if args.chaos_kill_step > 0:
            specs.append(FaultSpec("step_error",
                                   at_step=args.chaos_kill_step, replica=0))
        if args.chaos_rate > 0:
            specs.append(FaultSpec("step_error", rate=args.chaos_rate))
        faults = FaultPlan(specs, seed=args.chaos_seed)
    gw = ServeFrontend(pool, router=build_router(args.router),
                       profile=PROFILES[args.profile], max_seq=96, spin=spin,
                       chunk_tokens=args.chunk_tokens or None,
                       step_token_budget=args.step_token_budget or None,
                       decode_burst=args.decode_burst,
                       spec_draft=args.spec_draft or None,
                       spec_k=args.spec_k,
                       flight_record=args.flight_record or None,
                       faults=faults,
                       sched=SchedulerConfig(
                           max_queue_depth=args.max_queue_depth))
    prompts = generate_corpus(max(args.requests, 64), seed=17)[: args.requests]
    rng = np.random.RandomState(3)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=len(prompts)))
    reqs = [CompletionRequest(prompt=p.text,
                              max_new_tokens=args.max_new_tokens,
                              deadline_s=args.deadline_s) for p in prompts]

    handles, wall = gw.serve_open_loop(reqs, arrivals)
    gw.settle(timeout_s=spin.idle_tau_s + 1.0)
    results = [h.response for h in handles if not h.shed]

    _print_results(results, wall, args, f"concurrent @ {args.rate:.1f} rps")
    shed = sum(h.shed for h in handles)
    if shed:
        print(f"shed at admission (queue depth {args.max_queue_depth}): "
              f"{shed}")
    if faults is not None:
        retried = sum(r.usage.retries > 0 for r in results if r is not None)
        print(f"chaos: {len(faults.fired)} fault(s) fired, "
              f"{gw.pool.quarantines} quarantine(s), "
              f"{retried} request(s) recovered via retry")
    print("\nlifecycle events (pool, measured on live engines):")
    for e in gw.pool.events:
        print(f"  {e}")
    print("orchestrator decisions (Algorithm 1, live):")
    for e in gw.orch_events:
        print(f"  {e}")
    _dump_metrics(gw, args.metrics_dump)
    if args.flight_record and gw.obs is not None:
        # on-demand dump: the run's final step ring + event tail joins
        # whatever automatic anomaly dumps already landed in the file
        p = gw.obs.flight.dump("on-demand", t=time.perf_counter())
        print(f"flight record: {p} "
              f"({len(gw.obs.flight.dumps)} dump(s))")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", default=DEFAULT_POOL)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--profile", default="quality", choices=sorted(PROFILES))
    ap.add_argument("--router", default="keyword",
                    choices=("keyword", "distilbert", "hybrid"))
    ap.add_argument("--deadline-s", type=float, default=120.0)
    ap.add_argument("--concurrent", action="store_true",
                    help="use the ServeFrontend serve plane (replica pools, "
                         "bounded queues, live Spin control loop)")
    ap.add_argument("--rate", type=float, default=6.0,
                    help="open-loop Poisson arrival rate, rps (--concurrent)")
    ap.add_argument("--max-queue-depth", type=int, default=64,
                    help="per-service admission bound (--concurrent)")
    ap.add_argument("--chunk-tokens", type=int, default=64,
                    help="prefill chunk bound per engine step; 0 = "
                         "whole-prompt prefill (--concurrent)")
    ap.add_argument("--step-token-budget", type=int, default=256,
                    help="tokens one engine step may spend across decode "
                         "+ prefill; 0 = unbounded (--concurrent)")
    ap.add_argument("--decode-burst", type=int, default=1,
                    help="fused decode iterations per step when no "
                         "prefill backlog is pending (1 = stepwise; "
                         "throughput knob for offline traffic, bounds "
                         "cancel/deadline latency by K tokens) "
                         "(--concurrent)")
    ap.add_argument("--spec-draft", default="",
                    help="registry arch that speculatively drafts for "
                         "every engine it can co-reside with (vocab "
                         "match + KV headroom; others keep plain "
                         "stepwise decode) (--concurrent)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per speculative verify step")
    ap.add_argument("--metrics-dump", default="",
                    help="write Prometheus exposition to PATH plus "
                         "PATH.events.jsonl (scale/shed/orch decisions) "
                         "and PATH.spans.jsonl (request lifecycles)")
    ap.add_argument("--chaos-rate", type=float, default=0.0,
                    help="per-step replica crash probability from a "
                         "seeded fault plan; failures are contained "
                         "(quarantine + deterministic retry) "
                         "(--concurrent)")
    ap.add_argument("--chaos-kill-step", type=int, default=0,
                    help="deterministically kill the first replica "
                         "incarnation at this engine step (0 = off) "
                         "(--concurrent)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the fault plan's Bernoulli streams")
    ap.add_argument("--flight-record", default="",
                    help="flight-recorder JSONL sink: automatic anomaly "
                         "dumps (shed storm, expiry burst, engine "
                         "exception) plus one on-demand dump at exit "
                         "(--concurrent)")
    args = ap.parse_args()

    pool = {}
    for name in args.pool.split(","):
        name = name.strip()
        if name not in ARCHS:
            raise SystemExit(f"unknown arch {name!r}; choose from "
                             f"{sorted(ARCHS)}")
        pool[name] = dataclasses.replace(ARCHS[name].reduced(),
                                         dtype="float32")

    if args.spec_draft and args.spec_draft not in ARCHS:
        raise SystemExit(f"unknown spec draft arch {args.spec_draft!r}; "
                         f"choose from {sorted(ARCHS)}")

    if args.concurrent:
        if args.rate <= 0:
            ap.error("--rate must be > 0 (open-loop arrivals per second)")
        run_concurrent(pool, args)
    else:
        run_serial(pool, args)


if __name__ == "__main__":
    main()
