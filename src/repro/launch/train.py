"""Training launcher.

Two modes:
  * CPU (default): trains the REDUCED variant of ``--arch`` for real on
    this host — the end-to-end driver used by examples/train_lm.py.
  * --production: builds the sharded train step for the production mesh
    and reports the lowered/compiled artifact (use launch/dryrun.py for
    the full sweep; this is the single-config entry point).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 50
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import ARCHS, get_config
from repro.data.pipeline import lm_batches
from repro.models import init_model
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (assigned) config instead of reduced "
                         "(requires the production mesh / dryrun env)")
    ap.add_argument("--ckpt", default=None, help="save final params here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch={args.batch} seq={args.seq}")

    params = init_model(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                      total_steps=args.steps)
    tr = Trainer(cfg, opt, params, log_every=max(1, args.steps // 20))
    stats = tr.fit(lm_batches(cfg, args.batch, args.seq), steps=args.steps)
    print({k: round(float(v), 4) for k, v in stats.items()})
    if args.ckpt:
        from repro.checkpoint.checkpoint import save_pytree
        save_pytree(tr.params, args.ckpt)
        print(f"saved params to {args.ckpt}")


if __name__ == "__main__":
    main()
