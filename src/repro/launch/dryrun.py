import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first lines, before any other import: jax locks the
#   device count on first init. Set ONLY here — smoke tests and benches
#   must see 1 device.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) pair, build the sharded step the
shape exercises (train / prefill / serve-decode), ``.lower().compile()``
it on the production mesh — (data=16, model=16) single pod and
(pod=2, data=16, model=16) multi-pod — and record:

  * ``compiled.memory_analysis()``  (fits-per-device proof)
  * ``compiled.cost_analysis()``    (FLOPs / bytes for §Roofline)
  * collective bytes parsed from the optimized HLO (§Roofline)

Artifacts land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json;
the roofline report and EXPERIMENTS.md §Dry-run read from there.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape
from repro.configs.registry import (ARCHS, LONG_CONTEXT_MODE,
                                    get_config_for_shape, supported_shapes)
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        logits_sharding, opt_shardings,
                                        param_shardings, replicated)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SDS, cache_specs_tree, input_specs, param_specs
from repro.models import model_decode, model_prefill
from repro.roofline.analysis import (Roofline, analytic_memory_bytes,
                                     analytic_model_flops, parse_collectives)
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.trainer import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts/dryrun")


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def build_lowered(arch: str, shape: InputShape, mesh, q_chunk: int = 512,
                  loss_chunk: int = 256, decode_moe_cf=None,
                  remat: bool = True, mla_seq_shard: bool = True,
                  kv_int8: bool = False):
    """Construct + lower the sharded step for this (arch, shape)."""
    cfg = get_config_for_shape(arch, shape.name)
    if kv_int8:
        cfg = cfg.with_int8_kv()
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        pdtype = jnp.float32
        psds = param_specs(cfg, pdtype)
        pshard = param_shardings(psds, mesh)
        osds = jax.eval_shape(init_adamw, psds)
        oshard = opt_shardings(osds, mesh)
        bsds = input_specs(cfg, shape)
        bshard = batch_shardings(cfg, bsds, mesh)
        opt = AdamWConfig()
        step = make_train_step(cfg, opt, q_chunk=q_chunk,
                               loss_chunk=loss_chunk, remat=remat)
        rep = replicated(mesh)
        metric_shard = {k: rep for k in
                        ("nll", "token_acc", "ppl", "moe_aux", "loss",
                         "grad_norm", "lr")}
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, metric_shard),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(psds, osds, bsds)
        return cfg, lowered

    pdtype = jnp.bfloat16
    psds = param_specs(cfg, pdtype)
    pshard = param_shardings(psds, mesh)

    if shape.kind == "prefill":
        # vision/audio prefixes extend the prefilled sequence
        cache_len = S + (cfg.frontend_seq if cfg.family == "vlm" else 0)
        bsds = input_specs(cfg, shape)
        bshard = batch_shardings(cfg, bsds, mesh)
        csds = jax.eval_shape(
            lambda p, b: model_prefill(p, cfg, b, cache_len, q_chunk=q_chunk)[1],
            psds, bsds)
        cshard = cache_shardings(cfg, csds, mesh, B)
        lshard = logits_sharding(cfg, mesh, B, with_seq=False)

        def prefill_step(params, batch):
            return model_prefill(params, cfg, batch, cache_len, q_chunk=q_chunk)

        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard),
                     out_shardings=(lshard, cshard))
        with mesh:
            lowered = fn.lower(psds, bsds)
        return cfg, lowered

    # decode: ONE new token against a seq_len cache
    csds = cache_specs_tree(cfg, B, S, jnp.bfloat16)
    cshard = cache_shardings(cfg, csds, mesh, B, mla_seq_shard=mla_seq_shard)
    tok_sds = SDS((B, 1), jnp.int32)
    tok_shard = batch_shardings(cfg, {"tokens": tok_sds}, mesh)["tokens"]
    pos_sds = SDS((), jnp.int32)
    lshard = logits_sharding(cfg, mesh, B, with_seq=False)

    def serve_step(params, token, cache, pos):
        return model_decode(params, cfg, token, cache, pos,
                            moe_cf=decode_moe_cf)

    fn = jax.jit(serve_step,
                 in_shardings=(pshard, tok_shard, cshard, replicated(mesh)),
                 out_shardings=(lshard, cshard),
                 donate_argnums=(2,))
    with mesh:
        lowered = fn.lower(psds, tok_sds, csds, pos_sds)
    return cfg, lowered


def run_pair(arch: str, shape_name: str, multi_pod: bool = False,
             save: bool = True, verbose: bool = True, variant: str = "",
             mesh_shape=None, decode_moe_cf=None, q_chunk_: int = 512,
             loss_chunk_: int = 256, remat_: bool = True,
             mla_seq_shard: bool = True, kv_int8: bool = False) -> Dict:
    """``variant`` labels a §Perf experiment (artifact name suffix);
    ``mesh_shape=(data, model)`` overrides the production mesh for
    per-instance topologies; ``decode_moe_cf`` sets the serve-step MoE
    dispatch capacity (None = no-drop)."""
    shape = INPUT_SHAPES[shape_name]
    if mesh_shape:
        from repro.launch.mesh import make_custom_mesh
        mesh = make_custom_mesh(*mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.devices.shape)))
    t0 = time.perf_counter()
    cfg, lowered = build_lowered(arch, shape, mesh,
                                 decode_moe_cf=decode_moe_cf,
                                 q_chunk=q_chunk_, loss_chunk=loss_chunk_,
                                 remat=remat_, mla_seq_shard=mla_seq_shard,
                                 kv_int8=kv_int8)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))

    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)

    # analytic cross-check (scan-undercount correction)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = analytic_model_flops(cfg.active_param_count(), shape.kind, tokens)
    hlo_flops_raw = hlo_flops
    hlo_bytes_raw = hlo_bytes
    scan_corrected = False
    if hlo_flops < 0.2 * mf:
        # XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE
        # (verified empirically: flops/bytes identical for 2/4/8-layer
        # stacks). Floor FLOPs at the analytic model FLOPs; floor BYTES at
        # the analytic HBM-traffic model (raw values stay in the artifact).
        hlo_flops = mf
        scan_corrected = True
    cache_bytes = 0
    if shape.kind != "train":
        import jax as _jax
        from repro.launch.specs import cache_specs_tree as _cst
        ctree = _cst(cfg, shape.global_batch, shape.seq_len)
        cache_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                          for l in _jax.tree_util.tree_leaves(ctree))
    mem_floor = analytic_memory_bytes(
        cfg.param_count(), cfg.active_param_count(), shape.kind, tokens,
        cfg.d_model, cfg.num_layers, cache_bytes)
    hlo_bytes = max(hlo_bytes_raw, mem_floor)

    mesh_label = (f"mesh{mesh_shape[0]}x{mesh_shape[1]}" if mesh_shape
                  else _mesh_name(multi_pod))
    rl = Roofline(arch=arch, shape=shape_name, mesh=mesh_label,
                  chips=n_chips, hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
                  collective_bytes=float(coll.total_bytes), model_flops=mf,
                  scan_corrected=scan_corrected)

    art = {
        **rl.row(),
        "hlo_flops_raw": hlo_flops_raw,
        "hlo_bytes_raw": hlo_bytes_raw,
        "analytic_memory_bytes": mem_floor,
        "cache_bytes": cache_bytes,
        "lower_s": t_lower, "compile_s": t_compile,
        "collectives_bytes_by_op": coll.bytes_by_op,
        "collectives_count_by_op": coll.count_by_op,
        "memory_analysis": {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)},
        "sliding_window": cfg.sliding_window,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {_mesh_name(multi_pod)}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"compute {rl.compute_s*1e3:.2f}ms memory {rl.memory_s*1e3:.2f}ms "
              f"collective {rl.collective_s*1e3:.2f}ms -> {rl.dominant}"
              f"{' (scan-corrected)' if scan_corrected else ''}")
        print(f"  memory_analysis: "
              f"{ {k: f'{v/1e9:.2f}GB' for k, v in art['memory_analysis'].items()} }")
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        tag = f"{arch}__{shape_name}__{mesh_label}"
        if variant:
            tag += f"__{variant}"
            art["variant"] = variant
        with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
            json.dump(art, f, indent=1)
    return art


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--mesh-shape", default=None,
                    help="DATAxMODEL per-instance topology, e.g. 32x8")
    ap.add_argument("--decode-moe-cf", type=float, default=None)
    args = ap.parse_args()
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    pairs = []
    if args.all:
        for arch in ARCHS:
            for shape in supported_shapes(arch):
                pairs.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = []
    for mp in meshes:
        for arch, shape in pairs:
            fn = os.path.join(ARTIFACT_DIR,
                              f"{arch}__{shape}__{_mesh_name(mp)}.json")
            if args.skip_existing and os.path.exists(fn):
                continue
            try:
                run_pair(arch, shape, multi_pod=mp, variant=args.variant,
                         mesh_shape=mesh_shape,
                         decode_moe_cf=args.decode_moe_cf)
            except Exception as e:       # noqa: BLE001 — report and continue
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
