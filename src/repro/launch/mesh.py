"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run forces 512 host devices (its own
first two lines); real deployments get real TPU topologies.

  single pod : (data=16, model=16)            = 256 chips (v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — the "
            f"dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            f"=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU tests."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def make_custom_mesh(data: int, model: int, pod: int = 0):
    """Per-instance serving topology (the service matrix may give each
    (model x backend) instance its own slice shape — a beyond-paper
    optimization explored in EXPERIMENTS.md §Perf)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             devices=jax.devices()[: pod * data * model])
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
