"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

Weak-type-correct, shardable, zero allocation — consumed by
``jax.jit(...).lower()`` in the dry-run and by the roofline module.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig

SDS = jax.ShapeDtypeStruct


def _frontend_len(cfg: ModelConfig) -> int:
    return cfg.frontend_seq


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, SDS]:
    """Batch spec for the step the shape exercises.

    train    -> {tokens, labels (+modality extras)}   (B, S)
    prefill  -> {tokens (+modality extras)}           (B, S)
    decode   -> {token}  (B, 1) — the cache is built separately
    """
    B, S = shape.global_batch, shape.seq_len
    adt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind == "decode":
        return {"tokens": SDS((B, 1), jnp.int32)}
    out: Dict[str, SDS] = {"tokens": SDS((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = SDS((B, S), jnp.int32)
    if cfg.family == "vlm":
        F = _frontend_len(cfg)
        out["vision_embeds"] = SDS((B, F, cfg.d_model), adt)
        out["positions"] = SDS((B, F + S, 3), jnp.int32)
    if cfg.family == "encdec":
        F = _frontend_len(cfg)
        out["src_embeds"] = SDS((B, F, cfg.d_model), adt)
    return out


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    """Parameter ShapeDtypeStruct tree via eval_shape (no allocation)."""
    from repro.models import init_model
    key = SDS((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_model(cfg, k, dtype), key)


def cache_specs_tree(cfg: ModelConfig, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    from repro.models import init_cache
    return jax.eval_shape(lambda: init_cache(cfg, batch, cache_len, dtype))
