"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                  scale: Optional[float] = None):
    """q: (B,Hq,Sq,D); k/v: (B,Hkv,Skv,D). Naive full-materialized softmax."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def ref_decode_attention(q, k_cache, v_cache, valid_len, *, ring=False,
                         scale: Optional[float] = None):
    """q: (B,Hq,D); caches: (B,Hkv,S,D); valid_len: (B,)."""
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    k = jnp.repeat(k_cache, G, axis=1)
    v = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    slot = jnp.arange(S)[None, :]
    vl = valid_len[:, None]
    live = slot < jnp.minimum(vl, S) if ring else slot < vl
    s = jnp.where(live[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w, v.astype(jnp.float32)).astype(q.dtype)


def ref_paged_decode_attention(q, k_pool, v_pool, block_tables, valid_len,
                               *, scale: Optional[float] = None):
    """Paged decode oracle: gather KV through the block table, then run the
    dense decode reference.

    q: (B, Hq, D); pools: (NB, BS, Hkv, D); block_tables: (B, NBseq) int32
    ids into the pool's leading axis; valid_len: (B,) written tokens."""
    B = q.shape[0]
    NB, BS, Hkv, D = k_pool.shape
    # (B, NBseq, BS, Hkv, D) -> (B, Hkv, NBseq*BS, D)
    def gather(pool):
        g = jnp.take(pool, block_tables, axis=0)
        g = g.reshape(B, -1, Hkv, pool.shape[-1])
        return jnp.moveaxis(g, 1, 2)

    return ref_decode_attention(q, gather(k_pool), gather(v_pool), valid_len,
                                ring=False, scale=scale)


def ref_paged_prefill_attention(q, k_pool, v_pool, k_new, v_new,
                                block_table, start, s_real,
                                *, scale: Optional[float] = None):
    """Chunked prefill-append oracle: one sequence's query chunk attends
    the KV it already cached (gathered through the block table, positions
    ``< start``) PLUS the chunk's own fresh KV (causal within the chunk,
    limited to ``s_real`` live tokens — the rest is bucket padding).

    q: (Sb, Hq, D) chunk queries at global offset ``start``;
    pools: (NB, BS, Hkv, D); k_new/v_new: (Sb, Hkv, D); block_table:
    (NBctx,) int32. Returns (Sb, Hq, Dv)."""
    Sb, Hq, D = q.shape
    NB, BS, Hkv, _ = k_pool.shape
    G = Hq // Hkv
    Dv = v_pool.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    ctx_k = jnp.take(k_pool, block_table, axis=0).reshape(-1, Hkv, D)
    ctx_v = jnp.take(v_pool, block_table, axis=0).reshape(-1, Hkv, Dv)
    CtxT = ctx_k.shape[0]
    k = jnp.concatenate([ctx_k, k_new], axis=0)         # (CtxT+Sb, Hkv, D)
    v = jnp.concatenate([ctx_v, v_new], axis=0)
    k = jnp.repeat(k, G, axis=1)                        # (K, Hq, D)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale       # (Hq, Sb, K)
    qi = jnp.arange(Sb)[:, None]
    live_ctx = jnp.broadcast_to((jnp.arange(CtxT) < start)[None, :],
                                (Sb, CtxT))
    kj = jnp.arange(Sb)[None, :]
    live_new = (kj <= qi) & (kj < s_real)
    mask = jnp.concatenate([live_ctx, live_new], axis=1)      # (Sb, K)
    s = jnp.where(mask[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_ssd(x, dt, A, Bm, Cm):
    """Naive O(L) recurrence. x: (B,L,H,P); dt: (B,L,H); A: (H,);
    Bm/Cm: (B,L,H,N). Returns (y (B,L,H,P) f32, final_state (B,H,P,N) f32)."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    x, dt, A, Bm, Cm = (t.astype(f32) for t in (x, dt, A, Bm, Cm))

    def step(h, inp):
        xt, dtt, bt, ct = inp            # (B,H,P), (B,H), (B,H,N), (B,H,N)
        g = jnp.exp(dtt * A[None, :])
        h = h * g[..., None, None] + jnp.einsum("bhp,bhn->bhpn",
                                                xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), f32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), final
