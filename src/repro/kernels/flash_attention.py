"""Pallas TPU kernel: blocked online-softmax (flash) prefill attention.

TPU adaptation (DESIGN.md §6): GPU flash-attention's warp-level tiling maps
to a sequential Pallas grid over (batch, q-head, q-block) with an inner
fori-loop over KV blocks; accumulators (m, l, acc) live in VMEM scratch.
Block shapes are multiples of the (8, 128) VPU / (128, 128) MXU tiles.
GQA is handled in the K/V BlockSpec index maps (head h reads KV head
h // group_size) — no KV replication in HBM.

Supports causal masking and sliding-window (ring-relevant band) masking.
Validated against ``repro.kernels.ref.ref_attention`` in interpret mode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               block_q: int, block_k: int, seq_kv: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, D)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

    n_kv = seq_kv // block_k

    def body(kj, _):
        # leading indices as scalar arrays: plain python ints in a pl.load
        # indexer are rejected by newer pallas interpreters
        zero = jnp.int32(0)
        k_blk = pl.load(k_ref, (zero, zero, pl.ds(kj * block_k, block_k),
                                slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (zero, zero, pl.ds(kj * block_k, block_k),
                                slice(None))).astype(jnp.float32)
        s = q @ k_blk.T                                     # (bq, bk)
        k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v_blk
        m_scr[...] = m_new
        return ()

    if causal:
        # skip fully-masked kv blocks past the diagonal
        last = jnp.minimum(n_kv, (qi + 1) * block_q // block_k + 1)
    else:
        last = n_kv
    if window is not None:
        first = jnp.maximum(0, (qi * block_q - window) // block_k)
    else:
        first = 0
    jax.lax.fori_loop(first, last, body, ())

    l = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,            # (B, Hq, Sq, D)
    k: jnp.ndarray,            # (B, Hkv, Skv, D)
    v: jnp.ndarray,            # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)

    grid = (B, Hq, Sq // block_q)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_kv=Skv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Skv, D), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
