"""Pallas TPU kernel: single-token GQA decode attention (flash-decoding).

One new query token attends to a long KV cache. The grid walks
(batch, kv-head); the group of query heads sharing a KV head is processed
together as the (G, D) left operand of the MXU matmuls — this keeps the
matmul M-dimension >= 8 even for one token, instead of wasting the MXU on
a single row. KV is streamed block-by-block through VMEM with online
softmax in scratch. Supports both linear caches (valid prefix mask) and
ring-buffer sliding-window caches (all slots < min(valid, S) live —
softmax is order-invariant, so ring order needs no unpermute).

The `latency` serving backend profile uses this kernel; validated against
``ref.ref_decode_attention`` in interpret mode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, scale: float, block_k: int, seq_kv: int, ring: bool):
    q = q_ref[0, 0].astype(jnp.float32) * scale             # (G, D)
    valid = valid_ref[pl.program_id(0)]                     # written entries
    live_max = jnp.minimum(valid, seq_kv) if ring else valid

    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)

    n_blocks = pl.cdiv(live_max, block_k)

    def body(kj, _):
        # leading indices as scalar arrays: plain python ints in a pl.load
        # indexer are rejected by newer pallas interpreters
        zero = jnp.int32(0)
        k_blk = pl.load(k_ref, (zero, zero, pl.ds(kj * block_k, block_k),
                                slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (zero, zero, pl.ds(kj * block_k, block_k),
                                slice(None))).astype(jnp.float32)
        s = q @ k_blk.T                                     # (G, bk)
        slot = kj * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((slot < live_max)[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v_blk
        m_scr[...] = m_new
        return ()

    jax.lax.fori_loop(0, n_blocks, body, ())
    l = jnp.maximum(l_scr[...], 1e-30)
    o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,            # (B, Hq, D) — one token per sequence
    k_cache: jnp.ndarray,      # (B, Hkv, S, D)
    v_cache: jnp.ndarray,      # (B, Hkv, S, D)
    valid_len: jnp.ndarray,    # (B,) int32 — number of written entries
    *,
    ring: bool = False,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                               seq_kv=S, ring=ring)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, valid: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, valid: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, D), lambda b, h, valid: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, valid: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(valid_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
