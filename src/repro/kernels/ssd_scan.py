"""Pallas TPU kernel: Mamba2 SSD chunked scan. [arXiv:2405.21060]

TPU adaptation (DESIGN.md §6): the chunk's dual ("attention-like") form is
three MXU matmuls per chunk — C·Bᵀ (Q x Q), masked-decay weighting, and the
(Q x Q)·(Q x P) product — plus a rank-N state update. Chunk length Q = 128
aligns every matmul to the 128x128 MXU tile. The inter-chunk recurrence is
carried in VMEM scratch across the sequential grid walk over chunks (the
TPU grid is executed in order, so the (P, N) state scratch persists from
chunk j to chunk j+1; the grid is (B, H, n_chunks) with chunks innermost).

Validated against ``ref.ref_ssd`` (naive recurrence) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                h_scr, *, chunk: int):
    cj = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)            # (Q,)
    A = a_ref[0].astype(jnp.float32)                    # scalar (per head)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)          # (Q, N)

    a = dt * A                                          # (Q,) decay logs
    a_cum = jnp.cumsum(a)                               # inclusive
    xdt = x * dt[:, None]                               # (Q, P)

    # intra-chunk dual form: L[i,j] = exp(cum[i]-cum[j]) for i >= j
    seg = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = (Cm @ Bm.T) * Lmat                          # (Q, Q) on MXU
    y = scores @ xdt                                     # (Q, P) on MXU

    # carried-in state contribution
    h = h_scr[...]                                       # (N, P)
    y += jnp.exp(a_cum)[:, None] * (Cm @ h)

    # state update: h' = exp(sum a) * h + sum_l exp(cum[-1]-cum[l]) B_l x_l
    decay_tail = jnp.exp(a_cum[-1] - a_cum)              # (Q,)
    h_scr[...] = jnp.exp(a_cum[-1]) * h + (Bm * decay_tail[:, None]).T @ xdt

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(cj == nc - 1)
    def _final():
        state_ref[0, 0] = h_scr[...].T.astype(state_ref.dtype)  # (P, N)


def ssd_scan(
    x: jnp.ndarray,      # (B, L, H, P)
    dt: jnp.ndarray,     # (B, L, H) — post-softplus
    A: jnp.ndarray,      # (H,) negative
    Bm: jnp.ndarray,     # (B, L, H, N)
    Cm: jnp.ndarray,     # (B, L, H, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,L,H,P) f32-accumulated, final_state (B,H,P,N) f32)."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, state
