"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this container) the kernels execute in interpret mode
— the kernel body runs as traced JAX on CPU, preserving semantics for
tests. On TPU they compile to Mosaic. ``interpret`` can be forced either
way for debugging.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ring", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, valid_len, *, ring: bool = False,
                     block_k: int = 512, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _dec.decode_attention(q, k_cache, v_cache, valid_len, ring=ring,
                                 block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, valid_len, *,
                           interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                         valid_len, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pool, v_pool, k_new, v_new, block_table,
                            start, s_real, *,
                            interpret: Optional[bool] = None):
    """Chunked prefill-append: a query chunk of one sequence attends its
    cached blocks (positions < start) plus its own fresh KV, causal
    within the chunk — the kernel contract behind token-budget
    continuous batching (see serving/engine.py)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_prefill_attention(q, k_pool, v_pool, k_new, v_new,
                                          block_table, start, s_real,
                                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
