"""Jit'd public wrappers for the Pallas kernels + the kernel-dispatch
registry.

On non-TPU backends (this container) the kernels execute in interpret mode
— the kernel body runs as traced JAX on CPU, preserving semantics for
tests. On TPU they compile to Mosaic. ``interpret`` can be forced either
way for debugging.

KERNEL DISPATCH: the model trunk (``models/attention.py`` /
``models/transformer.py``) asks ``kernel_mode()`` which implementation of
the paged-attention contract to trace into the engine's jitted hot path:

    mode        decode / chunk-prefill implementation       default on
    ---------   -----------------------------------------   -----------
    mosaic      Pallas kernels compiled by Mosaic            TPU
    interpret   same Pallas kernels, interpreter-executed    (tests)
    reference   the jnp trunk (gather + dense attention)     CPU

``reference`` stays the trunk on CPU because interpret-mode Pallas is an
interpreter, not a fast path; on TPU the Mosaic kernels ARE the hot path
— the decode kernel streams exactly the blocks a sequence owns through
its scalar-prefetched table instead of materializing a gathered
(B, max_seq) KV copy per step. ``kernel_dispatch(mode)`` overrides the
default (tests pin ``interpret`` to execute the real kernel bodies and
``reference`` for the oracle); the mode is read at TRACE time, so build
engines inside the context. int8-quantized KV pools always take the
reference path (the kernels read raw k/v blocks, not scale pairs).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _paged
from repro.kernels import ssd_scan as _ssd

KERNEL_MODES = ("mosaic", "interpret", "reference")
_forced_mode: Optional[str] = None


def kernel_mode() -> str:
    """Resolve the active dispatch mode (see module docstring table)."""
    if _forced_mode is not None:
        return _forced_mode
    return "mosaic" if jax.default_backend() == "tpu" else "reference"


def set_kernel_mode(mode: Optional[str]) -> None:
    """Force a dispatch mode process-wide (None restores the default).
    Affects functions traced AFTER the call — jit caches keep whatever
    mode they were traced under."""
    global _forced_mode
    if mode is not None and mode not in KERNEL_MODES:
        raise ValueError(f"kernel mode {mode!r} not in {KERNEL_MODES}")
    _forced_mode = mode


@contextlib.contextmanager
def kernel_dispatch(mode: str):
    """Scoped ``set_kernel_mode`` for tests/benchmarks."""
    prev = _forced_mode
    set_kernel_mode(mode)
    try:
        yield
    finally:
        set_kernel_mode(prev)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ring", "block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, valid_len, *, ring: bool = False,
                     block_k: int = 512, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _dec.decode_attention(q, k_cache, v_cache, valid_len, ring=ring,
                                 block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, valid_len, *,
                           interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                         valid_len, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_attention(q, k_pool, v_pool, k_new, v_new, block_table,
                            start, s_real, *,
                            interpret: Optional[bool] = None):
    """Chunked prefill-append: a query chunk of one sequence attends its
    cached blocks (positions < start) plus its own fresh KV, causal
    within the chunk — the kernel contract behind token-budget
    continuous batching (see serving/engine.py)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _paged.paged_prefill_attention(q, k_pool, v_pool, k_new, v_new,
                                          block_table, start, s_real,
                                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
