"""Pallas TPU kernel: single-token GQA decode attention over a PAGED cache.

Same online-softmax structure as ``decode_attention.py``, but KV lives in
a global block pool shaped (num_blocks, block_size, Hkv, D) shared by
every sequence, and each sequence names its blocks through a block table.
The grid walks (batch, kv-head, block-slot); the per-sequence block table
is a scalar-prefetch operand, so each KV block's index map dereferences
``table[b, j]`` and the DMA engine streams exactly the blocks the
sequence owns — attention never touches another request's memory, and a
shared prefix block is read in place by every sequence that leases it
(no gather materialization, no copies).

Scratch accumulators (m, l, acc) persist across the sequential block-slot
grid dimension; the output tile is flushed once on the last slot. Blocks
past ``valid_len`` are skipped entirely (their DMA still points at a
real block, masked out of the softmax). Validated against
``ref.ref_paged_decode_attention`` in interpret mode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, block_size: int,
                  blocks_per_seq: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[b]                                    # written tokens
    start = j * block_size

    @pl.when(start < valid)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (G, D)
        k_blk = k_ref[0, :, 0, :].astype(jnp.float32)       # (bs, D)
        v_blk = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k_blk.T                                     # (G, bs)
        slot = start + jax.lax.iota(jnp.int32, block_size)
        s = jnp.where((slot < valid)[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v_blk
        m_scr[...] = m_new

    @pl.when(j == blocks_per_seq - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(
    q: jnp.ndarray,             # (B, Hq, D) — one token per sequence
    k_pool: jnp.ndarray,        # (NB, BS, Hkv, D) global block pool
    v_pool: jnp.ndarray,        # (NB, BS, Hkv, Dv)
    block_tables: jnp.ndarray,  # (B, NBseq) int32 pool block ids
    valid_len: jnp.ndarray,     # (B,) int32 — written tokens per sequence
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    NB, BS, Hkv, Dv = v_pool.shape
    NBseq = block_tables.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_paged_kernel, scale=scale, block_size=BS,
                               blocks_per_seq=NBseq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # block tables + valid lens
        grid=(B, Hkv, NBseq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, vl: (b, h, 0, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda b, h, j, bt, vl: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, BS, 1, Dv),
                         lambda b, h, j, bt, vl: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, j, bt, vl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), valid_len.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(B, Hq, Dv)
