"""Pallas TPU kernels: GQA attention over a PAGED cache.

Two kernels share the block-table discipline:

  * ``paged_decode_attention`` — one new token per sequence against the
    cached blocks (the decode hot path);
  * ``paged_prefill_attention`` — a PREFILL CHUNK: ``Sb`` queries of one
    sequence attend every token the sequence already cached (streamed
    block by block through its table, positions ``< start``) plus the
    chunk's own fresh KV, causal within the chunk. This is the
    chunk-append contract continuous batching needs: a long prompt is
    prefilled ``chunk_tokens`` at a time across engine steps, each chunk
    attending cached-prefix + itself, so decode iterations interleave
    between chunks instead of stalling behind a whole-prompt prefill.


Decode: same online-softmax structure as ``decode_attention.py``, but KV lives in
a global block pool shaped (num_blocks, block_size, Hkv, D) shared by
every sequence, and each sequence names its blocks through a block table.
The grid walks (batch, kv-head, block-slot); the per-sequence block table
is a scalar-prefetch operand, so each KV block's index map dereferences
``table[b, j]`` and the DMA engine streams exactly the blocks the
sequence owns — attention never touches another request's memory, and a
shared prefix block is read in place by every sequence that leases it
(no gather materialization, no copies).

Scratch accumulators (m, l, acc) persist across the sequential block-slot
grid dimension; the output tile is flushed once on the last slot. Blocks
past ``valid_len`` are skipped entirely (their DMA still points at a
real block, masked out of the softmax). Validated against
``ref.ref_paged_decode_attention`` in interpret mode.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, block_size: int,
                  blocks_per_seq: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = valid_ref[b]                                    # written tokens
    start = j * block_size

    @pl.when(start < valid)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32) * scale         # (G, D)
        k_blk = k_ref[0, :, 0, :].astype(jnp.float32)       # (bs, D)
        v_blk = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k_blk.T                                     # (G, bs)
        slot = start + jax.lax.iota(jnp.int32, block_size)
        s = jnp.where((slot < valid)[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v_blk
        m_scr[...] = m_new

    @pl.when(j == blocks_per_seq - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(
    q: jnp.ndarray,             # (B, Hq, D) — one token per sequence
    k_pool: jnp.ndarray,        # (NB, BS, Hkv, D) global block pool
    v_pool: jnp.ndarray,        # (NB, BS, Hkv, Dv)
    block_tables: jnp.ndarray,  # (B, NBseq) int32 pool block ids
    valid_len: jnp.ndarray,     # (B,) int32 — written tokens per sequence
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    NB, BS, Hkv, Dv = v_pool.shape
    NBseq = block_tables.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(_paged_kernel, scale=scale, block_size=BS,
                               blocks_per_seq=NBseq)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # block tables + valid lens
        grid=(B, Hkv, NBseq),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, bt, vl: (b, h, 0, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda b, h, j, bt, vl: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, BS, 1, Dv),
                         lambda b, h, j, bt, vl: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv),
                               lambda b, h, j, bt, vl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), valid_len.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(B, Hq, Dv)


# ---------------------------------------------------------------------------
# chunked prefill-append


def _chunk_prefill_kernel(info_ref, bt_ref, q_ref, kp_ref, vp_ref, kn_ref,
                          vn_ref, o_ref, m_scr, l_scr, acc_scr, *,
                          scale: float, block_size: int, n_ctx: int,
                          group: int):
    """Grid (Hkv, n_ctx + 1): the sequential j dimension streams the
    sequence's cached context blocks (j < n_ctx) and finishes on the
    chunk's own KV (j == n_ctx), accumulating one online softmax across
    both — so a chunk's attention never materializes (Sb x history)."""
    j = pl.program_id(1)
    start = info_ref[0]                       # cached tokens (chunk offset)
    s_real = info_ref[1]                      # live (non-pad) chunk tokens

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    SbG, D = q_ref.shape[1] * q_ref.shape[2], q_ref.shape[3]
    q = (q_ref[0].astype(jnp.float32) * scale).reshape(SbG, D)
    # query row r of the flattened (Sb*G) tile belongs to chunk token r//G
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (SbG, 1), 0) // group

    def online(s, v_blk):
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v_blk
        m_scr[...] = m_new

    # cached context: every token of this block below `start` is live for
    # every chunk query (it precedes the whole chunk). Skip blocks with
    # nothing cached — attending an all-masked block would poison the
    # online softmax (m stays -inf and exp(s - m) saturates to 1).
    @pl.when((j < n_ctx) & (j * block_size < start))
    def _ctx():
        k_blk = kp_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)
        v_blk = vp_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k_blk.T                                     # (SbG, BS)
        slot = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)
        online(jnp.where(slot < start, s, NEG_INF), v_blk)

    # the chunk itself: causal within the chunk, pads masked out
    @pl.when(j == n_ctx)
    def _self():
        k_new = kn_ref[:, 0, :].astype(jnp.float32)         # (Sb, D)
        v_new = vn_ref[:, 0, :].astype(jnp.float32)
        s = q @ k_new.T                                     # (SbG, Sb)
        k_idx = jax.lax.broadcasted_iota(jnp.int32, (1, s.shape[1]), 1)
        live = (k_idx <= q_idx) & (k_idx < s_real)
        online(jnp.where(live, s, NEG_INF), v_new)

    @pl.when(j == n_ctx)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        out = (acc_scr[...] / l[:, None])
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def paged_prefill_attention(
    q: jnp.ndarray,             # (Sb, Hq, D) one sequence's chunk queries
    k_pool: jnp.ndarray,        # (NB, BS, Hkv, D) global block pool
    v_pool: jnp.ndarray,        # (NB, BS, Hkv, Dv)
    k_new: jnp.ndarray,         # (Sb, Hkv, D) the chunk's fresh KV
    v_new: jnp.ndarray,         # (Sb, Hkv, Dv)
    block_table: jnp.ndarray,   # (NBctx,) int32 blocks holding the context
    start,                      # scalar int32: tokens already cached
    s_real,                     # scalar int32: live chunk tokens (<= Sb)
    *,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Chunk-append attention for continuous batching: the ``Sb`` chunk
    queries run at global positions ``start .. start+Sb-1`` against the
    sequence's cached blocks plus the chunk's own KV (causal). The chunk
    KV is an operand, not yet in the pool — the caller scatters it after
    (gather/compute/scatter, same split the paged engine prefill uses)."""
    Sb, Hq, D = q.shape
    NB, BS, Hkv, Dv = v_pool.shape
    if block_table.shape[0] == 0:       # no context yet: dummy (masked) block
        block_table = jnp.zeros((1,), jnp.int32)
    NBctx = block_table.shape[0]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    qg = jnp.moveaxis(q.reshape(Sb, Hkv, G, D), 1, 0)   # (Hkv, Sb, G, D)
    info = jnp.stack([jnp.asarray(start, jnp.int32),
                      jnp.asarray(s_real, jnp.int32)])
    kernel = functools.partial(_chunk_prefill_kernel, scale=scale,
                               block_size=BS, n_ctx=NBctx, group=G)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # [start, s_real] + table
        grid=(Hkv, NBctx + 1),
        in_specs=[
            pl.BlockSpec((1, Sb, G, D), lambda h, j, info, bt: (h, 0, 0, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda h, j, info, bt:
                         (bt[jnp.minimum(j, NBctx - 1)], 0, h, 0)),
            pl.BlockSpec((1, BS, 1, Dv),
                         lambda h, j, info, bt:
                         (bt[jnp.minimum(j, NBctx - 1)], 0, h, 0)),
            pl.BlockSpec((Sb, 1, D), lambda h, j, info, bt: (0, h, 0)),
            pl.BlockSpec((Sb, 1, Dv), lambda h, j, info, bt: (0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, Sb, G, Dv),
                               lambda h, j, info, bt: (h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sb * G,), jnp.float32),
            pltpu.VMEM((Sb * G,), jnp.float32),
            pltpu.VMEM((Sb * G, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, Sb, G, Dv), q.dtype),
        interpret=interpret,
    )(info, block_table.astype(jnp.int32), qg, k_pool, v_pool, k_new, v_new)
    return jnp.moveaxis(out, 0, 1).reshape(Sb, Hq, Dv)
