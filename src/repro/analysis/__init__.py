"""servelint: repo-specific static analysis for the serve plane.

The serve plane's hardest-won invariants — clock discipline under
simulated time, host-sync hygiene on the decode hot path, retrace and
donation safety around the jitted step functions, bounded metric-label
cardinality — were enforced only at runtime (the transfer-guard test,
the ``trace_counts`` assertion) until they produced real bugs (the PR-6
mixed-clock stamp, the PR-7 double-``now`` resolution).  This package
moves those checks to lint time: an AST pass over every file of every
PR, wired as a CI gate.

Usage::

    python scripts/servelint.py src tests benchmarks examples scripts
    python -m repro.analysis --config servelint.toml src

Rules (see ``repro/analysis/rules.py``):

  SL001 clock-discipline     — wall-clock calls inside clock-param
                               functions / sim-time modules
  SL002 host-sync-hygiene    — device->host syncs in decode hot-path
                               functions
  SL003 retrace-hazard       — missing donation on state-first jitted
                               fns; varying scalars in static positions
  SL004 donation-hazard      — use-after-donate of buffers passed to
                               donating CompiledFns entries
  SL005 metric-cardinality   — uid-derived metric labels; inconsistent
                               label shapes across call sites

Suppress a reviewed finding inline (the reason string is mandatory)::

    x = time.perf_counter()  # servelint: disable=SL001 -- real interval

IMPORTANT: this package must stay importable without jax/numpy — the CI
lint job runs it on a bare Python install.
"""
from repro.analysis.core import (Config, Finding, Project, load_config,
                                 run_paths, run_source)
from repro.analysis.rules import ALL_RULES

__all__ = ["Config", "Finding", "Project", "load_config", "run_paths",
           "run_source", "ALL_RULES"]
