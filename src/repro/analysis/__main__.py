"""CLI: ``python -m repro.analysis [--config servelint.toml] PATHS...``

Exit codes: 0 clean, 1 unsuppressed findings, 2 usage/parse failure.
``--report out.json`` writes the full report (findings + reviewed
suppressions with their reasons) for the CI artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.core import load_config, run_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="servelint",
        description="repo-specific static analysis for the serve plane")
    ap.add_argument("paths", nargs="+",
                    help="files or directories to analyse")
    ap.add_argument("--config", default=None,
                    help="servelint.toml (default: ./servelint.toml "
                         "when present)")
    ap.add_argument("--root", default=".",
                    help="repo root findings are reported relative to")
    ap.add_argument("--report", default=None,
                    help="write a JSON report here (CI artifact)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="summary line only")
    args = ap.parse_args(argv)

    try:
        config = load_config(args.config, root=args.root)
    except (OSError, ValueError) as e:
        print(f"servelint: {e}", file=sys.stderr)
        return 2

    report = run_paths(args.paths, config=config)

    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(report.to_json(), f, indent=2)

    if not args.quiet:
        for finding in report.findings:
            print(finding.render())
    n = len(report.findings)
    print(f"servelint: {report.n_files} files, {n} finding"
          f"{'s' if n != 1 else ''}, {len(report.suppressed)} suppressed")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
