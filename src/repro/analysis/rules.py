"""servelint rules SL001-SL006.

Each rule encodes one invariant this codebase has already paid for at
runtime (see README "Static analysis" for the origin bugs).  Rules are
plain objects with ``id``, ``check_file(ctx, project)`` and optionally
``finalize(project)`` for cross-file passes; ``ALL_RULES`` is the
registry the runner and CLI use.

Findings may be created with ``path=""`` — the runner fills in the
file's relpath; finalize-phase findings must carry their own path.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.core import FileCtx, Finding, FuncInfo, Project

# ---------------------------------------------------------------------------
# shared helpers


def _walk_own(node: ast.AST) -> Iterator[ast.AST]:
    """ast.walk limited to the function's own body: does not descend
    into nested function/class definitions."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(child))


def _fn_qual(ctx: FileCtx, fn: FuncInfo) -> str:
    return f"{ctx.relpath}::{fn.qualname}"


def _match_any(target: str, patterns: List[str]) -> bool:
    return any(fnmatch.fnmatch(target, p) for p in patterns)


# ---------------------------------------------------------------------------
# SL001 clock-discipline


def _is_none_check(node: ast.AST, param: str) -> Optional[bool]:
    """``param is None`` -> True, ``param is not None`` -> False,
    anything else -> None."""
    if (isinstance(node, ast.Compare) and len(node.ops) == 1
            and isinstance(node.left, ast.Name) and node.left.id == param
            and isinstance(node.comparators[0], ast.Constant)
            and node.comparators[0].value is None):
        if isinstance(node.ops[0], ast.Is):
            return True
        if isinstance(node.ops[0], ast.IsNot):
            return False
    return None


class ClockDiscipline:
    """SL001: inside a function that takes simulated time (a
    ``now``/``clock``/``stamp`` parameter) or lives in a configured
    sim-time module, wall-clock reads are only legal as the single
    entry resolution ``now = time.perf_counter() if now is None else
    now`` (expression or if-statement form).  Anything else is the
    PR-6 mixed-clock / PR-7 double-resolution bug class."""

    id = "SL001"

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        clock_params = cfg.get("clock_params", [])
        clock_modules = cfg.get("clock_modules", [])
        wall_calls = set(cfg.get("wall_calls", []))
        in_clock_module = _match_any(ctx.relpath, clock_modules)
        out: List[Finding] = []
        for fn in ctx.functions:
            params = [p for p in fn.params if p in clock_params]
            if not params and not in_clock_module:
                continue
            out.extend(self._check_fn(ctx, fn, params, wall_calls))
        return out

    # -- per function -----------------------------------------------------
    def _check_fn(self, ctx: FileCtx, fn: FuncInfo, params: List[str],
                  wall_calls) -> List[Finding]:
        def is_wall(node: ast.AST) -> bool:
            return (isinstance(node, ast.Call)
                    and ctx.resolve(node.func) in wall_calls)

        allowed: set = set()          # id() of wall-call nodes in resolutions
        in_resolution: set = set()    # id() of every node inside one
        resolutions: Dict[str, List[int]] = {p: [] for p in params}

        def note_resolution(param: str, wall_node: ast.AST, line: int,
                            *construct: ast.AST):
            allowed.add(id(wall_node))
            resolutions[param].append(line)
            for c in construct:
                for sub in ast.walk(c):
                    in_resolution.add(id(sub))

        # pass 1: find resolution sites
        for node in _walk_own(fn.node):
            # expression form:  x = WALL() if param is None else param
            if isinstance(node, ast.IfExp):
                for param in params:
                    chk = _is_none_check(node.test, param)
                    if chk is True and is_wall(node.body):
                        note_resolution(param, node.body, node.lineno, node)
                    elif chk is False and is_wall(node.orelse):
                        note_resolution(param, node.orelse, node.lineno, node)
            # statement form:  if param is None: param = WALL()
            elif isinstance(node, ast.If):
                for param in params:
                    if _is_none_check(node.test, param) is not True:
                        continue
                    for stmt in node.body:
                        if (isinstance(stmt, ast.Assign)
                                and is_wall(stmt.value)):
                            note_resolution(param, stmt.value, stmt.lineno,
                                            node.test, stmt)
            # fallback form:  x = param or WALL()
            elif isinstance(node, ast.BoolOp) and isinstance(node.op, ast.Or):
                vals = node.values
                if (len(vals) == 2 and isinstance(vals[0], ast.Name)
                        and vals[0].id in params and is_wall(vals[1])):
                    note_resolution(vals[0].id, vals[1], node.lineno, node)

        out: List[Finding] = []
        # pass 2: double resolution
        for param, lines in resolutions.items():
            for line in sorted(lines)[1:]:
                out.append(Finding(
                    self.id, "", line,
                    f"`{param}` resolved against the wall clock more than "
                    f"once in `{fn.qualname}` (first at line "
                    f"{sorted(lines)[0]})",
                    f"resolve `{param}` exactly once at function entry"))
        # pass 2b: resolution AFTER the param was already consumed (the
        # PR-7 `enqueue` bug: `_note(..., now, ...)` saw None on one
        # path while the evict branch resolved a wall stamp on another)
        for param, lines in resolutions.items():
            if not lines:
                continue
            first_res = min(lines)
            uses = [n.lineno for n in _walk_own(fn.node)
                    if isinstance(n, ast.Name) and n.id == param
                    and isinstance(n.ctx, ast.Load)
                    and id(n) not in in_resolution]
            early = [u for u in uses if u < first_res]
            if early:
                out.append(Finding(
                    self.id, "", first_res,
                    f"`{param}` resolved here but already used at line "
                    f"{min(early)} in `{fn.qualname}` — callers passing "
                    "None get mixed/unresolved stamps",
                    f"move the `{param}` resolution to function entry"))
        # pass 3: stray wall-clock reads (the PR-6 mixed-clock bug)
        for node in _walk_own(fn.node):
            if is_wall(node) and id(node) not in allowed:
                why = (f"`{fn.qualname}` takes simulated time "
                       f"({', '.join(params)})" if params else
                       f"`{ctx.relpath}` participates in simulated time")
                out.append(Finding(
                    self.id, "", node.lineno,
                    f"direct `{ctx.resolve(node.func)}()` call — {why}",
                    "use the resolved clock value, or suppress with a "
                    "reason if this measures a real wall interval"))
        return out


# ---------------------------------------------------------------------------
# SL002 host-sync hygiene


class HostSyncHygiene:
    """SL002: device->host synchronisation inside the decode hot path.
    The runtime transfer guard (PR 5) catches these when the path is
    exercised; this catches them on every PR.  ``jax.device_get`` at a
    designed sync point needs a reviewed suppression."""

    id = "SL002"

    # jnp.asarray is a host->device UPLOAD (legal in the hot path);
    # np.asarray on a device value is the device->host direction.
    _SYNC_CALLS = {"jax.device_get", "numpy.asarray", "numpy.array"}

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        hot = cfg.get("hot_functions", [])
        if not hot:
            return []
        device_fns = set(cfg.get("device_fns", []))
        out: List[Finding] = []
        for fn in ctx.functions:
            if not _match_any(_fn_qual(ctx, fn), hot):
                continue
            out.extend(self._check_fn(ctx, fn, device_fns))
        return out

    def _check_fn(self, ctx: FileCtx, fn: FuncInfo, device_fns
                  ) -> List[Finding]:
        # taint: names assigned from device-producing calls; device_get
        # output is host-side, so it clears taint for its targets
        tainted: set = set()
        host: set = set()
        for node in _walk_own(fn.node):
            if not isinstance(node, ast.Assign):
                continue
            val = node.value
            if not isinstance(val, ast.Call):
                continue
            resolved = ctx.resolve(val.func) or ""
            term = ctx.terminal(val.func) or ""
            targets: List[str] = []
            for t in node.targets:
                if isinstance(t, ast.Name):
                    targets.append(t.id)
                elif isinstance(t, ast.Tuple):
                    targets.extend(e.id for e in t.elts
                                   if isinstance(e, ast.Name))
            if resolved == "jax.device_get":
                host.update(targets)
            elif term in device_fns or resolved.startswith("jax."):
                tainted.update(targets)
        tainted -= host

        out: List[Finding] = []
        for node in _walk_own(fn.node):
            if not isinstance(node, ast.Call):
                continue
            resolved = ctx.resolve(node.func) or ""
            if resolved in self._SYNC_CALLS:
                out.append(Finding(
                    self.id, "", node.lineno,
                    f"`{resolved}` in hot-path function `{fn.qualname}` "
                    "forces a device->host sync",
                    "keep values on device, or suppress with a reason if "
                    "this is a designed sync point"))
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                out.append(Finding(
                    self.id, "", node.lineno,
                    f"`.item()` in hot-path function `{fn.qualname}` "
                    "forces a device->host sync",
                    "batch the readback at the designed sync point"))
            elif (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and node.args and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in tainted):
                out.append(Finding(
                    self.id, "", node.lineno,
                    f"`{node.func.id}({node.args[0].id})` on a device "
                    f"value in hot-path function `{fn.qualname}` forces "
                    "a device->host sync",
                    "keep the value on device or read it back at the "
                    "designed sync point"))
        return out


# ---------------------------------------------------------------------------
# SL003 retrace hazards


class RetraceHazard:
    """SL003: (a) ``jax.jit`` on a function whose first parameter is
    named like donated serving state but without ``donate_argnums`` —
    every step then keeps two live copies of the cache in HBM; (b) a
    varying Python scalar (loop variable, ``len(...)``) passed in a
    known static position — one retrace per distinct value."""

    id = "SL003"

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        state_params = cfg.get("donated_state_params", [])
        static_pos = {k: [int(i) for i in v]
                      for k, v in cfg.get("static_positions", {}).items()}
        out: List[Finding] = []
        out.extend(self._check_jit_sites(ctx, state_params))
        out.extend(self._check_static_positions(ctx, static_pos))
        return out

    # -- (a) missing donation --------------------------------------------
    def _first_param(self, fn: FuncInfo) -> Optional[str]:
        for p in fn.params:
            if p not in ("self", "cls"):
                return p
        return None

    def _check_jit_sites(self, ctx: FileCtx, state_params) -> List[Finding]:
        module_fns = {fn.qualname: fn for fn in ctx.functions
                      if "." not in fn.qualname}
        out: List[Finding] = []

        def has_donate(call: ast.Call) -> bool:
            return any(kw.arg in ("donate_argnums", "donate_argnames")
                       for kw in call.keywords)

        for node in ast.walk(ctx.tree):
            # jax.jit(fn, ...) call form
            if (isinstance(node, ast.Call)
                    and ctx.resolve(node.func) == "jax.jit"
                    and node.args and isinstance(node.args[0], ast.Name)):
                fn = module_fns.get(node.args[0].id)
                if fn is None:
                    continue
                first = self._first_param(fn)
                if (first in state_params and not has_donate(node)):
                    out.append(Finding(
                        self.id, "", node.lineno,
                        f"`jax.jit({fn.qualname})` without donate_argnums "
                        f"— first parameter `{first}` is serving state",
                        "add donate_argnums=(0,) (or suppress with a "
                        "reason if the buffer is reused by the caller)"))
            # @jax.jit decorator form
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    bare = (not isinstance(dec, ast.Call)
                            and ctx.resolve(dec) == "jax.jit")
                    wrapped = (isinstance(dec, ast.Call)
                               and ctx.resolve(dec.func) == "jax.jit")
                    if not (bare or wrapped):
                        continue
                    if wrapped and any(
                            kw.arg in ("donate_argnums", "donate_argnames")
                            for kw in dec.keywords):
                        continue
                    args = node.args
                    ps = ([a.arg for a in args.posonlyargs]
                          + [a.arg for a in args.args])
                    first = next((p for p in ps if p not in ("self", "cls")),
                                 None)
                    if first in state_params:
                        out.append(Finding(
                            self.id, "", dec.lineno,
                            f"`@jax.jit` on `{node.name}` without "
                            f"donate_argnums — first parameter `{first}` "
                            "is serving state",
                            "add donate_argnums=(0,)"))
        return out

    # -- (b) varying scalar in static position ----------------------------
    def _check_static_positions(self, ctx: FileCtx, static_pos
                                ) -> List[Finding]:
        if not static_pos:
            return []
        out: List[Finding] = []
        for fn in ctx.functions:
            loop_vars: set = set()
            for node in _walk_own(fn.node):
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    for t in ast.walk(node.target):
                        if isinstance(t, ast.Name):
                            loop_vars.add(t.id)
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.DictComp, ast.GeneratorExp)):
                    for gen in node.generators:
                        for t in ast.walk(gen.target):
                            if isinstance(t, ast.Name):
                                loop_vars.add(t.id)
            for node in _walk_own(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                positions = static_pos.get(ctx.terminal(node.func) or "")
                if not positions:
                    continue
                for pos in positions:
                    if pos >= len(node.args):
                        continue
                    arg = node.args[pos]
                    varying = (
                        (isinstance(arg, ast.Name) and arg.id in loop_vars)
                        or (isinstance(arg, ast.Call)
                            and isinstance(arg.func, ast.Name)
                            and arg.func.id == "len"))
                    if varying:
                        desc = (f"loop variable `{arg.id}`"
                                if isinstance(arg, ast.Name)
                                else "`len(...)`")
                        out.append(Finding(
                            self.id, "", node.lineno,
                            f"{desc} passed in static position {pos} of "
                            f"`{ctx.terminal(node.func)}` — retraces on "
                            "every distinct value",
                            "quantise/bucket the value or hoist it out "
                            "of the loop"))
        return out


# ---------------------------------------------------------------------------
# SL004 donation use-after-donate


class DonationHazard:
    """SL004: a buffer passed into a donating position of a
    CompiledFns/PagedCompiledFns entry is dead after the call — jax
    reuses its memory for the output.  Reading it afterwards (without
    rebinding) returns garbage or raises on deleted buffers."""

    id = "SL004"

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        donated = {k: [int(i) for i in v]
                   for k, v in cfg.get("donated", {}).items()}
        if not donated:
            return []
        out: List[Finding] = []
        for fn in ctx.functions:
            out.extend(self._check_fn(ctx, fn, donated))
        return out

    def _check_fn(self, ctx: FileCtx, fn: FuncInfo, donated
                  ) -> List[Finding]:
        consumed: Dict[str, Tuple[int, str]] = {}   # path -> (line, callee)
        out: List[Finding] = []

        def handle_expr(expr: ast.AST) -> None:
            """Flag reads of consumed paths, then record new
            consumptions from donating calls in this expression."""
            for node in ast.walk(expr):
                if (isinstance(node, (ast.Name, ast.Attribute))
                        and isinstance(getattr(node, "ctx", None), ast.Load)):
                    path = ctx.dotted(node)
                    if path in consumed:
                        line, callee = consumed[path]
                        out.append(Finding(
                            self.id, "", node.lineno,
                            f"`{path}` read after being donated to "
                            f"`{callee}` at line {line}",
                            f"rebind `{path}` from the call result "
                            "before reusing it"))
                        del consumed[path]    # flag once per donation
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                positions = donated.get(ctx.terminal(node.func) or "")
                if not positions:
                    continue
                for pos in positions:
                    if pos >= len(node.args):
                        continue
                    path = ctx.dotted(node.args[pos])
                    if path is not None:
                        consumed[path] = (node.lineno,
                                          ctx.terminal(node.func))

        def clear_target(t: ast.AST) -> None:
            if isinstance(t, ast.Tuple):
                for e in t.elts:
                    clear_target(e)
                return
            path = ctx.dotted(t)
            if path is not None:
                consumed.pop(path, None)

        def handle_stmt(stmt: ast.AST) -> None:
            if isinstance(stmt, ast.Assign):
                handle_expr(stmt.value)
                for t in stmt.targets:
                    clear_target(t)
            elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
                if stmt.value is not None:
                    handle_expr(stmt.value)
                if isinstance(stmt, ast.AnnAssign):
                    clear_target(stmt.target)
            elif isinstance(stmt, (ast.Expr, ast.Return)):
                if stmt.value is not None:
                    handle_expr(stmt.value)
            elif isinstance(stmt, (ast.If, ast.While)):
                handle_expr(stmt.test)
                for s in stmt.body:
                    handle_stmt(s)
                for s in getattr(stmt, "orelse", []):
                    handle_stmt(s)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                handle_expr(stmt.iter)
                for s in stmt.body:
                    handle_stmt(s)
                for s in stmt.orelse:
                    handle_stmt(s)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    handle_expr(item.context_expr)
                for s in stmt.body:
                    handle_stmt(s)
            elif isinstance(stmt, ast.Try):
                for s in (stmt.body + stmt.orelse + stmt.finalbody):
                    handle_stmt(s)
                for h in stmt.handlers:
                    for s in h.body:
                        handle_stmt(s)
            # nested defs: fresh scope, skip

        body = getattr(fn.node, "body", [])
        for stmt in body:
            handle_stmt(stmt)
        return out


# ---------------------------------------------------------------------------
# SL005 metric-label cardinality


_METRIC_FACTORIES = ("counter", "gauge", "histogram")


def _shape_from_template(s: str):
    """Label shape: plain string vs composite ``base|k=v|...`` — the
    shape is the sorted tuple of composite keys."""
    if "|" not in s:
        return ("plain",)
    keys = []
    for part in s.split("|")[1:]:
        k = part.split("=", 1)[0].strip()
        if k:
            keys.append(k)
    return ("composite", tuple(sorted(keys)))


def _label_shape(node: Optional[ast.AST]):
    if node is None:
        return ("plain",)          # label defaults to ""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return _shape_from_template(node.value)
    if isinstance(node, ast.JoinedStr):
        const = "".join(v.value for v in node.values
                        if isinstance(v, ast.Constant)
                        and isinstance(v.value, str))
        if "|" in const:
            return _shape_from_template("x" + const if
                                        const.startswith("|") else const)
        return ("plain",)
    return None                    # dynamic — unknown shape, skip


def _shape_str(shape) -> str:
    if shape == ("plain",):
        return "plain label"
    return "composite label with keys {%s}" % ", ".join(shape[1])


class MetricCardinality:
    """SL005: (a) metric labels derived from per-request identifiers —
    unbounded series cardinality; (b) the same metric name registered
    with structurally different label shapes at different call sites
    (plain vs ``base|k=v`` composite, or different composite keys)."""

    id = "SL005"

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        uid_names = set(cfg.get("uid_label_names", []))
        sites = project.state.setdefault("SL005.sites", [])
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _METRIC_FACTORIES
                    and node.args):
                continue
            name_node = node.args[0]
            label = node.args[1] if len(node.args) > 1 else None
            if label is None:
                for kw in node.keywords:
                    if kw.arg == "label":
                        label = kw.value
            # (a) uid-derived labels
            if label is not None:
                for sub in ast.walk(label):
                    leaf = None
                    if isinstance(sub, ast.Name) and sub.id in uid_names:
                        leaf = sub.id
                    elif (isinstance(sub, ast.Attribute)
                            and sub.attr in uid_names):
                        leaf = sub.attr
                    if leaf is not None:
                        out.append(Finding(
                            self.id, "", node.lineno,
                            f"metric label derived from `{leaf}` — one "
                            "series per request, unbounded cardinality",
                            "aggregate per model/replica; put request "
                            "ids in the trace, not in metric labels"))
                        break
            # (b) collect shape for the cross-file pass (literal names
            # only — computed names like "sched_" + event are skipped)
            if (isinstance(name_node, ast.Constant)
                    and isinstance(name_node.value, str)):
                shape = _label_shape(label)
                if shape is not None:
                    sites.append((name_node.value, shape, ctx.relpath,
                                  node.lineno))
        return out

    def finalize(self, project: Project) -> List[Finding]:
        sites = project.state.get("SL005.sites", [])
        by_name: Dict[str, List[Tuple]] = {}
        for name, shape, path, line in sites:
            by_name.setdefault(name, []).append((shape, path, line))
        out: List[Finding] = []
        for name, entries in by_name.items():
            shapes = {s for s, _, _ in entries}
            if len(shapes) < 2:
                continue
            counts: Dict[Tuple, int] = {}
            for s, _, _ in entries:
                counts[s] = counts.get(s, 0) + 1
            majority = max(counts, key=lambda s: counts[s])
            for s, path, line in entries:
                if s != majority:
                    out.append(Finding(
                        self.id, path, line,
                        f"metric `{name}` registered with {_shape_str(s)} "
                        f"here but {_shape_str(majority)} elsewhere",
                        "use one label shape per metric name"))
        return out


# ---------------------------------------------------------------------------
# SL006 spec-verify hygiene


class SpecVerifyHygiene:
    """SL006: per-drafted-position host syncs inside the speculative
    verify path.  A verify step's contract is ONE batched int32 id
    readback per dispatch (the PR-5 transfer guard measures this at
    runtime); a device->host sync INSIDE a loop of a configured verify
    function — per-position ``.item()``, ``jax.device_get``,
    ``np.asarray``, or ``int()/float()`` on a device value — turns the
    K-tokens-per-forward win into K blocking round-trips."""

    id = "SL006"

    _SYNC_CALLS = {"jax.device_get", "numpy.asarray", "numpy.array"}

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        verify = cfg.get("verify_functions", [])
        if not verify:
            return []
        device_fns = set(cfg.get("device_fns", []))
        out: List[Finding] = []
        for fn in ctx.functions:
            if not _match_any(_fn_qual(ctx, fn), verify):
                continue
            out.extend(self._check_fn(ctx, fn, device_fns))
        return out

    def _check_fn(self, ctx: FileCtx, fn: FuncInfo, device_fns
                  ) -> List[Finding]:
        # taint as in SL002: names bound from device-producing calls are
        # device values; jax.device_get output is host-side and clears
        tainted: set = set()
        host: set = set()
        for node in _walk_own(fn.node):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            resolved = ctx.resolve(node.value.func) or ""
            term = ctx.terminal(node.value.func) or ""
            targets: List[str] = []
            for t in node.targets:
                if isinstance(t, ast.Name):
                    targets.append(t.id)
                elif isinstance(t, ast.Tuple):
                    targets.extend(e.id for e in t.elts
                                   if isinstance(e, ast.Name))
            if resolved == "jax.device_get":
                host.update(targets)
            elif term in device_fns or resolved.startswith("jax."):
                tainted.update(targets)
        tainted -= host

        def base_name(node: ast.AST) -> Optional[str]:
            while isinstance(node, ast.Subscript):
                node = node.value
            return node.id if isinstance(node, ast.Name) else None

        out: List[Finding] = []
        seen: set = set()
        loops = [n for n in _walk_own(fn.node)
                 if isinstance(n, (ast.For, ast.AsyncFor, ast.While))]
        for loop in loops:
            for node in ast.walk(loop):
                if id(node) in seen or not isinstance(node, ast.Call):
                    continue
                seen.add(id(node))
                resolved = ctx.resolve(node.func) or ""
                if resolved in self._SYNC_CALLS:
                    out.append(Finding(
                        self.id, "", node.lineno,
                        f"`{resolved}` inside a loop of verify function "
                        f"`{fn.qualname}` — one device->host sync per "
                        "drafted position",
                        "pull the whole id matrix once per verify and "
                        "iterate the host copy"))
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item" and not node.args):
                    out.append(Finding(
                        self.id, "", node.lineno,
                        f"`.item()` inside a loop of verify function "
                        f"`{fn.qualname}` — one device->host sync per "
                        "drafted position",
                        "batch the readback: one device_get of the "
                        "(max_batch, K+1) id matrix per verify"))
                elif (isinstance(node.func, ast.Name)
                        and node.func.id in ("float", "int", "bool")
                        and node.args
                        and base_name(node.args[0]) in tainted):
                    out.append(Finding(
                        self.id, "", node.lineno,
                        f"`{node.func.id}(...)` on device value "
                        f"`{base_name(node.args[0])}` inside a loop of "
                        f"verify function `{fn.qualname}` — one "
                        "device->host sync per drafted position",
                        "device_get the array once per verify, then "
                        "convert host-side"))
        return out


# ---------------------------------------------------------------------------
# SL007 fault-path hygiene


class FaultPathHygiene:
    """SL007: a broad exception handler (bare ``except:``, ``except
    Exception:``, ``except BaseException:``) in a configured serving
    module that neither re-raises nor invokes a containment routine
    (``report_step_failure``, ``quarantine``, ...).  The fault-tolerant
    serve plane's whole contract is that every replica failure ends up
    quarantined, retried, or propagated — a handler that swallows one
    silently turns a crash into state corruption the chaos harness can
    never see.  A designed suppression point needs a reviewed
    ``servelint: disable=SL007 -- reason`` directive."""

    id = "SL007"

    _BROAD = {"Exception", "BaseException"}

    def check_file(self, ctx: FileCtx, project: Project) -> List[Finding]:
        cfg = ctx.config.rule(self.id)
        modules = cfg.get("modules", [])
        if not modules or not _match_any(ctx.relpath, modules):
            return []
        containment = set(cfg.get("containment_calls", []))
        out: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Try):
                continue
            for h in node.handlers:
                caught = self._broad_name(ctx, h.type)
                if caught is None:
                    continue
                if self._contains_or_reraises(h, containment):
                    continue
                out.append(Finding(
                    self.id, "", h.lineno,
                    f"{caught} swallows the failure — no re-raise and no "
                    "containment call on the fault path",
                    "re-raise, route through "
                    f"{'/'.join(sorted(containment)) or 'a containment'} "
                    "routine, or suppress with a reason"))
        return out

    def _broad_name(self, ctx: FileCtx, typ) -> Optional[str]:
        """Human-readable name when the handler catches broadly, else
        None.  Typed handlers (``except PoolExhausted:``) are the
        DESIGNED narrow form and never flagged."""
        if typ is None:
            return "bare `except:`"
        types = typ.elts if isinstance(typ, ast.Tuple) else [typ]
        for t in types:
            name = ctx.resolve(t) or ""
            if name.split(".")[-1] in self._BROAD:
                return f"`except {name.split('.')[-1]}`"
        return None

    def _contains_or_reraises(self, handler: ast.AST, containment) -> bool:
        for node in _walk_own(handler):
            if isinstance(node, ast.Raise):
                return True
            if isinstance(node, ast.Call):
                term = (node.func.attr
                        if isinstance(node.func, ast.Attribute)
                        else node.func.id
                        if isinstance(node.func, ast.Name) else None)
                if term in containment:
                    return True
        return False


ALL_RULES = [ClockDiscipline(), HostSyncHygiene(), RetraceHazard(),
             DonationHazard(), MetricCardinality(), SpecVerifyHygiene(),
             FaultPathHygiene()]
