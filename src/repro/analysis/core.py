"""servelint framework: config, AST file context, suppressions, runner.

Deliberately dependency-free (stdlib only — the CI lint job runs on a
bare Python without jax), and pyproject-free: configuration lives in
``servelint.toml`` at the repo root, parsed by a minimal TOML-subset
reader (3.10 has no ``tomllib``; ``tomllib`` is used when available).

The moving parts:

  * ``Config``        — parsed ``servelint.toml`` with per-rule tables
    and built-in defaults, so the tool is useful with no config at all;
  * ``FileCtx``       — one parsed file: AST, source lines, resolved
    import aliases (``jnp`` -> ``jax.numpy``, ``from time import
    perf_counter`` -> ``time.perf_counter``), and every function with
    its dotted qualname (``Class.method``) for pattern-scoped rules;
  * suppressions      — ``# servelint: disable=SL001 -- reason`` on the
    flagged line (or alone on the line above).  A directive WITHOUT a
    reason is itself a finding (SL000): every suppression is a reviewed
    decision, and the review is the reason string;
  * ``Project``       — cross-file state for rules that need the whole
    run (SL005 label-shape consistency), via the ``finalize`` hook;
  * ``run_paths``     — collect files (honouring ``exclude`` globs),
    run every rule, apply suppressions, return the report.
"""
from __future__ import annotations

import ast
import fnmatch
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# findings


@dataclass(frozen=True)
class Finding:
    """One diagnostic: rule id + location + message + fix hint."""
    rule: str
    path: str                     # repo-relative posix path
    line: int
    message: str
    hint: str = ""

    def render(self) -> str:
        s = f"{self.path}:{self.line}: {self.rule} {self.message}"
        if self.hint:
            s += f"  [{self.hint}]"
        return s

    def sort_key(self) -> Tuple[str, int, str]:
        return (self.path, self.line, self.rule)


# ---------------------------------------------------------------------------
# minimal TOML-subset parser (sections, dotted sections, strings, ints,
# floats, bools, flat arrays — everything servelint.toml needs)


def _strip_comment(line: str) -> str:
    out, quote = [], None
    for ch in line:
        if quote:
            out.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            out.append(ch)
        elif ch == "#":
            break
        else:
            out.append(ch)
    return "".join(out).strip()


def _parse_scalar(tok: str):
    tok = tok.strip()
    if len(tok) >= 2 and tok[0] in "\"'" and tok[-1] == tok[0]:
        return tok[1:-1]
    if tok == "true":
        return True
    if tok == "false":
        return False
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        pass
    raise ValueError(f"servelint.toml: cannot parse value {tok!r}")


def _parse_array(body: str) -> list:
    items, depth, cur, quote = [], 0, [], None
    for ch in body:
        if quote:
            cur.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            cur.append(ch)
        elif ch == "[":
            depth += 1
            if depth > 1:
                cur.append(ch)
        elif ch == "]":
            depth -= 1
            if depth >= 1:
                cur.append(ch)
        elif ch == "," and depth == 1:
            tok = "".join(cur).strip()
            if tok:
                items.append(_parse_scalar(tok))
            cur = []
        else:
            cur.append(ch)
    tok = "".join(cur).strip()
    if tok:
        items.append(_parse_scalar(tok))
    return items


def parse_toml(text: str) -> dict:
    """Parse the TOML subset servelint uses.  Uses the stdlib parser
    when available (3.11+) so quoting edge cases behave identically."""
    try:
        import tomllib
        return tomllib.loads(text)
    except ImportError:
        pass
    root: dict = {}
    table = root
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = _strip_comment(lines[i])
        i += 1
        if not line:
            continue
        if line.startswith("[") and line.endswith("]"):
            table = root
            for part in line[1:-1].split("."):
                part = part.strip().strip("\"'")
                table = table.setdefault(part, {})
            continue
        if "=" not in line:
            raise ValueError(f"servelint.toml: bad line {line!r}")
        key, _, val = line.partition("=")
        key = key.strip().strip("\"'")
        val = val.strip()
        if val.startswith("["):
            # arrays may span lines: accumulate until brackets balance
            while val.count("[") > val.count("]"):
                if i >= len(lines):
                    raise ValueError("servelint.toml: unterminated array")
                val += " " + _strip_comment(lines[i])
                i += 1
            table[key] = _parse_array(val)
        else:
            table[key] = _parse_scalar(val)
    return root


# ---------------------------------------------------------------------------
# config


def _defaults() -> dict:
    return {
        "exclude": [],
        "SL001": {
            "clock_params": ["now", "clock", "stamp"],
            "clock_modules": [],
            "wall_calls": ["time.perf_counter", "time.time",
                           "time.monotonic"],
        },
        "SL002": {
            "hot_functions": [],
            "device_fns": ["fused_step", "fused_burst", "first_tokens",
                           "_fused_step", "_fused_burst", "_first_fn",
                           "sample_rows"],
        },
        "SL003": {
            "donated_state_params": ["cache", "state", "dstate", "pool"],
            "static_positions": {"fused_burst": [3], "_fused_burst": [3]},
        },
        "SL004": {
            "donated": {
                "fused_step": [1, 2], "_fused_step": [1, 2],
                "fused_burst": [1, 2], "_fused_burst": [1, 2],
                "first_tokens": [0], "_first_fn": [0],
                "occupy": [0], "_occupy_fn": [0],
                "deactivate": [0], "_deactivate_fn": [0],
                "scatter": [0], "_scatter": [0],
                "scatter_slot": [0], "_scatter_slot": [0],
                "copy": [0], "_copy": [0],
                "insert": [0], "_insert": [0],
            },
        },
        "SL005": {
            "uid_label_names": ["uid", "request_id", "req_id"],
        },
        "SL006": {
            "verify_functions": [],
            "device_fns": ["fused_step", "fused_burst", "first_tokens",
                           "_fused_step", "_fused_burst", "_first_fn",
                           "sample_rows", "spec_step", "_spec_dispatch"],
        },
        "SL007": {
            "modules": [],
            "containment_calls": ["report_step_failure", "quarantine",
                                  "note_exception"],
        },
    }


def _merge(base: dict, over: dict) -> dict:
    out = dict(base)
    for k, v in over.items():
        if isinstance(v, dict) and isinstance(out.get(k), dict):
            out[k] = _merge(out[k], v)
        else:
            out[k] = v
    return out


@dataclass
class Config:
    data: dict = field(default_factory=_defaults)
    root: str = "."               # paths in findings are relative to this

    def rule(self, rule_id: str) -> dict:
        return self.data.get(rule_id, {})

    @property
    def exclude(self) -> List[str]:
        return list(self.data.get("exclude", []))

    def excluded(self, relpath: str) -> bool:
        for pat in self.exclude:
            if fnmatch.fnmatch(relpath, pat) or \
                    relpath.startswith(pat.rstrip("*/") + "/"):
                return True
        return False


def load_config(path: Optional[str] = None, root: str = ".") -> Config:
    """Load ``servelint.toml`` (defaults merged under it). ``path=None``
    looks for ``<root>/servelint.toml`` and falls back to defaults."""
    data = _defaults()
    if path is None:
        cand = os.path.join(root, "servelint.toml")
        path = cand if os.path.exists(cand) else None
    if path is not None:
        with open(path, encoding="utf-8") as f:
            raw = parse_toml(f.read())
        data = _merge(data, raw.get("servelint", raw))
    return Config(data=data, root=root)


# ---------------------------------------------------------------------------
# suppressions

_DIRECTIVE = re.compile(
    r"#\s*servelint:\s*disable=([A-Za-z0-9_,\s]+?|all)"
    r"\s*(?:--\s*(.*?))?\s*$")


@dataclass
class Suppression:
    line: int                     # source line the directive sits on
    applies_to: int               # line it suppresses
    rules: Optional[frozenset]    # None == all
    reason: str


def scan_suppressions(source: str) -> List[Suppression]:
    out: List[Suppression] = []
    for i, raw in enumerate(source.splitlines(), start=1):
        m = _DIRECTIVE.search(raw)
        if not m:
            continue
        rules_s, reason = m.group(1), (m.group(2) or "").strip()
        rules = (None if rules_s.strip() == "all" else
                 frozenset(r.strip() for r in rules_s.split(",") if r.strip()))
        code = raw[:m.start()].strip()
        # a standalone directive line suppresses the NEXT line
        target = i if code else i + 1
        out.append(Suppression(i, target, rules, reason))
    return out


# ---------------------------------------------------------------------------
# file context


def _import_aliases(tree: ast.AST) -> Dict[str, str]:
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


@dataclass
class FuncInfo:
    node: ast.AST                 # FunctionDef | AsyncFunctionDef
    qualname: str                 # Class.method / outer.<locals>.inner
    params: List[str]


class FileCtx:
    """One parsed file plus everything the rules share."""

    def __init__(self, relpath: str, source: str, config: Config):
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.config = config
        self.tree = ast.parse(source, filename=relpath)
        self.imports = _import_aliases(self.tree)
        self.functions: List[FuncInfo] = []
        self._collect_functions(self.tree, "")

    def _collect_functions(self, node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qn = f"{prefix}{child.name}"
                args = child.args
                params = ([a.arg for a in args.posonlyargs]
                          + [a.arg for a in args.args]
                          + [a.arg for a in args.kwonlyargs])
                self.functions.append(FuncInfo(child, qn, params))
                self._collect_functions(child, f"{qn}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                self._collect_functions(child, f"{prefix}{child.name}.")
            else:
                self._collect_functions(child, prefix)

    # -- name resolution --------------------------------------------------
    def dotted(self, node: ast.AST) -> Optional[str]:
        """Dotted path of a Name/Attribute chain (``self.cache`` ->
        "self.cache"); None for anything else."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return None

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted path with the ROOT name resolved through imports:
        ``jnp.asarray`` -> "jax.numpy.asarray", a bare ``perf_counter``
        from ``from time import perf_counter`` -> "time.perf_counter"."""
        path = self.dotted(node)
        if path is None:
            return None
        head, _, rest = path.partition(".")
        head = self.imports.get(head, head)
        return f"{head}.{rest}" if rest else head

    def terminal(self, node: ast.AST) -> Optional[str]:
        """Last component of a callee chain: ``self._fused_step`` ->
        "_fused_step", ``fns.occupy`` -> "occupy"."""
        if isinstance(node, ast.Attribute):
            return node.attr
        if isinstance(node, ast.Name):
            return node.id
        return None


# ---------------------------------------------------------------------------
# runner


@dataclass
class Project:
    """Whole-run state shared by finalize-phase rules."""
    config: Config
    files: List[FileCtx] = field(default_factory=list)
    state: dict = field(default_factory=dict)


@dataclass
class Report:
    findings: List[Finding]       # unsuppressed — these fail the gate
    suppressed: List[Tuple[Finding, Suppression]]
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "files": self.n_files,
            "findings": [vars(f) for f in self.findings],
            "suppressed": [
                {**vars(f), "reason": s.reason, "directive_line": s.line}
                for f, s in self.suppressed],
        }


def _collect_files(paths: Sequence[str], config: Config) -> List[str]:
    out: List[str] = []
    for p in paths:
        ap = os.path.join(config.root, p) if not os.path.isabs(p) else p
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = [d for d in dirnames if d != "__pycache__"]
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
    seen, files = set(), []
    for ap in out:
        rel = os.path.relpath(ap, config.root).replace(os.sep, "/")
        if rel in seen or config.excluded(rel):
            continue
        seen.add(rel)
        files.append(ap)
    return files


def _apply_suppressions(findings: List[Finding], source: str
                        ) -> Tuple[List[Finding], List[Tuple[Finding,
                                                             Suppression]]]:
    sups = scan_suppressions(source)
    by_line: Dict[int, List[Suppression]] = {}
    for s in sups:
        by_line.setdefault(s.applies_to, []).append(s)
    live: List[Finding] = []
    quiet: List[Tuple[Finding, Suppression]] = []
    for f in findings:
        hit = None
        for s in by_line.get(f.line, []):
            if s.rules is None or f.rule in s.rules:
                hit = s
                break
        if hit is None:
            live.append(f)
        else:
            quiet.append((f, hit))
    # suppression hygiene: a directive with no reason is itself a
    # finding — every suppression must be a reviewed, explained decision
    for s in sups:
        if not s.reason:
            live.append(Finding(
                "SL000", "", s.line,
                "suppression directive without a reason string",
                "append `-- <why this is safe>` to the directive"))
    return live, quiet


def run_source(relpath: str, source: str, config: Optional[Config] = None,
               rules=None) -> List[Finding]:
    """Analyse ONE source blob (tests use this); suppressions applied,
    cross-file finalize rules run against just this file."""
    config = config or Config()
    from repro.analysis.rules import ALL_RULES
    rules = rules if rules is not None else ALL_RULES
    project = Project(config=config)
    ctx = FileCtx(relpath, source, config)
    project.files.append(ctx)
    findings: List[Finding] = []
    for rule in rules:
        findings.extend(rule.check_file(ctx, project))
    for rule in rules:
        fin = getattr(rule, "finalize", None)
        if fin is not None:
            findings.extend(fin(project))
    live, _quiet = _apply_suppressions(
        [f for f in findings], source)
    out = [Finding(f.rule, relpath, f.line, f.message, f.hint)
           if not f.path else f for f in live]
    return sorted(out, key=Finding.sort_key)


def run_paths(paths: Sequence[str], config: Optional[Config] = None,
              rules=None) -> Report:
    """Analyse files/directories and return the gate report."""
    config = config or Config()
    from repro.analysis.rules import ALL_RULES
    rules = rules if rules is not None else ALL_RULES
    files = _collect_files(paths, config)
    project = Project(config=config)
    per_file: List[Tuple[FileCtx, List[Finding]]] = []
    for ap in files:
        rel = os.path.relpath(ap, config.root).replace(os.sep, "/")
        with open(ap, encoding="utf-8") as f:
            source = f.read()
        try:
            ctx = FileCtx(rel, source, config)
        except SyntaxError as e:
            per_file.append((None, [Finding(
                "SL000", rel, e.lineno or 0,
                f"syntax error: {e.msg}", "")]))
            continue
        project.files.append(ctx)
        findings: List[Finding] = []
        for rule in rules:
            findings.extend(rule.check_file(ctx, project))
        per_file.append((ctx, findings))
    # finalize-phase (cross-file) findings attach to their own files
    extra: Dict[str, List[Finding]] = {}
    for rule in rules:
        fin = getattr(rule, "finalize", None)
        if fin is not None:
            for f in fin(project):
                extra.setdefault(f.path, []).append(f)
    live_all: List[Finding] = []
    quiet_all: List[Tuple[Finding, Suppression]] = []
    for ctx, findings in per_file:
        if ctx is None:               # syntax error pseudo-finding
            live_all.extend(findings)
            continue
        findings = findings + extra.pop(ctx.relpath, [])
        findings = [Finding(f.rule, ctx.relpath, f.line, f.message, f.hint)
                    if not f.path else f for f in findings]
        live, quiet = _apply_suppressions(findings, ctx.source)
        live = [Finding(f.rule, ctx.relpath, f.line, f.message, f.hint)
                if not f.path else f for f in live]
        live_all.extend(live)
        quiet_all.extend(quiet)
    for leftover in extra.values():   # files not parsed this run
        live_all.extend(leftover)
    return Report(findings=sorted(live_all, key=Finding.sort_key),
                  suppressed=quiet_all, n_files=len(files))
