"""Data pipeline: packed LM batches from the synthetic corpus.

Used by the end-to-end training example (train a ~100M model a few hundred
steps) and by per-arch smoke tests. Deterministic, seeded, infinite.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.benchmarks import generate_corpus
from repro.data.tokenizer import ByteTokenizer


def lm_batches(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
               n_prompts: int = 4000) -> Iterator[dict]:
    """Infinite (tokens, labels) batches packed from the synthetic corpus."""
    tok = ByteTokenizer()
    corpus = generate_corpus(n_prompts, seed)
    stream: list = []
    for p in corpus:
        stream.extend(tok.encode(p.text, eos=True))
    stream = np.asarray(stream, np.int64) % cfg.vocab_size
    rng = np.random.RandomState(seed)
    n = len(stream) - seq - 1
    while True:
        starts = rng.randint(0, n, size=batch)
        toks = np.stack([stream[s:s + seq] for s in starts])
        labs = np.stack([stream[s + 1:s + seq + 1] for s in starts])
        b = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(labs, jnp.int32)}
        yield _add_modality(cfg, b, rng)


def _add_modality(cfg: ModelConfig, b: dict, rng) -> dict:
    B, S = b["tokens"].shape
    if cfg.family == "vlm":
        F = cfg.frontend_seq
        b["vision_embeds"] = jnp.asarray(
            rng.randn(B, F, cfg.d_model).astype(np.float32) * 0.02)
        # M-RoPE positions: image patches first (t=0, spatial grid), then text
        g = max(1, int(np.sqrt(F)))
        t = np.zeros((F,), np.int32)
        hh = (np.arange(F) // g).astype(np.int32)
        ww = (np.arange(F) % g).astype(np.int32)
        img = np.stack([t, hh, ww], -1)
        text_start = int(hh.max()) + 1
        txt = np.arange(text_start, text_start + S, dtype=np.int32)
        txt = np.stack([txt, txt, txt], -1)
        pos = np.concatenate([img, txt], 0)
        b["positions"] = jnp.asarray(np.broadcast_to(pos[None], (B, F + S, 3)).copy())
    if cfg.family == "encdec":
        F = cfg.frontend_seq
        b["src_embeds"] = jnp.asarray(
            rng.randn(B, F, cfg.d_model).astype(np.float32) * 0.02)
    return b
