"""Synthetic 8-benchmark prompt corpus (paper: Fig. 3 / Table 1 datasets).

No internet access in this environment, so we synthesize a prompt corpus
that preserves the properties the paper's evaluation depends on:

  * eight benchmark families with the paper's relative sizes (Table 1
    run counts / 5 inference strategies);
  * a ground-truth complexity tier per prompt (low / medium / high) — the
    router's training label, mirroring the paper's label construction;
  * keyword signal embedded with benchmark-dependent probability, so the
    keyword router is informative but imperfect (paper: Fig. 4/5);
  * per-benchmark expected output lengths (drives completion/truncation
    behaviour, hence Table-1-style success rates);
  * per-benchmark baseline success probabilities matching Table 1.

Everything is seeded and deterministic.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

# Table 1 baseline statistics (runs, success %) from the paper
BENCHMARK_STATS: Dict[str, dict] = {
    "humaneval":  {"runs": 820,    "base_success": 0.800, "kind": "code"},
    "gsm8k":      {"runs": 6595,   "base_success": 0.898, "kind": "math"},
    "mbpp":       {"runs": 2500,   "base_success": 0.694, "kind": "code"},
    "truthfulqa": {"runs": 3950,   "base_success": 0.802, "kind": "factual"},
    "arc":        {"runs": 5860,   "base_success": 0.803, "kind": "reasoning"},
    "hellaswag":  {"runs": 50210,  "base_success": 0.802, "kind": "commonsense"},
    "math":       {"runs": 25000,  "base_success": 0.796, "kind": "math"},
    "mmlu_pro":   {"runs": 60160,  "base_success": 0.700, "kind": "multitask"},
}
TOTAL_RUNS = 163720          # paper total
TOTAL_PROMPTS = 31019        # paper unique prompts
STRATEGIES = 5               # inference strategies per prompt

LOW_KEYWORDS = ["sum", "list", "define", "what is", "name", "count"]
HIGH_KEYWORDS = ["prove", "derive", "explain why", "step by step",
                 "justify", "analyze"]

# complexity mix per benchmark: P(low), P(medium), P(high)
COMPLEXITY_MIX = {
    "humaneval":  (0.25, 0.50, 0.25),
    "gsm8k":      (0.30, 0.50, 0.20),
    "mbpp":       (0.40, 0.45, 0.15),
    "truthfulqa": (0.35, 0.45, 0.20),
    "arc":        (0.30, 0.45, 0.25),
    "hellaswag":  (0.55, 0.35, 0.10),
    "math":       (0.10, 0.40, 0.50),
    "mmlu_pro":   (0.20, 0.45, 0.35),
}

# P(an indicative keyword appears | tier) — keyword routing is useful but
# imperfect, reproducing the paper's keyword/semantic gap
KEYWORD_EMIT = {"low": 0.80, "medium": 0.35, "high": 0.75}

# expected new-token output length (mean, std) per benchmark kind
OUTPUT_LEN = {
    "code": (180, 90), "math": (120, 60), "factual": (60, 30),
    "reasoning": (80, 40), "commonsense": (30, 15), "multitask": (70, 40),
}

TIERS = ("low", "medium", "high")

_SUBJECTS = ["the sequence", "a binary tree", "the dataset", "this function",
             "the equation", "a physical system", "the market model",
             "an enzyme pathway", "the training loop", "a state machine"]
# tier-correlated lexical cues: the semantic signal a learned classifier can
# exploit beyond the explicit router keywords (mimics what DistilBERT picks
# up from real prompts — phrasing, hedging, scaffolding)
_TIER_CUES = {
    "low": ["briefly", "directly", "in one line", "simply"],
    "medium": ["as usual", "in the standard way", "concisely but fully"],
    "high": ["rigorously", "with full justification", "considering corner "
             "cases and asymptotics", "via a multi-step argument"],
}
_CUE_EMIT = 0.9
_TASKS_LOW = ["write down", "output", "return", "compute", "give"]
_TASKS_HIGH = ["carefully work through", "rigorously show", "formally verify",
               "derive from first principles"]
_FILLERS = ["considering all edge cases", "for n up to 10^9",
            "under the stated constraints", "with full intermediate steps",
            "in the general case", "given the context above"]


@dataclass(frozen=True)
class Prompt:
    uid: int
    benchmark: str
    text: str
    complexity: str            # ground-truth tier: low | medium | high
    out_tokens: int            # tokens needed for a valid completion
    base_success: float        # Table-1 baseline completion probability


def _sample_tier(rng, bench: str) -> str:
    return TIERS[rng.choice(3, p=COMPLEXITY_MIX[bench])]


def _make_text(rng, bench: str, tier: str) -> str:
    subj = _SUBJECTS[rng.randint(len(_SUBJECTS))]
    filler = _FILLERS[rng.randint(len(_FILLERS))]
    parts = []
    if rng.rand() < KEYWORD_EMIT[tier]:
        pool = LOW_KEYWORDS if tier == "low" else (
            HIGH_KEYWORDS if tier == "high" else LOW_KEYWORDS + HIGH_KEYWORDS)
        parts.append(pool[rng.randint(len(pool))].capitalize())
    else:
        parts.append(_TASKS_LOW[rng.randint(len(_TASKS_LOW))].capitalize()
                     if tier != "high" else
                     _TASKS_HIGH[rng.randint(len(_TASKS_HIGH))].capitalize())
    parts.append(f"{subj} ({bench})")
    # high-tier prompts are longer (paper: complexity correlates with, but
    # is not determined by, length — we add noise)
    n_extra = {"low": 1, "medium": 2, "high": 4}[tier] + rng.randint(0, 3)
    parts.extend(rng.permutation(_FILLERS)[:n_extra].tolist())
    if rng.rand() < _CUE_EMIT:
        cues = _TIER_CUES[tier]
        parts.insert(1 + rng.randint(0, 2), cues[rng.randint(len(cues))])
    parts.append(filler)
    return " ".join(parts) + "."


def generate_corpus(n_prompts: int = 2000, seed: int = 0) -> List[Prompt]:
    """Corpus with the paper's benchmark proportions (scaled to n_prompts)."""
    rng = np.random.RandomState(seed)
    total = sum(s["runs"] for s in BENCHMARK_STATS.values())
    prompts: List[Prompt] = []
    uid = 0
    for bench, stats in BENCHMARK_STATS.items():
        n = max(8, round(n_prompts * stats["runs"] / total))
        mu, sd = OUTPUT_LEN[stats["kind"]]
        for _ in range(n):
            tier = _sample_tier(rng, bench)
            ot = int(np.clip(rng.normal(mu, sd), 8, 512))
            # harder prompts need longer outputs
            ot = int(ot * {"low": 0.7, "medium": 1.0, "high": 1.5}[tier])
            prompts.append(Prompt(
                uid=uid, benchmark=bench, text=_make_text(rng, bench, tier),
                complexity=tier, out_tokens=ot,
                base_success=stats["base_success"]))
            uid += 1
    rng.shuffle(prompts)
    return prompts


def paper_scale_corpus(seed: int = 0) -> List[Prompt]:
    """Full 31,019-prompt corpus matching the paper's scale."""
    return generate_corpus(TOTAL_PROMPTS, seed)


def split(prompts: List[Prompt], val_frac: float = 0.1, seed: int = 1):
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(prompts))
    n_val = int(len(prompts) * val_frac)
    val = [prompts[i] for i in idx[:n_val]]
    train = [prompts[i] for i in idx[n_val:]]
    return train, val
