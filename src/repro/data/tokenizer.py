"""Byte-level tokenizer (vocab 256 bytes + 4 specials).

Every assigned arch has vocab >= 512 even in reduced form, so byte ids are
universally valid. Deterministic, reversible, dependency-free.
"""
from __future__ import annotations

from typing import List

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
SEP_ID = 259
VOCAB_SIZE = 260


class ByteTokenizer:
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID
    sep_id = SEP_ID
    vocab_size = VOCAB_SIZE

    def encode(self, text: str, bos: bool = False, eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: List[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")


def clamp_vocab(ids: List[int], vocab_size: int) -> List[int]:
    """Fold special ids into range for tiny-vocab smoke models."""
    return [i % vocab_size for i in ids]
