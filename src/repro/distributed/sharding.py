"""Declarative sharding rules for every architecture in the zoo.

Strategy (DESIGN.md §5, updated through the §Perf iterations in
EXPERIMENTS.md):
  * weights: Megatron-style TP — parallel dim (heads / d_ff / experts /
    SSD heads / vocab) over ``model``; MoE expert banks keep a secondary
    ``data`` dim for memory; embeddings are vocab-parallel with a
    d@data fallback ONLY when the vocab doesn't divide (either-or);
  * activations: batch over ``data`` (x ``pod``);
  * KV caches: batch over ``data``; kv-heads over ``model`` when divisible,
    otherwise the cache SEQUENCE dim over ``model`` (context-parallel
    decode). MLA latent caches seq-shard over ``model`` by default (§Perf
    H3: -96% decode collectives). Batch=1 long-context decode shards the
    sequence dim over ``data`` too.
  * every rule passes through divisibility pruning, so all ten
    heterogeneous archs lower without per-arch special cases.

Rules match on the parameter path (joined with '/') with trailing-ndim
awareness; specs are padded with leading None for stacked (scanned) layers.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig

# (regex on path, ndim of the TRAILING dims the spec describes, spec)
# first match wins.
#
# Baseline scheme: Megatron-style tensor parallelism — the "parallel" dim
# (heads / d_ff / experts / SSD heads / vocab) shards over ``model``; the
# contraction dim stays unsharded so forward matmuls produce at most ONE
# partial-sum all-reduce per block (wo / w_down row-parallel layers).
# MoE expert banks additionally shard their FFN width over ``data`` for
# memory (236B must fit 16 GB/chip).
#
# NOTE (§Perf iteration 0, recorded in EXPERIMENTS.md): the first version
# of these rules was FSDP-style 2D weight sharding (second weight dim over
# ``data``). XLA's SPMD partitioner lowered the d-contractions against
# data-sharded weight dims into partial-sum all-reduces over activations
# with the BATCH dim replicated — 2.4 TB of collectives per smollm train
# step (~100x the Megatron form). Hypothesis refuted; scheme replaced.
PARAM_RULES = [
    # MoE expert banks: (E, d, f) / (E, f, d) — experts over model,
    # expert-FFN width over data (memory), contraction dims unsharded
    (r"ffn/w_(gate|up)$", 3, ("model", None, "data")),
    (r"ffn/w_down$", 3, ("model", "data", None)),
    (r"ffn/router$", 2, (None, "model")),
    # dense FFN (incl. shared experts)
    (r"(ffn|shared|shared_ffn)/w_(gate|up|1)$", 2, (None, "model")),
    (r"(ffn|shared|shared_ffn)/w_(down|2)$", 2, ("model", None)),
    # attention projections (column-parallel qkv, row-parallel out)
    (r"attn/w(q|k|v)$|wqkv$", 2, (None, "model")),
    (r"attn/wo$|/wo$", 2, ("model", None)),
    (r"attn/b(q|k|v)$", 1, ("model",)),
    # MLA: LoRA ranks column-sharded; up-projections head-sharded
    (r"w_dq$|w_dkv$", 2, (None, "model")),
    (r"w_uq$|w_uk$|w_uv$", 2, (None, "model")),
    # mamba2: SSD heads over model (in_* column-, out_proj row-parallel)
    (r"mixer/in_(z|xbc|dt)$", 2, (None, "model")),
    (r"mixer/out_proj$", 2, ("model", None)),
    (r"mixer/conv_w$", 2, (None, "model")),
    (r"mixer/(conv_b)$", 1, ("model",)),
    (r"mixer/(A_log|D|dt_bias)$", 1, ("model",)),
    # embeddings / unembedding: vocab-parallel; when the assigned vocab
    # doesn't divide the model axis (mamba2's 50280), fall back to sharding
    # d_model over data (prune_spec resolves per-dim)
    (r"embed$|lm_head$|^pos$", 2, ("model", "data")),
    # norms and everything 1-D: replicate
    (r".*", 1, (None,)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def prune_spec(shape: Tuple[int, ...], spec: Tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the dim or are already used."""
    used = set()
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        keep = []
        for a in axes:
            if a in used or a not in mesh.shape:
                continue
            size = mesh.shape[a]
            cur = int(np.prod([mesh.shape[x] for x in keep])) or 1
            if dim % (cur * size) == 0:
                keep.append(a)
                used.add(a)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    # embeddings: vocab-parallel, with d-over-data ONLY as a fallback when
    # the vocab doesn't divide (both at once re-creates the pathological
    # 2D-sharded-weight gather pattern — §Perf H2 iteration 3).
    if re.search(r"embed$|lm_head$|^pos$", path) and len(shape) == 2:
        vocab_spec = prune_spec(shape, ("model", None), mesh)
        if vocab_spec[0] is not None:
            return vocab_spec
        return prune_spec(shape, (None, "data"), mesh)
    for pat, ndim, spec in PARAM_RULES:
        if re.search(pat, path) and len(shape) >= ndim:
            lead = (None,) * (len(shape) - ndim)
            return prune_spec(shape, lead + tuple(spec), mesh)
    return P()


def param_shardings(params_shape, mesh: Mesh):
    """Tree of NamedSharding matching a params (shape) tree."""
    def one(path, leaf):
        return NamedSharding(mesh, spec_for_param(_path_str(path),
                                                  tuple(leaf.shape), mesh))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_shardings(opt_shape, mesh: Mesh):
    """AdamW state: mu/nu shard like params; step replicated."""
    def one(path, leaf):
        p = _path_str(path)
        if p.endswith("step"):
            return NamedSharding(mesh, P())
        # strip the leading mu/ nu/ component so param rules match
        stripped = p.split("/", 1)[1] if "/" in p else p
        return NamedSharding(mesh, spec_for_param(stripped,
                                                  tuple(leaf.shape), mesh))
    return jax.tree_util.tree_map_with_path(one, opt_shape)


# ---------------------------------------------------------------------------
# activations / inputs


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_shardings(cfg: ModelConfig, batch_tree, mesh: Mesh):
    """Input batch specs: batch dim over (pod, data); positions replicate
    trailing dims; modality embeds shard d_model over model."""
    da = data_axes(mesh)

    def one(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape)
        if p.endswith(("tokens", "labels", "loss_mask")):
            spec = (da,) + (None,) * (len(shape) - 1)
        elif p.endswith("positions"):
            spec = (da,) + (None,) * (len(shape) - 1)
        elif p.endswith(("vision_embeds", "src_embeds")):
            spec = (da, None, None)
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, prune_spec(shape, spec, mesh))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_shardings(cfg: ModelConfig, cache_tree, mesh: Mesh, batch: int,
                    mla_seq_shard: bool = True):
    """KV / state cache specs.

    ``mla_seq_shard``: shard the MLA latent cache's SEQUENCE dim over
    ``model`` (context-parallel decode) — §Perf H3 optimization: the
    absorbed einsums then reduce partial-softmax stats instead of
    all-gathering the f32 latent stream to every model rank.

    Layout reminders (leading L = stacked layers axis):
      gqa:    k/v (L, B, S, Hkv, D)
      mla:    ckv (L, B, S, r), krope (L, B, S, dr)
      ssm:    conv (L, B, W-1, CH), ssm (L, B, H, P, N)
      hybrid: mamba.* like ssm; attn.k/v (APPS, B, S, Hkv, D)
      encdec: stack.self|cross.k/v (L, B, S, Hkv, D)
    """
    da = data_axes(mesh)
    msize = mesh.shape.get("model", 1)
    batch_shardable = all(batch % int(np.prod([mesh.shape[a] for a in da[:i + 1]])) == 0
                          for i in range(len(da))) and batch > 1

    def one(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape)
        name = p.rsplit("/", 1)[-1]
        if name in ("k_scale", "v_scale"):
            hkv = shape[3]
            head_ax = "model" if hkv % msize == 0 else None
            spec = (None, da if batch_shardable else None, None, head_ax, None)
        elif name in ("k", "v"):
            hkv = shape[3]
            head_ax = "model" if hkv % msize == 0 else None
            seq_axes = []
            if not batch_shardable:
                seq_axes.extend(da)            # context-parallel over data
            if head_ax is None:
                seq_axes.append("model")       # heads indivisible -> seq
            spec = (None,
                    da if batch_shardable else None,
                    tuple(seq_axes) or None,
                    head_ax, None)
        elif name in ("ckv", "krope"):
            seq_axes = [] if batch_shardable else list(da)
            if mla_seq_shard:
                seq_axes.append("model")
            spec = (None, da if batch_shardable else None,
                    tuple(seq_axes) or None, None)
        elif name == "conv":
            spec = (None, da if batch_shardable else None, None, "model")
        elif name == "ssm":
            spec = (None, da if batch_shardable else None, "model", None, None)
        else:
            spec = (None,) * len(shape)
        return NamedSharding(mesh, prune_spec(shape, spec, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


def logits_sharding(cfg: ModelConfig, mesh: Mesh, batch: int, with_seq: bool):
    da = data_axes(mesh)
    bx = da if batch > 1 else None
    spec = (bx, None, "model") if with_seq else (bx, "model")
    shape = (batch, 1, cfg.vocab_size) if with_seq else (batch, cfg.vocab_size)
    return NamedSharding(mesh, prune_spec(shape, spec, mesh))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
