"""Msgpack pytree checkpointing (no orbax in this env).

Format: {"__tree__": flattened {path: (dtype, shape)} manifest,
         "__data__": raw little-endian bytes per leaf}, compressed with
zstd when the optional ``zstandard`` package is present, else stdlib
zlib. Loading sniffs the container magic, so checkpoints written with
either codec read back on any install (as long as zstd files are only
opened where zstd is available).
"""
from __future__ import annotations

import os
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                   # optional codec — zlib fallback below
    import zstandard as zstd
except ImportError:                    # pragma: no cover - env-dependent
    zstd = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(payload: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(payload)
    return zlib.compress(payload, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but 'zstandard' is not "
                "installed; re-save it with the zlib codec or install zstd")
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def save_pytree(tree: Any, path: str) -> None:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = []
    blobs = []
    for p, leaf in leaves_with_paths:
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype = "bfloat16"
        else:
            dtype = arr.dtype.name
        manifest.append({"path": _path_str(p), "dtype": dtype,
                         "shape": list(arr.shape)})
        blobs.append(arr.tobytes())
    payload = msgpack.packb({"manifest": manifest, "blobs": blobs,
                             "treedef": str(treedef)})
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(_compress(payload))


def load_pytree(template: Any, path: str) -> Any:
    """Restore into the structure of ``template`` (shapes must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(_decompress(f.read()))
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    by_path = {m["path"]: (m, b) for m, b in
               zip(payload["manifest"], payload["blobs"])}
    out = []
    for p, leaf in leaves_with_paths:
        key = _path_str(p)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        m, blob = by_path[key]
        if m["dtype"] == "bfloat16":
            arr = np.frombuffer(blob, np.uint16).reshape(m["shape"])
            arr = jnp.asarray(arr).view(jnp.bfloat16)
        else:
            arr = jnp.asarray(np.frombuffer(blob, m["dtype"]).reshape(m["shape"]))
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
