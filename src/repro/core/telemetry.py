"""Telemetry: sliding-window service statistics (paper Fig. 1 feedback loop).

Feeds Algorithm 1 (request rate + average latency over a window, default
w = 5 min) and the score normalizers (historical latency/cost bounds).
Works on either real wall-clock (gateway) or simulated time (simulator).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Tuple

WINDOW_S = 300.0   # paper: w = 5 min


class Telemetry:
    def __init__(self, window_s: float = WINDOW_S):
        self.window_s = window_s
        self._requests: Dict[str, Deque[float]] = defaultdict(deque)
        self._latency: Dict[str, Deque[Tuple[float, float]]] = defaultdict(deque)
        self._last_seen: Dict[str, float] = {}
        self._gauges: Dict[Tuple[str, str], Tuple[float, float]] = {}

    # -- recording ---------------------------------------------------------
    def record_request(self, model: str, t: float) -> None:
        self._requests[model].append(t)
        self._last_seen[model] = t
        self._gc(model, t)

    def record_latency(self, model: str, t: float, latency_s: float) -> None:
        self._latency[model].append((t, latency_s))
        self._gc(model, t)

    def record_gauge(self, model: str, name: str, t: float,
                     value: float) -> None:
        """Point-in-time service gauge (e.g. ``kv_pressure``,
        ``kv_hit_rate`` from the paged serve plane). Last write wins."""
        self._gauges[(model, name)] = (t, value)

    def gauge(self, model: str, name: str, now: float = None,
              default: float = 0.0) -> float:
        """Latest gauge value; stale readings (older than the telemetry
        window) fall back to ``default`` when ``now`` is given."""
        rec = self._gauges.get((model, name))
        if rec is None:
            return default
        t, value = rec
        if now is not None and now - t > self.window_s:
            return default
        return value

    def _gc(self, model: str, now: float) -> None:
        cut = now - self.window_s
        q = self._requests[model]
        while q and q[0] < cut:
            q.popleft()
        ql = self._latency[model]
        while ql and ql[0][0] < cut:
            ql.popleft()

    # -- queries (Algorithm 1 inputs) ---------------------------------------
    def request_rate(self, model: str, now: float) -> float:
        """GetAvgRequestRate(m, w): requests/second over the window."""
        self._gc(model, now)
        q = self._requests[model]
        if not q:
            return 0.0
        span = max(now - q[0], 1.0)
        return len(q) / span

    def avg_latency(self, model: str, now: float, default: float = 1.0) -> float:
        """GetAvgLatency(m)."""
        self._gc(model, now)
        ql = self._latency[model]
        if not ql:
            return default
        return sum(v for _, v in ql) / len(ql)

    def idle_time(self, model: str, now: float) -> float:
        """IdleTime(m): seconds since the last request."""
        if model not in self._last_seen:
            return float("inf")
        return now - self._last_seen[model]
