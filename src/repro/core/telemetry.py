"""Telemetry: sliding-window service statistics (paper Fig. 1 feedback loop).

Feeds Algorithm 1 (request rate + average latency over a window, default
w = 5 min) and the score normalizers (historical latency/cost bounds).
Works on either real wall-clock (gateway) or simulated time (simulator).

Bridged to the observability plane (``repro.obs``): built with a
``MetricsRegistry``, every latency sample also lands in a per-model
``service_latency_s`` histogram and every gauge write mirrors into a
registry gauge — so the SAME feed Algorithm 1 ticks on is exported via
``--metrics-dump`` and queryable as quantiles.  ``latency_quantile``
answers p50/p95/p99 over the telemetry window (exact, from the windowed
samples), which is the signal the self-tuning control loops consume
where ``avg_latency`` alone would hide tail collapse.
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import TYPE_CHECKING, Deque, Dict, Optional, Tuple

if TYPE_CHECKING:                                  # import cycle guard only
    from repro.obs import MetricsRegistry

WINDOW_S = 300.0   # paper: w = 5 min


class Telemetry:
    def __init__(self, window_s: float = WINDOW_S,
                 registry: Optional["MetricsRegistry"] = None):
        self.window_s = window_s
        self.registry = registry
        self._requests: Dict[str, Deque[float]] = defaultdict(deque)
        self._latency: Dict[str, Deque[Tuple[float, float]]] = defaultdict(deque)
        self._last_seen: Dict[str, float] = {}
        self._gauges: Dict[Tuple[str, str], Tuple[float, float]] = {}

    # -- recording ---------------------------------------------------------
    def record_request(self, model: str, t: float) -> None:
        self._requests[model].append(t)
        self._last_seen[model] = t
        if self.registry is not None:
            self.registry.counter("requests", model).inc()
        self._gc(model, t)

    def record_latency(self, model: str, t: float, latency_s: float) -> None:
        self._latency[model].append((t, latency_s))
        if self.registry is not None:
            self.registry.histogram("service_latency_s",
                                    model).observe(latency_s)
        self._gc(model, t)

    def record_gauge(self, model: str, name: str, t: float,
                     value: float) -> None:
        """Point-in-time service gauge (e.g. ``kv_pressure``,
        ``kv_hit_rate`` from the paged serve plane). Last write wins."""
        self._gauges[(model, name)] = (t, value)
        if self.registry is not None:
            self.registry.gauge(name, model).set(value, stamp=t)

    def gauge(self, model: str, name: str, now: Optional[float] = None,
              default: float = 0.0) -> float:
        """Latest gauge value; stale readings (older than the telemetry
        window) fall back to ``default`` when ``now`` is given."""
        rec = self._gauges.get((model, name))
        if rec is None:
            return default
        t, value = rec
        if now is not None and now - t > self.window_s:
            return default
        return value

    def _gc(self, model: str, now: float) -> None:
        cut = now - self.window_s
        q = self._requests[model]
        while q and q[0] < cut:
            q.popleft()
        ql = self._latency[model]
        while ql and ql[0][0] < cut:
            ql.popleft()

    # -- queries (Algorithm 1 inputs) ---------------------------------------
    def request_rate(self, model: str, now: float) -> float:
        """GetAvgRequestRate(m, w): requests/second over the window."""
        self._gc(model, now)
        q = self._requests[model]
        if not q:
            return 0.0
        span = max(now - q[0], 1.0)
        return len(q) / span

    def avg_latency(self, model: str, now: float, default: float = 1.0) -> float:
        """GetAvgLatency(m)."""
        self._gc(model, now)
        ql = self._latency[model]
        if not ql:
            return default
        return sum(v for _, v in ql) / len(ql)

    def latency_quantile(self, model: str, now: float, q: float = 0.95,
                         default: float = 1.0) -> float:
        """Latency quantile over the telemetry window (exact, linear
        interpolation over the windowed samples) — ``p95_latency`` is
        the tail signal the self-tuning serve plane targets where the
        window AVERAGE hides queueing collapse."""
        self._gc(model, now)
        ql = self._latency[model]
        if not ql:
            return default
        vals = sorted(v for _, v in ql)
        if len(vals) == 1:
            return vals[0]
        pos = q * (len(vals) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(vals) - 1)
        return vals[lo] + (vals[hi] - vals[lo]) * (pos - lo)

    def p95_latency(self, model: str, now: float,
                    default: float = 1.0) -> float:
        """GetP95Latency(m): the Algorithm-1-adjacent tail query."""
        return self.latency_quantile(model, now, 0.95, default)

    def idle_time(self, model: str, now: float) -> float:
        """IdleTime(m): seconds since the last request."""
        if model not in self._last_seen:
            return float("inf")
        return now - self._last_seen[model]

    def cost_per_query(self, model: str) -> float:
        """Measured mean $/query for ``model`` from the chip-second
        ledger's registry gauge — the live counterpart of the paper's
        attributed-cost column, available to the same control loops
        that read the latency quantiles. 0.0 until a request closes
        (or when metrics are off)."""
        if self.registry is None:
            return 0.0
        return self.registry.value("cost_per_query_usd", model)
