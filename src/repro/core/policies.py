"""Matrix selection and routing — paper Algorithm 2 + baselines.

Given a prompt's RouteDecision and the service matrix, select (x*, y*) =
argmax f(p, S_xy). Three strategies, matching the paper's Table 3:

  random          — uniform over healthy services (baseline)
  latency_only    — argmin predicted latency (healthy, has capacity)
  multi_objective — Algorithm 2 with the Eq. 2 score

The policies only READ the registry; queuing/cold-start consequences are
the simulator's (or gateway's) business.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.costmodel import predict_cost, predict_latency
from repro.core.registry import ServiceEntry, ServiceRegistry
from repro.core.router import RouteDecision, relevance
from repro.core.scoring import MinMaxNormalizer, OperatorProfile, \
    orchestration_score


@dataclass
class Selection:
    entry: ServiceEntry
    score: float
    pred_latency: float
    pred_cost: float
    relevance: float


class SelectionPolicy:
    name = "base"

    def __init__(self, registry: ServiceRegistry, seed: int = 0,
                 require_capacity: bool = True):
        """``require_capacity=False`` lets the policy pick scaled-to-zero
        services (their cold start enters the latency prediction) — the
        gateway's scale-from-zero-on-route mode."""
        self.reg = registry
        self.rng = np.random.RandomState(seed)
        self.require_capacity = require_capacity
        self.t_norm = MinMaxNormalizer(0.0, 1.0)
        self.c_norm = MinMaxNormalizer(0.0, 1e-4)

    def _viable(self, require_capacity: bool) -> List[ServiceEntry]:
        require_capacity = require_capacity and self.require_capacity
        ents = [e for e in self.reg.entries() if e.healthy]
        if require_capacity:
            up = [e for e in ents if e.has_capacity()]
            if up:
                return up
        return ents

    def _predict(self, e: ServiceEntry, prompt_tokens: int, out_tokens: int
                 ) -> Tuple[float, float]:
        queue = 0.0
        if e.replicas == 0:
            queue += e.cost.cold_start_s if e.warm == 0 else e.cost.warm_start_s
        if e.queued or not e.has_capacity():
            # waiting work ahead of us, drained at the fleet's batched rate
            fleet_tps = e.cost.tokens_per_s * max(e.replicas, 1)
            queue += (e.queued + 1) * out_tokens / max(fleet_tps, 1e-6)
        # mild batching penalty mirrors the simulator's decode model
        from repro.serving.backend import BACKENDS
        nb = max(1, min(e.active_requests + 1, BACKENDS[e.backend].max_batch))
        penalty = 1.0 + 0.25 * (nb - 1) / BACKENDS[e.backend].max_batch
        lat = predict_latency(e.cost, prompt_tokens, out_tokens, queue,
                              1.0 / penalty)
        cost = predict_cost(e.cost, lat - queue, 1.0 / nb)
        self.t_norm.update(lat)
        self.c_norm.update(cost)
        return lat, cost

    def select(self, decision: RouteDecision, prompt_tokens: int,
               out_tokens: int, profile: OperatorProfile) -> Selection:
        raise NotImplementedError


class RandomPolicy(SelectionPolicy):
    name = "random"

    def select(self, decision, prompt_tokens, out_tokens, profile) -> Selection:
        ents = self._viable(require_capacity=False)
        e = ents[self.rng.randint(len(ents))]
        lat, cost = self._predict(e, prompt_tokens, out_tokens)
        return Selection(e, 0.0, lat, cost, relevance(decision, e.tier))


class LatencyOnlyPolicy(SelectionPolicy):
    name = "latency_only"

    def select(self, decision, prompt_tokens, out_tokens, profile) -> Selection:
        best, best_lat, best_cost = None, float("inf"), 0.0
        for e in self._viable(require_capacity=True):
            lat, cost = self._predict(e, prompt_tokens, out_tokens)
            if lat < best_lat:
                best, best_lat, best_cost = e, lat, cost
        return Selection(best, 0.0, best_lat, best_cost,
                         relevance(decision, best.tier))


class MultiObjectivePolicy(SelectionPolicy):
    """Algorithm 2: evaluate f over every healthy (model x backend) pair."""
    name = "multi_objective"

    def select(self, decision, prompt_tokens, out_tokens, profile) -> Selection:
        # two passes: predict ALL candidates first so the min-max bounds
        # cover this round before any score is computed (order-independent)
        cands = []
        for e in self._viable(require_capacity=True):         # line 3 (healthy)
            r = relevance(decision, e.tier)                   # R(p, L_x)
            lat, cost = self._predict(e, prompt_tokens, out_tokens)
            cands.append((e, r, lat, cost))
        best: Optional[Selection] = None
        for e, r, lat, cost in cands:
            f = orchestration_score(r, lat, cost, profile,
                                    self.t_norm, self.c_norm)  # Eq. 2 (line 5)
            if best is None or f > best.score:
                best = Selection(e, f, lat, cost, r)           # line 7 argmax
        return best


def _load_policies():
    from repro.core.bandit import BanditPolicy
    return {p.name: p for p in
            (RandomPolicy, LatencyOnlyPolicy, MultiObjectivePolicy,
             BanditPolicy)}


POLICIES = _load_policies()
