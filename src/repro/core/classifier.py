"""Prompt-complexity classifier — the paper's DistilBERT analogue, in JAX.

A small bidirectional transformer encoder over byte tokens with a [CLS]
head (paper Eq. 3–4):

    p_k = softmax(W h_[CLS] + b),   C_hat = argmax_k p_k

Trained exactly as the paper describes where transferable: 3-way
cross-entropy, AdamW, batch 32, lr 2e-5 (epochs scaled down for CPU).
The paper fine-tunes a 66M-param pretrained DistilBERT; with no weights
available offline we train a compact encoder from scratch on the same
corpus both routers share — the fair-comparison requirement the paper
states. Validation accuracy is reported as measured (paper: 96.8%).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.benchmarks import TIERS, Prompt
from repro.data.tokenizer import ByteTokenizer
from repro.models.common import (dense_init, embed_init, init_layernorm,
                                 layernorm, stack_init)
from repro.training.optimizer import AdamWConfig, adamw_update, init_adamw

CLS_ID = 259  # reuse SEP slot as [CLS]


@dataclass(frozen=True)
class ClassifierConfig:
    vocab_size: int = 260
    max_len: int = 128
    d_model: int = 128
    num_heads: int = 4
    d_ff: int = 512
    num_layers: int = 2
    num_classes: int = 3


def init_classifier(cfg: ClassifierConfig, key) -> dict:
    ks = jax.random.split(key, 5)

    def block(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "ln1": init_layernorm(cfg.d_model),
            "wqkv": dense_init(k1, cfg.d_model, 3 * cfg.d_model),
            "wo": dense_init(k2, cfg.d_model, cfg.d_model),
            "ln2": init_layernorm(cfg.d_model),
            "w1": dense_init(k3, cfg.d_model, cfg.d_ff),
            "w2": dense_init(k4, cfg.d_ff, cfg.d_model),
        }

    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "pos": embed_init(ks[1], cfg.max_len, cfg.d_model),
        "layers": stack_init(ks[2], cfg.num_layers, block),
        "ln_f": init_layernorm(cfg.d_model),
        "w_cls": dense_init(ks[3], cfg.d_model, cfg.num_classes),
        "b_cls": jnp.zeros((cfg.num_classes,)),
    }


def classifier_logits(params: dict, cfg: ClassifierConfig,
                      tokens: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """tokens: (B, S) int32 with [CLS] at position 0; mask: (B, S) {0,1}."""
    B, S = tokens.shape
    h = params["embed"][tokens] + params["pos"][None, :S]
    neg = jnp.where(mask[:, None, None, :] > 0, 0.0, -1e30)

    def body(h, lp):
        x = layernorm(lp["ln1"], h)
        qkv = x @ lp["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = cfg.d_model // cfg.num_heads
        q = q.reshape(B, S, cfg.num_heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, S, cfg.num_heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, cfg.num_heads, hd).transpose(0, 2, 1, 3)
        s = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(hd) + neg
        a = jax.nn.softmax(s, axis=-1) @ v
        a = a.transpose(0, 2, 1, 3).reshape(B, S, cfg.d_model)
        h = h + a @ lp["wo"]
        x = layernorm(lp["ln2"], h)
        h = h + jax.nn.gelu(x @ lp["w1"]) @ lp["w2"]
        return h, None

    h, _ = jax.lax.scan(body, h, params["layers"])
    h_cls = layernorm(params["ln_f"], h[:, 0])     # [CLS] embedding (Eq. 3)
    return h_cls @ params["w_cls"] + params["b_cls"]


# ---------------------------------------------------------------------------
# data prep + training


def encode_prompts(texts: Sequence[str], max_len: int = 128
                   ) -> Tuple[np.ndarray, np.ndarray]:
    tok = ByteTokenizer()
    ids = np.full((len(texts), max_len), 0, np.int32)
    mask = np.zeros((len(texts), max_len), np.int32)
    for i, t in enumerate(texts):
        e = [CLS_ID] + tok.encode(t)[: max_len - 1]
        ids[i, : len(e)] = e
        mask[i, : len(e)] = 1
    return ids, mask


def train_classifier(
    prompts: List[Prompt],
    val_prompts: List[Prompt],
    cfg: ClassifierConfig = ClassifierConfig(),
    epochs: int = 3,
    batch_size: int = 32,           # paper hyperparameter
    lr: float = 2e-5 * 50,          # paper lr is for a pretrained 66M model;
                                    # scaled for from-scratch training
    seed: int = 0,
    log=print,
) -> Tuple[dict, dict]:
    """Returns (params, report{val_accuracy, ...})."""
    x, m = encode_prompts([p.text for p in prompts], cfg.max_len)
    y = np.asarray([TIERS.index(p.complexity) for p in prompts], np.int32)
    xv, mv = encode_prompts([p.text for p in val_prompts], cfg.max_len)
    yv = np.asarray([TIERS.index(p.complexity) for p in val_prompts], np.int32)

    params = init_classifier(cfg, jax.random.PRNGKey(seed))
    opt = AdamWConfig(lr=lr, weight_decay=0.01, grad_clip=1.0,
                      warmup_steps=20,
                      total_steps=max(1, epochs * len(prompts) // batch_size))
    opt_state = init_adamw(params)

    def loss_fn(params, tokens, mask, labels):
        logits = classifier_logits(params, cfg, tokens, mask)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
        acc = (jnp.argmax(logits, -1) == labels).mean()
        return nll, acc

    @jax.jit
    def step(params, opt_state, tokens, mask, labels):
        (nll, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tokens, mask, labels)
        params, opt_state, _ = adamw_update(opt, grads, opt_state, params)
        return params, opt_state, nll, acc

    @jax.jit
    def eval_logits(params, tokens, mask):
        return classifier_logits(params, cfg, tokens, mask)

    rng = np.random.RandomState(seed)
    n = len(prompts)
    t0 = time.perf_counter()
    for ep in range(epochs):
        order = rng.permutation(n)
        accs = []
        for i in range(0, n - batch_size + 1, batch_size):
            b = order[i:i + batch_size]
            params, opt_state, nll, acc = step(
                params, opt_state, jnp.asarray(x[b]), jnp.asarray(m[b]),
                jnp.asarray(y[b]))
            accs.append(float(acc))
        if log:
            log(f"classifier epoch {ep}: train_acc={np.mean(accs):.3f}")

    # validation
    preds = []
    for i in range(0, len(xv), 256):
        lg = eval_logits(params, jnp.asarray(xv[i:i + 256]),
                         jnp.asarray(mv[i:i + 256]))
        preds.append(np.argmax(np.asarray(lg), -1))
    preds = np.concatenate(preds) if preds else np.zeros(0, np.int64)
    val_acc = float((preds == yv).mean()) if len(yv) else 0.0
    report = {"val_accuracy": val_acc, "train_secs": time.perf_counter() - t0,
              "n_train": n, "n_val": len(yv), "epochs": epochs}
    if log:
        log(f"classifier val_accuracy={val_acc:.3f} (paper: 0.968)")
    return params, report


def predict_proba(params: dict, cfg: ClassifierConfig,
                  texts: Sequence[str]) -> np.ndarray:
    x, m = encode_prompts(texts, cfg.max_len)
    out = []
    fn = jax.jit(lambda p, t, mm: jax.nn.softmax(
        classifier_logits(p, cfg, t, mm), -1))
    for i in range(0, len(x), 256):
        out.append(np.asarray(fn(params, jnp.asarray(x[i:i + 256]),
                                 jnp.asarray(m[i:i + 256]))))
    return np.concatenate(out) if out else np.zeros((0, cfg.num_classes))
