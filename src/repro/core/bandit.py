"""Reinforcement-based routing — the paper's stated FUTURE WORK
("Future work will explore reinforcement based routing for adaptive
decision making"), implemented as a Thompson-sampling contextual bandit.

Context  = the router's predicted complexity tier (low/medium/high).
Arms     = model tiers (small/medium/large).
Reward   = request success (Bernoulli), optionally cost-discounted.

Per (context, arm) we keep a Beta(alpha, beta) posterior; selection samples
from each posterior and routes to the argmax arm's best service (within-arm
tie-break by predicted latency). Success/failure feedback flows back from
the simulator's completion events — the same closed control loop the paper
draws in Fig. 1, now learning the CAPABILITY structure online instead of
assuming it.

This subsumes the static capability matrix: with enough traffic the
posterior means converge to the true tier-match success rates, and the
router adapts when the pool or workload drifts (e.g. a model gets
fine-tuned, a benchmark mix shifts).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.policies import Selection, SelectionPolicy
from repro.core.router import RouteDecision, relevance
from repro.data.benchmarks import TIERS

ARMS = ("small", "medium", "large")


@dataclass
class BetaArm:
    alpha: float = 1.0
    beta: float = 1.0

    def sample(self, rng) -> float:
        return float(rng.beta(self.alpha, self.beta))

    def update(self, success: bool, weight: float = 1.0) -> None:
        if success:
            self.alpha += weight
        else:
            self.beta += weight

    @property
    def mean(self) -> float:
        return self.alpha / (self.alpha + self.beta)


class BanditPolicy(SelectionPolicy):
    """Thompson-sampling tier selection + latency tie-break within tier.

    ``cost_penalty`` discounts each arm's sampled success rate by the
    arm's normalized cost, trading accuracy for spend like the paper's mu
    preference — but learned, not configured.
    """
    name = "bandit"

    def __init__(self, registry, seed: int = 0, cost_penalty: float = 0.0,
                 require_capacity: bool = True):
        super().__init__(registry, seed, require_capacity)
        self.cost_penalty = cost_penalty
        self.posteriors: Dict[Tuple[str, str], BetaArm] = defaultdict(BetaArm)
        self.n_feedback = 0

    # -- selection ---------------------------------------------------------
    def select(self, decision: RouteDecision, prompt_tokens: int,
               out_tokens: int, profile) -> Selection:
        ctx = decision.tier
        ents = self._viable(require_capacity=True)
        by_tier = {}
        for e in ents:
            by_tier.setdefault(e.tier, []).append(e)
        # Thompson sample per available arm
        best_arm, best_draw = None, -1e9
        for arm, arm_ents in by_tier.items():
            draw = self.posteriors[(ctx, arm)].sample(self.rng)
            if self.cost_penalty:
                chips = min(e.cost.chips for e in arm_ents)
                draw -= self.cost_penalty * np.log1p(chips) / 10.0
            if draw > best_draw:
                best_arm, best_draw = arm, draw
        # within the arm: fastest predicted service
        best, best_lat, best_cost = None, float("inf"), 0.0
        for e in by_tier[best_arm]:
            lat, cost = self._predict(e, prompt_tokens, out_tokens)
            if lat < best_lat:
                best, best_lat, best_cost = e, lat, cost
        return Selection(best, float(best_draw), best_lat, best_cost,
                         relevance(decision, best.tier))

    # -- closed-loop feedback ------------------------------------------------
    def feedback(self, context_tier: str, model_tier: str,
                 success: bool) -> None:
        self.posteriors[(context_tier, model_tier)].update(success)
        self.n_feedback += 1

    def learned_capability(self) -> Dict[str, Dict[str, float]]:
        """Posterior means in CAPABILITY-matrix layout (for inspection)."""
        out = {a: {} for a in ARMS}
        for (ctx, arm), post in self.posteriors.items():
            out.setdefault(arm, {})[ctx] = post.mean
        return out
