"""Pick and Spin — the paper's primary contribution.

Pick: routing (keyword / semantic classifier / hybrid) + the multi-
objective orchestration score (Eq. 1-2). Spin: Algorithm-1 scaling with
warm pools, cooldowns and scale-to-zero over the service matrix (Eq. 5 /
Algorithm 2). Plus telemetry, the discrete-event cluster simulator, and
the real in-process gateway.
"""
from repro.core.scoring import (PROFILES, STRATEGIES, MinMaxNormalizer,  # noqa: F401
                                OperatorProfile, orchestration_score,
                                routing_efficiency)
from repro.core.router import (CAPABILITY, HybridRouter, KeywordRouter,  # noqa: F401
                               RouteDecision, SemanticRouter, relevance)
from repro.core.registry import ServiceEntry, ServiceRegistry  # noqa: F401
from repro.core.telemetry import Telemetry  # noqa: F401
from repro.core.orchestrator import Orchestrator, SpinConfig  # noqa: F401
from repro.core.policies import (POLICIES, LatencyOnlyPolicy,  # noqa: F401
                                 MultiObjectivePolicy, RandomPolicy)
from repro.core.simulator import (ClusterSimulator, SimConfig, SimReport,  # noqa: F401
                                  poisson_arrivals)
