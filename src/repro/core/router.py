"""Pick — the routing layer (paper §"Pick: The Routing Design").

Three modes, exactly as the paper defines them:
  * keyword   — deterministic rule-based tiering (low/medium/high) from
                indicative keywords; unmatched prompts -> medium.
  * semantic  — the DistilBERT-analogue classifier (core/classifier.py).
  * hybrid    — keywords first; ambiguous prompts (no keyword hit, or
                low-margin tier evidence) fall through to the classifier.

Routers emit a ``RouteDecision`` carrying the tier probabilities that feed
the relevance term R_hat(p, L_x) of the orchestration objective.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.classifier import ClassifierConfig, predict_proba
from repro.data.benchmarks import HIGH_KEYWORDS, LOW_KEYWORDS, TIERS

# capability[tier_of_model][prompt_tier] — how well a model tier serves a
# prompt tier. Encodes the paper's observation that no single model is best
# across all dimensions (large models are NOT the best low-tier servers
# once latency/cost enter, and small models fail on reasoning).
CAPABILITY: Dict[str, Dict[str, float]] = {
    "small":  {"low": 0.97, "medium": 0.62, "high": 0.30},
    "medium": {"low": 0.93, "medium": 0.90, "high": 0.66},
    "large":  {"low": 0.88, "medium": 0.92, "high": 0.95},
}

# router overhead (seconds) — keyword routing is ~free; the classifier adds
# an inference hop (paper: +23.5% median TTFT for DistilBERT routing)
KEYWORD_OVERHEAD_S = 0.0002
CLASSIFIER_OVERHEAD_S = 0.012


@dataclass(frozen=True)
class RouteDecision:
    """Immutable: decisions are shared across policy/bandit/simulator
    layers, so no consumer may rewrite another's view of the route."""
    tier: str                          # predicted complexity class C_hat
    probs: Dict[str, float]           # p_k over tiers (Eq. 3)
    mode: str                          # keyword | semantic | hybrid
    overhead_s: float = 0.0


class KeywordRouter:
    """Rule-based: low/high keyword hits; otherwise medium (paper)."""
    mode = "keyword"

    def route(self, text: str) -> RouteDecision:
        t = text.lower()
        low_hits = sum(k in t for k in LOW_KEYWORDS)
        high_hits = sum(k in t for k in HIGH_KEYWORDS)
        if high_hits > low_hits:
            tier, probs = "high", {"low": 0.05, "medium": 0.15, "high": 0.80}
        elif low_hits > high_hits:
            tier, probs = "low", {"low": 0.80, "medium": 0.15, "high": 0.05}
        else:
            tier, probs = "medium", {"low": 0.20, "medium": 0.60, "high": 0.20}
        return RouteDecision(tier, probs, self.mode, KEYWORD_OVERHEAD_S)

    def route_many(self, texts: Sequence[str]) -> List[RouteDecision]:
        return [self.route(t) for t in texts]


class SemanticRouter:
    """DistilBERT-analogue classifier routing (Eq. 3–4)."""
    mode = "semantic"

    def __init__(self, params: dict, cfg: ClassifierConfig):
        self.params = params
        self.cfg = cfg

    def route_many(self, texts: Sequence[str]) -> List[RouteDecision]:
        probs = predict_proba(self.params, self.cfg, texts)
        out = []
        for p in probs:
            tier = TIERS[int(np.argmax(p))]
            out.append(RouteDecision(
                tier, {t: float(v) for t, v in zip(TIERS, p)},
                self.mode, CLASSIFIER_OVERHEAD_S))
        return out

    def route(self, text: str) -> RouteDecision:
        return self.route_many([text])[0]


class HybridRouter:
    """Keywords for clear-cut prompts; classifier for ambiguous ones."""
    mode = "hybrid"

    def __init__(self, semantic: SemanticRouter, margin: float = 0.6):
        self.kw = KeywordRouter()
        self.sem = semantic
        self.margin = margin

    def route_many(self, texts: Sequence[str]) -> List[RouteDecision]:
        kw = self.kw.route_many(texts)
        ambiguous = [i for i, d in enumerate(kw)
                     if max(d.probs.values()) < self.margin + 1e-9
                     or d.tier == "medium"]
        sem = dict(zip(ambiguous,
                       self.sem.route_many([texts[i] for i in ambiguous])
                       if ambiguous else []))
        # fresh decisions throughout — the keyword router's outputs are
        # never rewritten in place (they may be cached/shared upstream)
        out: List[RouteDecision] = []
        for i, d in enumerate(kw):
            s = sem.get(i)
            if s is not None:
                out.append(RouteDecision(s.tier, s.probs, "hybrid",
                                         KEYWORD_OVERHEAD_S + s.overhead_s))
            else:
                out.append(RouteDecision(d.tier, dict(d.probs), "hybrid",
                                         d.overhead_s))
        return out

    def route(self, text: str) -> RouteDecision:
        return self.route_many([text])[0]


def relevance(decision: RouteDecision, model_tier: str) -> float:
    """R_hat(p, L_x): expected capability under the tier posterior."""
    return float(sum(decision.probs[t] * CAPABILITY[model_tier][t]
                     for t in TIERS))
