"""Spin — orchestration-aware scaling (paper Algorithm 1).

Verbatim implementation of the paper's loop:

    for each model m:
        r_m    <- GetAvgRequestRate(m, w)          # telemetry, w = 5 min
        lat_m  <- GetAvgLatency(m)
        target <- ceil(r_m * lat_m / Concurrency)  # Little's Law
        min_warm <- WarmPoolSize(ModelTier(m))
        if target > current and CooldownExpired(): scale(m, max(target, min_warm))
        elif IdleTime(m) > tau:                     scale(m, max(0, min_warm))

plus the lifecycle pieces the paper describes around it: warm pools per
tier, cooldown windows against oscillation, scale-to-zero for idle models,
and cold/warm start latencies on activation. ``scale`` is a callback so the
same orchestrator drives both the discrete-event simulator and the real
in-process gateway.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.registry import ServiceRegistry
from repro.core.telemetry import Telemetry
from repro.serving.backend import BACKENDS

# warm-pool sizes per model tier (paper: "maintains warm pools for
# frequently accessed models"); small models are cheap to keep warm.
WARM_POOL = {"small": 1, "medium": 1, "large": 0}


@dataclass
class SpinConfig:
    window_s: float = 300.0        # telemetry window w
    cooldown_s: float = 30.0       # CooldownExpired()
    idle_tau_s: float = 120.0      # IdleTime threshold tau
    max_replicas: int = 8
    tick_s: float = 5.0            # control-loop period
    scale_to_zero: bool = True     # PS(auto); False reproduces PS(base)
    warm_pool: Dict[str, int] = field(default_factory=lambda: dict(WARM_POOL))
    # paged serve plane: a service whose every replica is out of
    # allocatable KV blocks (kv_pressure gauge above this) is treated as
    # loaded even when Little's law alone wouldn't add capacity
    kv_pressure_high: float = 0.92


class Orchestrator:
    def __init__(self, registry: ServiceRegistry, telemetry: Telemetry,
                 cfg: Optional[SpinConfig] = None,
                 scale_cb: Optional[Callable] = None,
                 repair_cb: Optional[Callable] = None):
        self.reg = registry
        self.tel = telemetry
        # cfg=None -> a fresh SpinConfig per orchestrator: a shared default
        # instance would alias its mutable warm_pool dict across instances
        self.cfg = cfg if cfg is not None else SpinConfig()
        self.scale_cb = scale_cb          # (model, backend, new_replicas, now)
        self.repair_cb = repair_cb        # (now) -> spin quarantine substitutes
        self._last_scale_t: Dict[str, float] = {}

    # -- Algorithm 1 ---------------------------------------------------------
    def tick(self, now: float) -> Dict[str, int]:
        """One control-loop pass. Returns {model: new replica target}."""
        # repair FIRST: a quarantined replica's substitute is owed
        # capacity regardless of what Little's law says this tick (the
        # pool's warm cache makes it cheap when the service ran warm)
        if self.repair_cb is not None:
            self.repair_cb(now)
        decisions: Dict[str, int] = {}
        for model in self.reg.models:
            r_m = self.tel.request_rate(model, now)               # line 2
            lat_m = self.tel.avg_latency(model, now)              # line 3
            # Concurrency: requests a replica serves at once (its backend's
            # batch slots); use the max across this model's columns.
            conc = max(BACKENDS[b].max_batch for b in self.reg.backends)
            target = math.ceil(r_m * lat_m / conc)                # line 4
            # stranded-queue guard: work waiting on a scaled-down service
            # whose arrival telemetry has aged out of the window must still
            # pull capacity (Little's law sees rate 0 for it)
            queued = self.reg.model_queued(model)
            if queued:
                target = max(target, math.ceil(queued / conc))
            current = self.reg.model_replicas(model)              # line 5
            # KV-block pressure (paged engines report it via the
            # scheduler): all replicas block-starved -> memory, not
            # compute, is the bottleneck; one more replica adds a pool
            if current and self.tel.gauge(model, "kv_pressure", now) \
                    >= self.cfg.kv_pressure_high:
                target = max(target, current + 1)
            min_warm = self.cfg.warm_pool.get(
                self._tier(model), 0)                             # line 6
            # idle wins over the Little's-law target: once arrivals have
            # stopped for tau (and nothing is in flight or queued), the
            # window-averaged rate/latency are stale demand — acting on
            # them would flap scale-up/scale-to-zero every tick until the
            # telemetry window empties
            idle = (self.tel.idle_time(model, now) > self.cfg.idle_tau_s
                    and self.reg.model_active(model) == 0
                    and queued == 0)                              # line 9
            if idle:
                floor = min_warm if self.cfg.scale_to_zero else max(1, min_warm)
                new = max(0, floor)                               # line 10
                if new != current:
                    self._scale(model, new, now)
                    decisions[model] = new
            elif target > current and self._cooldown_expired(model, now):  # 7
                new = min(max(target, min_warm), self.cfg.max_replicas)
                if new != current:           # capped at max_replicas: no-op
                    self._scale(model, new, now)                  # line 8
                    decisions[model] = new
        return decisions

    def active_models(self):
        """Return set A = {m : replicas(m) > 0} (Algorithm 1 line 13)."""
        return {m for m in self.reg.models if self.reg.model_replicas(m) > 0}

    # -- internals -------------------------------------------------------
    def _tier(self, model: str) -> str:
        for e in self.reg.entries():
            if e.model == model:
                return e.tier
        return "medium"

    def _cooldown_expired(self, model: str, now: float) -> bool:
        return now - self._last_scale_t.get(model, -1e9) >= self.cfg.cooldown_s

    def _scale(self, model: str, replicas: int, now: float) -> None:
        """KubernetesScale(m, n): distribute replicas across the model's
        backend columns, preferring the latency backend for the first
        replica and the throughput backend for capacity."""
        self._last_scale_t[model] = now
        order = [b for b in ("trt", "vllm", "tgi") if b in self.reg.backends]
        order += [b for b in self.reg.backends if b not in order]
        per = {b: 0 for b in self.reg.backends}
        for i in range(replicas):
            per[order[min(i, len(order) - 1) % len(order)]] += 1
        for b in self.reg.backends:
            e = self.reg.entry(model, b)
            e.accrue(now)
            if self.scale_cb:
                self.scale_cb(model, b, per[b], now)
            else:
                e.replicas = per[b]
