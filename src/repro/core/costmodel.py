"""Physics-grounded cost model for every (arch x backend) service instance.

The simulator needs TTFT / tokens-per-second / $ / cold-start numbers for
models far too large to execute on this CPU. We derive them from first
principles on the TPU v5e target (the same constants the roofline module
uses) instead of inventing them:

  * decode step time  = max(compute, memory) roofline on ACTIVE params
  * prefill time      = 2 * N_active * prompt_len / (chips * peak * MFU)
  * replica size      = ceil(bytes(params) / (HBM_per_chip * budget)) chips
  * cold start        = weight load from PVC + program compile + warmup
  * cost              = chip_seconds * $/chip-hour

Backend profiles multiply these base numbers (serving/backend.py).
Small archs additionally get CPU-measured constants when the real engine
runs them (core/gateway.py feeds telemetry back in — the paper's closed
control loop).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.serving.backend import BackendProfile

# TPU v5e hardware constants (shared with repro/roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
HBM_BYTES = 16e9             # per chip
ICI_BW = 50e9                # bytes/s per link
USD_PER_CHIP_HOUR = 1.20     # on-demand v5e list-ish price
PVC_LOAD_BW = 2.0e9          # bytes/s weight streaming from PVC
COMPILE_S = 25.0             # program load+compile on activation
WARM_ACTIVATE_S = 1.5        # warm pool -> active
MFU_PREFILL = 0.45           # achievable prefill efficiency
MBU_DECODE = 0.60            # achievable decode memory-bandwidth util


def chip_seconds_usd(chip_seconds: float) -> float:
    """USD for metered chip-seconds at the on-demand rate — the pricing
    the live ledger (``repro.obs.cost``) and the simulator share."""
    return chip_seconds * USD_PER_CHIP_HOUR / 3600.0


@dataclass(frozen=True)
class InstanceCost:
    arch: str
    backend: str
    chips: int
    ttft_base_s: float         # prefill time for a reference 512-token prompt
    tokens_per_s: float        # decode throughput per replica (full batch)
    tokens_per_s_single: float # decode speed for a single stream
    cold_start_s: float        # scale-0 -> active
    warm_start_s: float        # warm -> active
    usd_per_s: float           # replica cost while active
    hbm_bytes: int


def instance_cost(cfg: ModelConfig, backend: BackendProfile,
                  ref_prompt: int = 512) -> InstanceCost:
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    bytes_total = 2 * n_total                     # bf16 weights
    chips = max(1, math.ceil(bytes_total * backend.mem_mult / (HBM_BYTES * 0.65)))
    # round to a power of two (mesh slice)
    chips = 1 << max(0, math.ceil(math.log2(chips)))

    # decode: memory-bound on active params (weights streamed per token)
    step_mem = 2 * n_active
    step_compute = 2 * n_active
    t_step = max(step_mem / (chips * HBM_BW * MBU_DECODE),
                 step_compute / (chips * PEAK_FLOPS * 0.5))
    tps_single = 1.0 / t_step
    # batched decode amortizes weight streaming; tps_mult captures the
    # backend's batching efficiency
    tokens_per_s = tps_single * backend.max_batch * 0.45 * backend.tps_mult

    ttft = (2 * n_active * ref_prompt) / (chips * PEAK_FLOPS * MFU_PREFILL)
    ttft *= backend.ttft_mult

    cold = bytes_total / (PVC_LOAD_BW * max(1, chips // 4)) + COMPILE_S
    usd_per_s = chips * USD_PER_CHIP_HOUR / 3600.0
    return InstanceCost(
        arch=cfg.name, backend=backend.name, chips=chips,
        ttft_base_s=ttft, tokens_per_s=tokens_per_s,
        tokens_per_s_single=tps_single, cold_start_s=cold,
        warm_start_s=WARM_ACTIVATE_S, usd_per_s=usd_per_s,
        hbm_bytes=int(bytes_total))


def predict_latency(ic: InstanceCost, prompt_tokens: int, out_tokens: int,
                    queue_s: float = 0.0, batch_share: float = 1.0) -> float:
    """End-to-end latency estimate for one request on an ACTIVE replica."""
    ttft = ic.ttft_base_s * max(1, prompt_tokens) / 512.0
    decode = out_tokens / max(ic.tokens_per_s_single * batch_share, 1e-6)
    return queue_s + ttft + decode


def predict_cost(ic: InstanceCost, latency_s: float,
                 batch_share: float = 1.0) -> float:
    """USD attributed to one request (replica cost / concurrent batch)."""
    return ic.usd_per_s * latency_s * batch_share
