"""The paper's multi-objective orchestration score (Eq. 1–2).

    f(p, S_xy) = w_R * R_hat(p, L_x) + w_T * T_hat(S_xy) + w_C * C_hat(S_xy)

with R_hat/T_hat/C_hat normalized into [0, 1] (min–max over historical
system statistics) and (w_R, w_T, w_C) the normalized operator preference
weights. f is a convex combination, so f in [0, 1] by construction — the
property tests assert exactly this invariant.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence


@dataclass(frozen=True)
class OperatorProfile:
    """Non-negative preference parameters (alpha, lambda, mu) — paper §3."""
    name: str
    alpha: float     # relevance / quality
    lam: float       # latency
    mu: float        # cost

    @property
    def weights(self):
        s = self.alpha + self.lam + self.mu
        return (self.alpha / s, self.lam / s, self.mu / s)


# Paper's four operator profiles (grid-searched on 3,000 validation prompts)
PROFILES: Dict[str, OperatorProfile] = {
    "quality":  OperatorProfile("quality",  1.0, 0.1, 0.1),
    "cost":     OperatorProfile("cost",     0.3, 0.2, 0.8),
    "speed":    OperatorProfile("speed",    0.3, 0.8, 0.2),
    "balanced": OperatorProfile("balanced", 0.5, 0.3, 0.3),
}
# the paper's five inference strategies = baseline + the four profiles
STRATEGIES = ("baseline", "quality", "cost", "speed", "balanced")


class MinMaxNormalizer:
    """Distributional min–max normalization over historical statistics.

    norm(v) maps into [0, 1]; T_hat and C_hat are 1 - norm(.) so that
    HIGHER is BETTER for every component (paper Eq. block after Eq. 1).
    Bounds update online from telemetry; a widening margin guards against
    early-history collapse (min == max)."""

    def __init__(self, lo: float = 0.0, hi: float = 1.0):
        self.lo = lo
        self.hi = hi

    def update(self, value: float) -> None:
        self.lo = min(self.lo, value)
        self.hi = max(self.hi, value)

    def update_many(self, values: Sequence[float]) -> None:
        for v in values:
            self.update(v)

    def norm(self, value: float) -> float:
        span = self.hi - self.lo
        if span <= 0:
            return 0.0
        return min(1.0, max(0.0, (value - self.lo) / span))


def orchestration_score(
    relevance: float,          # R_hat(p, L_x) in [0,1]
    latency_s: float,          # predicted latency for S_xy
    cost_usd: float,           # predicted cost for S_xy
    profile: OperatorProfile,
    t_norm: MinMaxNormalizer,
    c_norm: MinMaxNormalizer,
) -> float:
    w_r, w_t, w_c = profile.weights
    t_hat = 1.0 - t_norm.norm(latency_s)
    c_hat = 1.0 - c_norm.norm(cost_usd)
    f = w_r * relevance + w_t * t_hat + w_c * c_hat
    assert -1e-9 <= f <= 1 + 1e-9, f
    return float(min(1.0, max(0.0, f)))


def routing_efficiency(acc_routed: float, acc_base: float,
                       cost_routed: float, cost_base: float) -> float:
    """Paper Eq. 9: eta = (A_r/A_b) / (C_r/C_b)."""
    return (acc_routed / acc_base) / (cost_routed / cost_base)
