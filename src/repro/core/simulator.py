"""Discrete-event cluster simulator for Pick-and-Spin.

Runs the REAL control-plane code — routers (core/router.py), Algorithm 2
policies (core/policies.py), Algorithm 1 orchestrator (core/orchestrator.py),
telemetry — against a physics-grounded data plane (core/costmodel.py), so
the paper's cluster-scale experiments (31k prompts, scale-to-zero dynamics,
cold starts, 10->1000 qps sweeps) are reproducible on this CPU-only box.
The data-plane numbers for small archs are cross-checked against the real
in-process engine (tests/test_gateway.py).

Event kinds: arrival | finish | tick (Alg. 1 control loop) | scale_ready.

Success semantics follow the paper: "success indicates valid completion
within time and token limits, measuring inference reliability rather than
task correctness" — a request succeeds iff it finishes before its deadline
AND its completion is valid, with validity probability

    p = clip(base * (0.215 + cap(tier_m, tier_p))
                  / (0.215 + cap(medium, tier_p)), .02, .995)

base = the benchmark's Table-1 baseline success rate. The modifier is
normalized so a MEDIUM-tier model reproduces Table 1 exactly (the paper's
baseline was its default single-model deployment); smaller models lose on
hard prompts, larger models gain — see core/router.CAPABILITY.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.costmodel import predict_latency
from repro.core.orchestrator import Orchestrator, SpinConfig
from repro.core.policies import SelectionPolicy
from repro.core.registry import ServiceEntry, ServiceRegistry
from repro.core.router import CAPABILITY, RouteDecision
from repro.core.scoring import OperatorProfile
from repro.core.telemetry import Telemetry
from repro.data.benchmarks import Prompt
from repro.serving.backend import BACKENDS


@dataclass
class SimRequest:
    rid: int
    prompt: Prompt
    decision: RouteDecision
    arrival: float
    deadline_s: float
    entry: Optional[ServiceEntry] = None
    start: float = 0.0
    ttft: float = 0.0
    finish: float = 0.0
    success: bool = False
    timed_out: bool = False
    cost_usd: float = 0.0
    pred_latency: float = 0.0


@dataclass
class SimReport:
    requests: List[SimRequest]
    duration_s: float
    total_chip_seconds: float
    busy_chip_seconds: float
    usd_total: float

    # -- headline metrics ---------------------------------------------------
    def success_rate(self) -> float:
        if not self.requests:
            return 0.0
        return float(np.mean([r.success for r in self.requests]))

    def latencies(self) -> np.ndarray:
        return np.asarray([r.finish - r.arrival for r in self.requests
                           if not r.timed_out] or [0.0])

    def ttfts(self) -> np.ndarray:
        return np.asarray([r.ttft - r.arrival for r in self.requests
                           if r.ttft > 0] or [0.0])

    def mean_latency(self) -> float:
        return float(self.latencies().mean())

    def median_ttft(self) -> float:
        return float(np.median(self.ttfts()))

    def ttft_percentiles(self) -> Dict[str, float]:
        t = self.ttfts()
        return {"p50": float(np.percentile(t, 50)),
                "p95": float(np.percentile(t, 95)),
                "p99": float(np.percentile(t, 99))}

    def cost_per_query(self) -> float:
        """Deployment-level: total cluster spend / queries (Table 4)."""
        if not self.requests:
            return 0.0
        return self.usd_total / len(self.requests)

    def attributed_cost_per_query(self) -> float:
        """Per-request attributed spend (replica cost shared across its
        concurrent batch) — the Table-3 'Cost (USD)' semantics: what did
        THIS query consume, independent of idle allocation."""
        if not self.requests:
            return 0.0
        return float(np.mean([r.cost_usd for r in self.requests]))

    def steady_state(self, warmup_frac: float = 0.25) -> "SimReport":
        """View excluding the first arrivals (cold-start warmup)."""
        reqs = sorted(self.requests, key=lambda r: r.arrival)
        cut = int(len(reqs) * warmup_frac)
        return SimReport(requests=reqs[cut:], duration_s=self.duration_s,
                         total_chip_seconds=self.total_chip_seconds,
                         busy_chip_seconds=self.busy_chip_seconds,
                         usd_total=self.usd_total)

    def utilization(self) -> float:
        if self.total_chip_seconds <= 0:
            return 0.0
        return min(1.0, self.busy_chip_seconds / self.total_chip_seconds)

    def throughput(self) -> float:
        done = [r for r in self.requests if r.finish > 0]
        if not done or self.duration_s <= 0:
            return 0.0
        return len(done) / self.duration_s

    def summary(self) -> Dict[str, float]:
        return {
            "n": len(self.requests),
            "success_rate": self.success_rate(),
            "mean_latency_s": self.mean_latency(),
            "median_ttft_s": self.median_ttft(),
            **{f"ttft_{k}": v for k, v in self.ttft_percentiles().items()},
            "cost_per_query_usd": self.cost_per_query(),
            "attr_cost_per_query_usd": self.attributed_cost_per_query(),
            "gpu_utilization": self.utilization(),
            "throughput_rps": self.throughput(),
            "usd_total": self.usd_total,
        }


@dataclass
class SimConfig:
    deadline_s: float = 240.0
    seed: int = 0
    static: bool = False            # static deployment: fixed replicas, no Spin
    static_replicas: int = 1
    spin: SpinConfig = field(default_factory=SpinConfig)
    failure_detect_s: float = 10.0  # static-deployment fault detection


class ClusterSimulator:
    def __init__(self, registry: ServiceRegistry, policy: SelectionPolicy,
                 profile: OperatorProfile, cfg: SimConfig = SimConfig()):
        self.reg = registry
        self.policy = policy
        self.profile = profile
        self.cfg = cfg
        self.tel = Telemetry(cfg.spin.window_s)
        self.rng = np.random.RandomState(cfg.seed)
        self._events: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._queues: Dict[Tuple[str, str], List[SimRequest]] = {
            k: [] for k in registry.matrix}
        self._pending_scale: Dict[Tuple[str, str], int] = {}
        self.busy_chip_seconds = 0.0
        self.orch: Optional[Orchestrator] = None
        if not cfg.static:
            self.orch = Orchestrator(registry, self.tel, cfg.spin,
                                     scale_cb=self._apply_scale)
        else:
            for e in registry.entries():
                e.replicas = cfg.static_replicas
                e.last_change_t = 0.0

    # -- event plumbing ------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        self._seq += 1
        heapq.heappush(self._events, (t, self._seq, kind, payload))

    # -- scaling --------------------------------------------------------
    def _apply_scale(self, model: str, backend: str, replicas: int,
                     now: float) -> None:
        e = self.reg.entry(model, backend)
        e.accrue(now)
        if replicas > e.replicas:
            delay = e.cost.warm_start_s if e.warm > 0 else e.cost.cold_start_s
            self._pending_scale[(model, backend)] = replicas
            self._push(now + delay, "scale_ready", (model, backend))
        else:
            # scale down is immediate; keep warm pool if configured
            tier_warm = self.cfg.spin.warm_pool.get(e.tier, 0)
            e.warm = max(e.warm, min(tier_warm, e.replicas - replicas))
            e.replicas = replicas

    def _on_scale_ready(self, key: Tuple[str, str], now: float) -> None:
        e = self.reg.entry(*key)
        e.accrue(now)
        target = self._pending_scale.pop(key, None)
        if target is not None and target > e.replicas:
            e.replicas = target
            e.warm = max(0, e.warm - target)
        while self._queues[key] and e.has_capacity():
            self._start(self._queues[key].pop(0), e, now)
            e.queued = max(0, e.queued - 1)

    # -- request lifecycle -----------------------------------------------
    def _start(self, req: SimRequest, e: ServiceEntry, now: float) -> None:
        e.active_requests += 1
        req.entry = e
        req.start = now
        plen = max(8, len(req.prompt.text) // 4)
        nb = max(1, min(e.active_requests, BACKENDS[e.backend].max_batch))
        # memory-bound decode: weight streaming dominates, so per-stream
        # speed degrades only mildly with batch (continuous batching);
        # replica cost is SHARED across the concurrent streams.
        batch_penalty = 1.0 + 0.25 * (nb - 1) / BACKENDS[e.backend].max_batch
        cost_share = 1.0 / nb
        ttft = e.cost.ttft_base_s * plen / 512.0 + req.decision.overhead_s
        decode_s = (req.prompt.out_tokens * batch_penalty
                    / max(e.cost.tokens_per_s_single, 1e-9))
        req.ttft = now + ttft
        req.finish = now + ttft + decode_s
        req.cost_usd = e.cost.usd_per_s * (ttft + decode_s) * cost_share
        self.busy_chip_seconds += e.cost.chips * (ttft + decode_s) * cost_share
        self._push(req.finish, "finish", req)

    def _on_finish(self, req: SimRequest, now: float) -> None:
        self._outstanding = max(0, getattr(self, "_outstanding", 1) - 1)
        e = req.entry
        e.active_requests = max(0, e.active_requests - 1)
        lat = now - req.arrival
        req.timed_out = lat > req.deadline_s
        cap = CAPABILITY[e.tier][req.prompt.complexity]
        cap_med = CAPABILITY["medium"][req.prompt.complexity]
        p_valid = float(np.clip(
            req.prompt.base_success * (0.215 + cap) / (0.215 + cap_med),
            0.02, 0.995))
        req.success = (not req.timed_out) and (self.rng.rand() < p_valid)
        if hasattr(self.policy, "feedback"):
            # closed-loop reward for learning policies (core/bandit.py)
            self.policy.feedback(req.decision.tier, e.tier, req.success)
        self.tel.record_latency(e.model, now, lat)
        key = (e.model, e.backend)
        while self._queues[key] and e.has_capacity():
            self._start(self._queues[key].pop(0), e, now)
            e.queued = max(0, e.queued - 1)

    def _on_arrival(self, req: SimRequest, now: float) -> None:
        plen = max(8, len(req.prompt.text) // 4)
        sel = self.policy.select(req.decision, plen, req.prompt.out_tokens,
                                 self.profile)
        e = sel.entry
        req.pred_latency = sel.pred_latency
        self.tel.record_request(e.model, now)
        if e.has_capacity():
            self._start(req, e, now)
        else:
            self._queues[(e.model, e.backend)].append(req)
            e.queued += 1
            # a queued request on a scaled-to-zero service waits for the
            # control loop; nothing to do here (Alg. 1 sees the telemetry)

    # -- main loop -------------------------------------------------------
    def run(self, workload: List[Tuple[float, Prompt, RouteDecision]]
            ) -> SimReport:
        reqs: List[SimRequest] = []
        self._outstanding = len(workload)
        for i, (t, p, d) in enumerate(workload):
            r = SimRequest(rid=i, prompt=p, decision=d, arrival=t,
                           deadline_s=self.cfg.deadline_s)
            reqs.append(r)
            self._push(t, "arrival", r)
        horizon = max(t for t, _, _ in workload) + 1.0 if workload else 0.0
        if self.orch:
            tt = 0.0
            while tt < horizon + 600.0:
                self._push(tt, "tick")
                tt += self.cfg.spin.tick_s

        end = 0.0
        while self._events:
            t, _, kind, payload = heapq.heappop(self._events)
            end = max(end, t)
            if kind == "arrival":
                self._on_arrival(payload, t)
            elif kind == "finish":
                self._on_finish(payload, t)
            elif kind == "scale_ready":
                self._on_scale_ready(payload, t)
            elif kind == "tick":
                if self.orch and self._outstanding > 0:
                    self.orch.tick(t)
                # unstick queues whose services got capacity meanwhile
                for key, q in self._queues.items():
                    e = self.reg.entry(*key)
                    while q and e.has_capacity():
                        self._start(q.pop(0), e, t)
                        e.queued = max(0, e.queued - 1)
        # expire anything still queued
        for q in self._queues.values():
            for r in q:
                r.timed_out = True
                r.finish = r.arrival + r.deadline_s
                self._outstanding = max(0, self._outstanding - 1)

        # duration = end of actual serving (idle control ticks continue past
        # the workload and must not dilute throughput/cost-per-query)
        serve_end = max((r.finish for r in reqs if r.finish > 0),
                        default=end)
        total_cs = self.reg.total_chip_seconds(serve_end)
        usd = sum(e.chip_seconds for e in self.reg.entries()) / 3600.0 * 1.2
        return SimReport(requests=reqs, duration_s=serve_end,
                         total_chip_seconds=total_cs,
                         busy_chip_seconds=self.busy_chip_seconds,
                         usd_total=usd)


def poisson_arrivals(prompts: List[Prompt], rate_per_s: float, seed: int = 0
                     ) -> List[Tuple[float, Prompt]]:
    rng = np.random.RandomState(seed)
    t = 0.0
    out = []
    for p in prompts:
        t += rng.exponential(1.0 / rate_per_s)
        out.append((t, p))
    return out
