"""The Pick-and-Spin gateway — one serving API over the real serve plane.

``ServeFrontend`` is the single entry point (serving API v2): every
request — from the synchronous ``Gateway`` facade, the open-loop driver,
launchers, examples and benchmarks — takes the SAME path:

    CompletionRequest -> Router -> Algorithm-2 policy -> priority-ordered
    bounded admission queue (RequestScheduler) -> ReplicaPool of real
    engines, with Algorithm 1 (``Orchestrator.tick``) running inline
    against LIVE telemetry.

``submit()`` returns a ``CompletionHandle`` immediately: ``.result()``
drives the serve loop to completion, ``.tokens()`` streams one event per
decode iteration, ``.cancel()`` aborts queued or mid-decode work (slot +
KV blocks freed the same call). Shed requests resolve with a structured
``finish_reason == "shed"`` — never ``None``. Requests carrying a
``session_id`` chain multi-turn: the frontend prepends the session's
token history, which is exactly the prefix the paged engines' radix
cache holds, so turn N+1 prefills only its new suffix.

Model "spin-up" here is genuinely expensive (param init/load + XLA
compile), so cold starts, warm pools and scale-to-zero are measured, not
modeled — each response's ``usage.cold_start_s`` carries the spin time
the request actually waited on. This is the calibration source for the
simulator's constants on small archs, and the end-to-end serving
substrate.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.api import (CompletionHandle, CompletionRequest,
                       CompletionResponse, FinishReason, Priority, Usage)
from repro.configs.base import ModelConfig
from repro.core.orchestrator import Orchestrator, SpinConfig
from repro.core.policies import MultiObjectivePolicy, SelectionPolicy
from repro.core.registry import ServiceRegistry
from repro.core.router import KeywordRouter
from repro.core.scoring import PROFILES, OperatorProfile
from repro.core.telemetry import Telemetry
from repro.data.tokenizer import ByteTokenizer
from repro.obs import Observability
from repro.serving import (GenResult, ReplicaPool, Request, RequestScheduler,
                           SamplingParams, SchedulerConfig, SpecConfig)


@dataclass
class GatewayConfig:
    """The ONE construction recipe for a serve plane. Both ``Gateway``
    and ``ServeFrontend`` build from it, so there is a single
    registry/policy/router setup path.

    ``models`` are what EXECUTES (reduced on CPU); ``cost_configs``
    (default: the full assigned configs with the same names) drive the
    registry's production cost model, so tier economics — the reason
    Pick exists — stay realistic even when stand-in models serve."""
    models: Dict[str, ModelConfig]
    router: object = None                      # default: KeywordRouter()
    policy_cls: type = MultiObjectivePolicy
    profile: OperatorProfile = PROFILES["balanced"]
    backends: Tuple[str, ...] = ("trt",)
    max_seq: int = 256
    seed: int = 0
    cost_configs: Optional[Dict[str, ModelConfig]] = None
    spin: Optional[SpinConfig] = None
    sched: Optional[SchedulerConfig] = None
    paged: object = "auto"
    # continuous batching: each engine step spends `step_token_budget`
    # tokens — one per in-flight decode first, the rest on prefill
    # chunks of at most `chunk_tokens` — so a long prompt amortizes
    # across steps instead of stalling every in-flight decode.
    # chunk_tokens=None restores whole-prompt prefill (the bench
    # baseline); budget=None leaves the step unbounded.
    chunk_tokens: Optional[int] = 64
    step_token_budget: Optional[int] = 256
    # opt-in decode burst: with no prefill backlog pending, one engine
    # step runs K fused decode iterations in a single device dispatch
    # (the offline/throughput path). 1 keeps stepwise decoding — the
    # right default for an interactive serve plane, where bursts delay
    # admission of freshly arrived prompts by up to K-1 decode tokens.
    decode_burst: int = 1
    # speculative decoding: registry arch that drafts spec_k tokens per
    # verify on every engine whose target it can co-reside with (vocab
    # match + KV headroom; others fall back to plain fused stepwise).
    # None keeps spec off pool-wide.
    spec_draft: Optional[str] = None
    spec_k: int = 4
    autoscale: bool = True                     # run Algorithm 1 inline
    # observability plane: metrics registry + request tracing + event
    # log, shared by the scheduler, the pool and every spun engine. All
    # hooks are host-side bookkeeping on code paths that already ran —
    # zero new device->host syncs (the PR-5 transfer-guard contract
    # holds with metrics on), so the default is on.
    metrics: bool = True
    # anomaly flight recorder JSONL sink: when set, automatic dumps
    # (shed storm / expiry burst / engine exception) and on-demand
    # ``obs.flight.dump()`` calls append there. None keeps the ring
    # in-memory only.
    flight_record: Optional[str] = None
    result_retention: int = 256                # bounded finished-result buffer
    session_retention: int = 1024              # LRU bound on live sessions
    # fault tolerance: a seeded ``FaultPlan`` (serving.faults) injected
    # into every spun replica; the circuit-breaker threshold before a
    # failing replica is quarantined; and how long a draining replica
    # may run out its in-flight work before forced evacuation
    faults: Optional[object] = None            # serving.faults.FaultPlan
    quarantine_after: int = 2
    drain_deadline_s: float = 30.0

    def resolved_cost_configs(self) -> Dict[str, ModelConfig]:
        from repro.configs.registry import ARCHS as _FULL
        return self.cost_configs or {
            name: _FULL.get(name.replace("-smoke", ""), cfg)
            for name, cfg in self.models.items()}


@dataclass
class OrchEvent:
    """An Algorithm-1 decision applied to live engines."""
    t: float
    model: str
    before: int          # replicas before the tick
    target: int          # replica target the orchestrator issued

    @property
    def kind(self) -> str:
        if self.target == 0:
            return "scale-to-zero"
        if self.target > self.before:
            return "scale-up"
        return "hold" if self.target == self.before else "scale-down"

    def __str__(self) -> str:
        return (f"[tick] {self.kind:>13s} {self.model} "
                f"{self.before}->{self.target}")


@dataclass
class _Session:
    """Multi-turn chain: service pinned on the first turn (history
    tokens only mean something to one model), token history grown on
    each completed turn. Turn N+1's prompt = history + new text, which
    is the prefix the radix cache registered when turn N finished.

    Turns are sequential by contract (submit turn N+1 after turn N
    resolves). An overlapping turn is still served, but it neither sees
    nor overwrites history it wasn't built on — the ``turns`` counter
    guards the chain against clobbering."""
    model: str
    backend: str
    tier: str
    tokens: List[int] = field(default_factory=list)
    turns: int = 0


@dataclass
class _Inflight:
    request: CompletionRequest
    ereq: Request
    model: str
    backend: str
    tier: str
    cold_mark: int       # len(pool.cold_starts) at submit, for attribution
    turn: int = -1       # session turn counter at submit (-1: no session)


class ServeFrontend:
    """Serving API v2 frontend: typed submit -> handle, step-driven serve
    loop, streaming deltas, cancellation, sessions, priorities."""

    def __init__(self, models_or_config: Union[GatewayConfig,
                                               Dict[str, ModelConfig]],
                 **kw):
        cfg = (models_or_config if isinstance(models_or_config, GatewayConfig)
               else GatewayConfig(models=models_or_config, **kw))
        self.config = cfg
        self.models = cfg.models
        self.router = cfg.router or KeywordRouter()
        self.registry = ServiceRegistry(cfg.resolved_cost_configs(),
                                        cfg.backends)
        # scale-from-zero on route: cold start priced into the prediction
        self.policy: SelectionPolicy = cfg.policy_cls(
            self.registry, cfg.seed, require_capacity=False)
        self.profile = cfg.profile
        self.obs = Observability() if cfg.metrics else None
        if self.obs is not None and cfg.flight_record:
            self.obs.flight.config.path = cfg.flight_record
        self.telemetry = Telemetry(
            registry=self.obs.registry if self.obs is not None else None)
        self.tok = ByteTokenizer()
        self.max_seq = cfg.max_seq
        self.spin = cfg.spin or SpinConfig()
        self.pool = ReplicaPool(cfg.models, self.registry, max_seq=cfg.max_seq,
                                seed=cfg.seed, paged=cfg.paged,
                                chunk_tokens=cfg.chunk_tokens,
                                step_token_budget=cfg.step_token_budget,
                                decode_burst=cfg.decode_burst, obs=self.obs,
                                spec=(SpecConfig(cfg.spec_draft, cfg.spec_k)
                                      if cfg.spec_draft else None),
                                faults=cfg.faults,
                                quarantine_after=cfg.quarantine_after,
                                drain_deadline_s=cfg.drain_deadline_s)
        self.scheduler = RequestScheduler(self.pool, self.registry,
                                          self.telemetry, cfg.sched,
                                          obs=self.obs)
        self.orch = Orchestrator(self.registry, self.telemetry, self.spin,
                                 scale_cb=self.pool.scale,
                                 repair_cb=self.pool.replace_quarantined)
        self.orch_events: List[OrchEvent] = []
        self._next_tick = 0.0
        self._uid = 0
        self._inflight: Dict[int, _Inflight] = {}
        self._handles: Dict[int, CompletionHandle] = {}
        # bounded retention of finished responses (a serve plane driven
        # via serve_all()/step() without claiming handles must not grow
        # without bound) — drain() hands them over explicitly
        self._recent: "OrderedDict[int, CompletionResponse]" = OrderedDict()
        # LRU-bounded: one-shot conversations with unique ids must not
        # accumulate forever on a long-running plane (end_session() is
        # the explicit path; the bound is the backstop)
        self._sessions: "OrderedDict[str, _Session]" = OrderedDict()

    @property
    def cold_starts(self) -> List[Tuple[str, float]]:
        return self.pool.cold_starts

    # -- request path ("Pick" -> enqueue) ------------------------------------
    def submit(self, request: Union[CompletionRequest, str], *,
               max_new_tokens: int = 16, deadline_s: Optional[float] = None,
               priority: Priority = Priority.NORMAL,
               session_id: Optional[str] = None,
               sampling: Optional[SamplingParams] = None) -> CompletionHandle:
        """Route + select + enqueue. ALWAYS returns a handle: a shed
        request's handle is already resolved with ``finish_reason ==
        "shed"`` (structured backpressure, not ``None``)."""
        if not isinstance(request, CompletionRequest):
            request = CompletionRequest(
                prompt=request, max_new_tokens=max_new_tokens,
                deadline_s=deadline_s, priority=priority,
                session_id=session_id, sampling=sampling)
        now = time.perf_counter()
        prompt_tokens = self.tok.encode(request.prompt)
        sess = (self._sessions.get(request.session_id)
                if request.session_id else None)
        if sess is None:
            decision = self.router.route(request.prompt)
            sel = self.policy.select(decision, len(prompt_tokens),
                                     request.max_new_tokens, self.profile)
            model, backend = sel.entry.model, sel.entry.backend
            tier = sel.entry.tier
            if request.session_id:      # pin the service for later turns
                sess = _Session(model, backend, tier)
                self._sessions[request.session_id] = sess
                self._bound_sessions()
        else:
            model, backend, tier = sess.model, sess.backend, sess.tier
            self._sessions.move_to_end(request.session_id)
        self.telemetry.record_request(model, now)
        cfg = self.models[model]
        tokens = [t % cfg.vocab_size for t in prompt_tokens]
        if sess is not None:
            tokens = sess.tokens + tokens
        uid = self._uid
        self._uid += 1
        if self.obs is not None:
            self.obs.tracer.on_submit(uid, model, backend, now)
        ereq = Request(uid=uid, arrival_t=now, tokens=tokens,
                       sampling=request.sampling or
                       SamplingParams(max_new_tokens=request.max_new_tokens),
                       deadline_s=request.deadline_s,
                       priority=int(request.priority))
        handle = CompletionHandle(self, uid, request, model=model,
                                  backend=backend, tier=tier)
        info = _Inflight(request, ereq, model, backend, tier,
                         cold_mark=len(self.pool.cold_starts),
                         turn=sess.turns if sess is not None else -1)
        if not self.scheduler.enqueue(model, backend, ereq, now):
            res = GenResult(uid=uid, prompt_len=len(tokens), shed=True)
            handle._resolve(self._make_response(info, res))
            self._remember(handle.response)
            return handle
        self._inflight[uid] = info
        self._handles[uid] = handle
        return handle

    # -- serve loop -----------------------------------------------------
    def step(self) -> List[CompletionResponse]:
        """One serve-loop iteration: Algorithm-1 tick when due, one
        scheduling + decode pass over the pool, streaming deltas pushed
        to their handles. Returns newly finished responses."""
        now = time.perf_counter()
        # replace quarantined replicas at STEP cadence, not tick cadence:
        # a substitute owed between widely spaced Algorithm-1 ticks (or
        # with autoscale off) must not wait for one. Idempotent with the
        # tick's own repair path.
        self.pool.replace_quarantined(now)
        if self.config.autoscale and now >= self._next_tick:
            before = {m: self.registry.model_replicas(m)
                      for m in self.registry.models}
            for m, target in self.orch.tick(now).items():
                ev = OrchEvent(now, m, before[m], target)
                self.orch_events.append(ev)
                if self.obs is not None:
                    self.obs.events.append("orch", t=now, model=m,
                                           before=ev.before,
                                           target=ev.target, kind=ev.kind)
            self._next_tick = now + self.spin.tick_s
        finished = self.scheduler.step(now)
        for uid, token in self.scheduler.drain_deltas():
            h = self._handles.get(uid)
            if h is not None:            # warm-up probes have no handle
                h._push_token(token)
        out: List[CompletionResponse] = []
        for _key, res in finished:
            resp = self._finish(res)
            if resp is not None:
                out.append(resp)
        return out

    def cancel(self, uid: int) -> bool:
        """Abort ``uid`` wherever it is (queue or mid-decode). The handle
        resolves immediately with ``finish_reason == "cancelled"`` and
        the engine's slot + KV blocks are freed. False if unknown or
        already finished."""
        info = self._inflight.get(uid)
        if info is None:
            return False
        res = self.scheduler.cancel(info.model, info.backend, uid)
        if res is None:
            return False
        self._finish(res)
        return True

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def serve_all(self, max_steps: int = 1_000_000
                  ) -> List[CompletionResponse]:
        """Synchronous driver: run the serve loop until all queues drain."""
        out: List[CompletionResponse] = []
        steps = 0
        while self.has_work() and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    def drain(self) -> List[CompletionResponse]:
        """Hand over (and clear) the bounded buffer of finished
        responses — the explicit bulk-results surface for drivers that
        don't keep per-request handles."""
        out = list(self._recent.values())
        self._recent.clear()
        return out

    def serve_open_loop(self, requests: Sequence[CompletionRequest],
                        arrivals: Sequence[float]
                        ) -> Tuple[List[CompletionHandle], float]:
        """Open-loop driver: submit ``requests[i]`` at offset
        ``arrivals[i]`` (seconds, sorted) regardless of completions —
        arrivals do not wait for the system, so overload shows up as
        queueing/shedding, not as a slower workload. Drives the serve
        loop continuously in between. Returns (handles, wall_s); every
        handle is resolved on return (shed ones with
        ``finish_reason == "shed"``)."""
        t0 = time.perf_counter()
        handles: List[CompletionHandle] = []
        i, n = 0, len(requests)
        while i < n or self.has_work():
            now = time.perf_counter() - t0
            while i < n and arrivals[i] <= now:
                handles.append(self.submit(requests[i]))
                i += 1
            self.step()
            if not self.has_work() and i < n:
                time.sleep(max(0.0, min(0.005, arrivals[i] - now)))
        return handles, time.perf_counter() - t0

    def settle(self, timeout_s: float = 5.0, poll_s: float = 0.02) -> bool:
        """Idle the serve loop so Spin's idle branch can fire (scale-to-
        zero / warm floors). True once no replicas above the configured
        warm floors remain live."""
        floor = self._floor_replicas()
        t_end = time.perf_counter() + timeout_s
        while time.perf_counter() < t_end:
            self.step()
            if self.pool.total_replicas() <= floor:
                return True
            time.sleep(poll_s)
        return self.pool.total_replicas() <= floor

    def end_session(self, session_id: str) -> bool:
        """Drop a session's history explicitly (its cached KV blocks age
        out of the radix cache on their own). True if it existed."""
        return self._sessions.pop(session_id, None) is not None

    # -- internals -------------------------------------------------------
    def _finish(self, res: GenResult) -> Optional[CompletionResponse]:
        info = self._inflight.pop(res.uid, None)
        handle = self._handles.pop(res.uid, None)
        if info is None:                 # warm-up probe etc.
            return None
        resp = self._make_response(info, res)
        if info.request.session_id and resp.completed:
            sess = self._sessions.get(info.request.session_id)
            # turn guard: only a turn built on the CURRENT history may
            # extend it — an overlapping turn (submitted before the
            # previous one resolved) is served but never clobbers the
            # chain with history it didn't see
            if sess is not None and sess.turns == info.turn:
                sess.tokens = info.ereq.tokens + res.new_tokens
                sess.turns += 1
        if handle is not None:
            handle._resolve(resp)
        self._remember(resp)
        return resp

    def _make_response(self, info: _Inflight,
                       res: GenResult) -> CompletionResponse:
        if res.shed:
            reason = FinishReason.SHED
        elif res.cancelled:
            reason = FinishReason.CANCELLED
        elif res.failed:
            reason = FinishReason.FAILED
        elif res.timed_out:
            reason = FinishReason.TIMEOUT
        else:
            eos = info.ereq.sampling.eos_id
            reason = (FinishReason.STOP if res.completed and eos is not None
                      and res.new_tokens and res.new_tokens[-1] == eos
                      else FinishReason.LENGTH)
        # real measured spin time this request waited on: every cold/warm
        # start of ITS service logged between submit and finish
        svc = f"{info.model}/{info.backend}/"
        cold = sum(d for label, d in
                   self.pool.cold_starts[info.cold_mark:]
                   if label.startswith(svc))
        # every terminal resolution passes through here exactly once
        # (shed-at-submit included), so this is where the span closes
        span = (self.obs.tracer.on_finish(res.uid, time.perf_counter(),
                                          reason)
                if self.obs is not None else None)
        # settle the chip-second ledger: the request's attributed share
        # becomes its measured cost (None = it never shared a step)
        chip_s = cost_usd = 0.0
        if self.obs is not None:
            closed = self.obs.ledger.close_request(
                res.uid, info.model,
                t=span.finish_t if span else time.perf_counter())
            if closed is not None:
                chip_s, cost_usd = closed
            if span is not None:
                span.chip_seconds, span.cost_usd = chip_s, cost_usd
        usage = Usage(prompt_tokens=res.prompt_len,
                      cached_tokens=res.cached_tokens,
                      completion_tokens=len(res.new_tokens),
                      cold_start_s=cold,
                      prefill_chunks=res.prefill_chunks,
                      queue_wait_s=span.queue_wait_s if span else 0.0,
                      decode_s=span.decode_s if span else 0.0,
                      chip_seconds=chip_s, cost_usd=cost_usd,
                      kv_peak_bytes=res.kv_bytes,
                      drafted_tokens=res.drafted_tokens,
                      accepted_tokens=res.accepted_tokens,
                      retries=res.retries)
        return CompletionResponse(
            uid=res.uid, prompt=info.request.prompt, model=info.model,
            backend=info.backend, tier=info.tier,
            new_tokens=list(res.new_tokens), finish_reason=reason,
            completed=res.completed, ttft_s=res.ttft, latency_s=res.latency,
            usage=usage, session_id=info.request.session_id)

    def _remember(self, resp: CompletionResponse) -> None:
        self._recent[resp.uid] = resp
        while len(self._recent) > self.config.result_retention:
            self._recent.popitem(last=False)

    def _bound_sessions(self) -> None:
        while len(self._sessions) > self.config.session_retention:
            self._sessions.popitem(last=False)

    def _floor_replicas(self) -> int:
        """Total replicas Spin's idle branch would leave running."""
        total = 0
        for m in self.registry.models:
            tier = next(e.tier for e in self.registry.entries()
                        if e.model == m)
            floor = self.spin.warm_pool.get(tier, 0)
            if not self.spin.scale_to_zero:
                floor = max(1, floor)
            total += floor
        return total


class Gateway:
    """Thin SYNCHRONOUS facade over ``ServeFrontend`` — the serial
    baseline (one blocking request at a time) with zero construction
    logic of its own. ``handle()`` is ``submit().result()`` on the same
    concurrent plane everything else uses; Algorithm-1 autoscaling is
    off (the caller drives lifecycle explicitly via ``scale_to_zero``)."""

    def __init__(self, models: Dict[str, ModelConfig], router=None,
                 policy_cls=MultiObjectivePolicy,
                 profile: OperatorProfile = PROFILES["balanced"],
                 backends: Tuple[str, ...] = ("trt",),
                 max_seq: int = 256, seed: int = 0,
                 cost_configs: Optional[Dict[str, ModelConfig]] = None,
                 sched: Optional[SchedulerConfig] = None, paged="auto",
                 chunk_tokens: Optional[int] = 64,
                 step_token_budget: Optional[int] = 256,
                 decode_burst: int = 1, spec_draft: Optional[str] = None,
                 spec_k: int = 4):
        self.frontend = ServeFrontend(GatewayConfig(
            models=models, router=router, policy_cls=policy_cls,
            profile=profile, backends=backends, max_seq=max_seq, seed=seed,
            cost_configs=cost_configs, sched=sched, paged=paged,
            chunk_tokens=chunk_tokens, step_token_budget=step_token_budget,
            decode_burst=decode_burst, spec_draft=spec_draft, spec_k=spec_k,
            autoscale=False))

    # shared-plane passthroughs (no duplicated state)
    models = property(lambda self: self.frontend.models)
    router = property(lambda self: self.frontend.router)
    registry = property(lambda self: self.frontend.registry)
    policy = property(lambda self: self.frontend.policy)
    profile = property(lambda self: self.frontend.profile)
    telemetry = property(lambda self: self.frontend.telemetry)
    tok = property(lambda self: self.frontend.tok)
    max_seq = property(lambda self: self.frontend.max_seq)
    pool = property(lambda self: self.frontend.pool)
    scheduler = property(lambda self: self.frontend.scheduler)
    cold_starts = property(lambda self: self.frontend.cold_starts)
    obs = property(lambda self: self.frontend.obs)

    # -- request path ("Pick" -> serve) -------------------------------------
    def handle(self, text: str, max_new_tokens: int = 16,
               deadline_s: Optional[float] = None,
               priority: Priority = Priority.NORMAL,
               session_id: Optional[str] = None,
               sampling: Optional[SamplingParams] = None
               ) -> CompletionResponse:
        return self.frontend.submit(
            text, max_new_tokens=max_new_tokens, deadline_s=deadline_s,
            priority=priority, session_id=session_id,
            sampling=sampling).result()

    # -- lifecycle ("Spin", explicit on the serial facade) -------------------
    def scale_to_zero(self, model: str, backend: str,
                      keep_warm: bool = True) -> None:
        self.frontend.pool.scale(model, backend, 0)
        if not keep_warm:
            self.frontend.pool.evict(model)
