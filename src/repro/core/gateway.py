"""API Gateway — the real (non-simulated) Pick-and-Spin path.

Wires Router -> Registry -> Policy (Alg. 2) -> Orchestrator lifecycle ->
real ``InferenceEngine`` instances executing reduced models on this host.
Model "spin-up" here is genuinely expensive (param init/load + XLA compile),
so cold starts, warm pools and scale-to-zero are measured, not modeled —
this is the calibration source for the simulator's constants on small
archs, and the end-to-end serving example.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policies import MultiObjectivePolicy, SelectionPolicy
from repro.core.registry import ServiceRegistry
from repro.core.router import KeywordRouter, RouteDecision
from repro.core.scoring import PROFILES, OperatorProfile
from repro.core.telemetry import Telemetry
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_model
from repro.serving import (BACKENDS, InferenceEngine, Request,
                           SamplingParams)

import jax


@dataclass
class GatewayResult:
    text_prompt: str
    model: str
    backend: str
    tier: str
    new_tokens: List[int]
    ttft_s: float
    latency_s: float
    cold_start_s: float
    completed: bool


class Gateway:
    def __init__(self, models: Dict[str, ModelConfig], router=None,
                 policy_cls=MultiObjectivePolicy,
                 profile: OperatorProfile = PROFILES["balanced"],
                 backends: Tuple[str, ...] = ("trt",),
                 max_seq: int = 256, seed: int = 0,
                 cost_configs: Dict[str, ModelConfig] = None):
        """``models`` are what EXECUTES (reduced on CPU); ``cost_configs``
        (default: the full assigned configs with the same names) drive the
        registry's production cost model, so tier economics — the reason
        Pick exists — stay realistic even when stand-in models serve."""
        from repro.configs.registry import ARCHS as _FULL
        self.models = models
        self.router = router or KeywordRouter()
        cost_cfgs = cost_configs or {
            name: _FULL.get(name.replace("-smoke", ""), cfg)
            for name, cfg in models.items()}
        self.registry = ServiceRegistry(cost_cfgs, backends)
        # scale-from-zero on route: cold start priced into the prediction
        self.policy: SelectionPolicy = policy_cls(self.registry, seed,
                                                  require_capacity=False)
        self.profile = profile
        self.telemetry = Telemetry()
        self.max_seq = max_seq
        self.tok = ByteTokenizer()
        self._engines: Dict[Tuple[str, str], InferenceEngine] = {}
        self._params_cache: Dict[str, dict] = {}      # "warm" weights
        self.cold_starts: List[Tuple[str, float]] = []
        self._uid = 0

    # -- lifecycle ("Spin") ------------------------------------------------
    def _spin_up(self, model: str, backend: str) -> InferenceEngine:
        key = (model, backend)
        if key in self._engines:
            return self._engines[key]
        t0 = time.perf_counter()
        cfg = self.models[model]
        warm = model in self._params_cache
        if not warm:
            self._params_cache[model] = init_model(cfg, jax.random.PRNGKey(0))
        eng = InferenceEngine(cfg, self._params_cache[model],
                              BACKENDS[backend], max_seq=self.max_seq)
        # trigger compile (the dominant real cold-start cost)
        eng.run([Request(uid=-1, tokens=[1, 2, 3],
                         sampling=SamplingParams(max_new_tokens=2))])
        cold = time.perf_counter() - t0
        self.cold_starts.append((f"{model}/{backend}/"
                                 f"{'warm' if warm else 'cold'}", cold))
        self._engines[key] = eng
        self.registry.entry(model, backend).replicas = 1
        return eng

    def scale_to_zero(self, model: str, backend: str, keep_warm: bool = True
                      ) -> None:
        key = (model, backend)
        if key in self._engines:
            del self._engines[key]
            self.registry.entry(model, backend).replicas = 0
            if not keep_warm:
                self._params_cache.pop(model, None)

    # -- request path ("Pick" -> serve) -------------------------------------
    def handle(self, text: str, max_new_tokens: int = 16,
               deadline_s: Optional[float] = None) -> GatewayResult:
        t_arrive = time.perf_counter()
        decision = self.router.route(text)
        tokens = self.tok.encode(text)
        sel = self.policy.select(decision, len(tokens), max_new_tokens,
                                 self.profile)
        model, backend = sel.entry.model, sel.entry.backend
        self.telemetry.record_request(model, t_arrive)

        had_engine = (model, backend) in self._engines
        eng = self._spin_up(model, backend)
        cold = 0.0 if had_engine else self.cold_starts[-1][1]

        cfg = self.models[model]
        req = Request(uid=self._uid, arrival_t=t_arrive,
                      tokens=[t % cfg.vocab_size for t in tokens],
                      sampling=SamplingParams(max_new_tokens=max_new_tokens),
                      deadline_s=deadline_s)
        self._uid += 1
        res = eng.run([req])[0]
        self.telemetry.record_latency(model, time.perf_counter(), res.latency)
        return GatewayResult(
            text_prompt=text, model=model, backend=backend,
            tier=sel.entry.tier, new_tokens=res.new_tokens,
            ttft_s=res.ttft, latency_s=res.latency, cold_start_s=cold,
            completed=res.completed)
