"""API Gateways — the real (non-simulated) Pick-and-Spin paths.

Two planes over the same Pick machinery (Router -> Registry -> Policy):

  ``Gateway``      the serial baseline: one blocking request at a time,
                   each served to completion via ``eng.run([req])``.
  ``AsyncGateway`` the concurrent serve plane: ``submit()``/``poll()``
                   feed bounded per-service queues (RequestScheduler),
                   requests from many callers overlap inside replica
                   pools of real engines (iteration-level continuous
                   batching across the pool), and Algorithm 1
                   (``Orchestrator.tick``) runs inline against LIVE
                   telemetry — scale-up under load, scale-to-zero when
                   idle, warm-pool re-spins — on those real engines.

Model "spin-up" here is genuinely expensive (param init/load + XLA
compile), so cold starts, warm pools and scale-to-zero are measured, not
modeled — this is the calibration source for the simulator's constants
on small archs, and the end-to-end serving substrate.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.orchestrator import Orchestrator, SpinConfig
from repro.core.policies import MultiObjectivePolicy, SelectionPolicy
from repro.core.registry import ServiceRegistry
from repro.core.router import KeywordRouter, RouteDecision
from repro.core.scoring import PROFILES, OperatorProfile
from repro.core.telemetry import Telemetry
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_model
from repro.serving import (BACKENDS, InferenceEngine, ReplicaPool, Request,
                           RequestScheduler, SamplingParams, SchedulerConfig)

import jax


@dataclass
class GatewayResult:
    text_prompt: str
    model: str
    backend: str
    tier: str
    new_tokens: List[int]
    ttft_s: float
    latency_s: float
    cold_start_s: float
    completed: bool
    uid: int = -1


class Gateway:
    def __init__(self, models: Dict[str, ModelConfig], router=None,
                 policy_cls=MultiObjectivePolicy,
                 profile: OperatorProfile = PROFILES["balanced"],
                 backends: Tuple[str, ...] = ("trt",),
                 max_seq: int = 256, seed: int = 0,
                 cost_configs: Dict[str, ModelConfig] = None):
        """``models`` are what EXECUTES (reduced on CPU); ``cost_configs``
        (default: the full assigned configs with the same names) drive the
        registry's production cost model, so tier economics — the reason
        Pick exists — stay realistic even when stand-in models serve."""
        from repro.configs.registry import ARCHS as _FULL
        self.models = models
        self.router = router or KeywordRouter()
        cost_cfgs = cost_configs or {
            name: _FULL.get(name.replace("-smoke", ""), cfg)
            for name, cfg in models.items()}
        self.registry = ServiceRegistry(cost_cfgs, backends)
        # scale-from-zero on route: cold start priced into the prediction
        self.policy: SelectionPolicy = policy_cls(self.registry, seed,
                                                  require_capacity=False)
        self.profile = profile
        self.telemetry = Telemetry()
        self.max_seq = max_seq
        self.tok = ByteTokenizer()
        self._engines: Dict[Tuple[str, str], InferenceEngine] = {}
        self._params_cache: Dict[str, dict] = {}      # "warm" weights
        self.cold_starts: List[Tuple[str, float]] = []
        self._uid = 0

    # -- lifecycle ("Spin") ------------------------------------------------
    def _spin_up(self, model: str, backend: str) -> InferenceEngine:
        key = (model, backend)
        if key in self._engines:
            return self._engines[key]
        t0 = time.perf_counter()
        cfg = self.models[model]
        warm = model in self._params_cache
        if not warm:
            self._params_cache[model] = init_model(cfg, jax.random.PRNGKey(0))
        eng = InferenceEngine(cfg, self._params_cache[model],
                              BACKENDS[backend], max_seq=self.max_seq)
        # trigger compile (the dominant real cold-start cost)
        eng.run([Request(uid=-1, tokens=[1, 2, 3],
                         sampling=SamplingParams(max_new_tokens=2))])
        cold = time.perf_counter() - t0
        self.cold_starts.append((f"{model}/{backend}/"
                                 f"{'warm' if warm else 'cold'}", cold))
        self._engines[key] = eng
        self.registry.entry(model, backend).replicas = 1
        return eng

    def scale_to_zero(self, model: str, backend: str, keep_warm: bool = True
                      ) -> None:
        key = (model, backend)
        if key in self._engines:
            del self._engines[key]
            self.registry.entry(model, backend).replicas = 0
            if not keep_warm:
                self._params_cache.pop(model, None)

    # -- request path ("Pick" -> serve) -------------------------------------
    def handle(self, text: str, max_new_tokens: int = 16,
               deadline_s: Optional[float] = None) -> GatewayResult:
        t_arrive = time.perf_counter()
        decision = self.router.route(text)
        tokens = self.tok.encode(text)
        sel = self.policy.select(decision, len(tokens), max_new_tokens,
                                 self.profile)
        model, backend = sel.entry.model, sel.entry.backend
        self.telemetry.record_request(model, t_arrive)

        had_engine = (model, backend) in self._engines
        eng = self._spin_up(model, backend)
        cold = 0.0 if had_engine else self.cold_starts[-1][1]

        cfg = self.models[model]
        req = Request(uid=self._uid, arrival_t=t_arrive,
                      tokens=[t % cfg.vocab_size for t in tokens],
                      sampling=SamplingParams(max_new_tokens=max_new_tokens),
                      deadline_s=deadline_s)
        self._uid += 1
        res = eng.run([req])[0]
        self.telemetry.record_latency(model, time.perf_counter(), res.latency)
        return GatewayResult(
            text_prompt=text, model=model, backend=backend,
            tier=sel.entry.tier, new_tokens=res.new_tokens,
            ttft_s=res.ttft, latency_s=res.latency, cold_start_s=cold,
            completed=res.completed, uid=req.uid)


# ---------------------------------------------------------------------------
# concurrent serve plane


@dataclass
class OrchEvent:
    """An Algorithm-1 decision applied to live engines."""
    t: float
    model: str
    before: int          # replicas before the tick
    target: int          # replica target the orchestrator issued

    @property
    def kind(self) -> str:
        if self.target == 0:
            return "scale-to-zero"
        if self.target > self.before:
            return "scale-up"
        return "hold" if self.target == self.before else "scale-down"

    def __str__(self) -> str:
        return (f"[tick] {self.kind:>13s} {self.model} "
                f"{self.before}->{self.target}")


class AsyncGateway:
    """Concurrent serve plane: submit()/poll() + a step-driven serve loop.

    Request path: Router -> Algorithm-2 policy -> bounded admission queue
    (``RequestScheduler``) -> ``ReplicaPool`` of real engines. Each
    ``step()`` runs one decode iteration across EVERY engine with work
    (so in-flight requests genuinely overlap) and, every ``tick_s``, one
    pass of the Algorithm-1 control loop whose ``scale_cb`` spins real
    replicas up and down.
    """

    def __init__(self, models: Dict[str, ModelConfig], router=None,
                 policy_cls=MultiObjectivePolicy,
                 profile: OperatorProfile = PROFILES["balanced"],
                 backends: Tuple[str, ...] = ("trt",),
                 max_seq: int = 256, seed: int = 0,
                 cost_configs: Dict[str, ModelConfig] = None,
                 spin: Optional[SpinConfig] = None,
                 sched: Optional[SchedulerConfig] = None,
                 paged="auto"):
        from repro.configs.registry import ARCHS as _FULL
        self.models = models
        self.router = router or KeywordRouter()
        cost_cfgs = cost_configs or {
            name: _FULL.get(name.replace("-smoke", ""), cfg)
            for name, cfg in models.items()}
        self.registry = ServiceRegistry(cost_cfgs, backends)
        self.policy: SelectionPolicy = policy_cls(self.registry, seed,
                                                  require_capacity=False)
        self.profile = profile
        self.telemetry = Telemetry()
        self.tok = ByteTokenizer()
        self.max_seq = max_seq
        self.spin = spin or SpinConfig()
        self.pool = ReplicaPool(models, self.registry, max_seq=max_seq,
                                seed=seed, paged=paged)
        self.scheduler = RequestScheduler(self.pool, self.registry,
                                          self.telemetry, sched)
        self.orch = Orchestrator(self.registry, self.telemetry, self.spin,
                                 scale_cb=self.pool.scale)
        self.orch_events: List[OrchEvent] = []
        self._next_tick = 0.0
        self._uid = 0
        self._meta: Dict[int, Tuple[str, str, str, str]] = {}
        self._results: Dict[int, GatewayResult] = {}
        self.shed_uids: List[int] = []

    @property
    def cold_starts(self) -> List[Tuple[str, float]]:
        return self.pool.cold_starts

    # -- request path ("Pick" -> enqueue) ------------------------------------
    def submit(self, text: str, max_new_tokens: int = 16,
               deadline_s: Optional[float] = None,
               sampling: Optional[SamplingParams] = None) -> Optional[int]:
        """Route + select + enqueue. Returns the request uid, or None if
        the selected service's queue is full (request shed)."""
        now = time.perf_counter()
        decision = self.router.route(text)
        tokens = self.tok.encode(text)
        sel = self.policy.select(decision, len(tokens), max_new_tokens,
                                 self.profile)
        model, backend = sel.entry.model, sel.entry.backend
        self.telemetry.record_request(model, now)
        cfg = self.models[model]
        uid = self._uid
        self._uid += 1
        req = Request(uid=uid, arrival_t=now,
                      tokens=[t % cfg.vocab_size for t in tokens],
                      sampling=sampling or
                      SamplingParams(max_new_tokens=max_new_tokens),
                      deadline_s=deadline_s)
        if not self.scheduler.enqueue(model, backend, req, now):
            self.shed_uids.append(uid)
            return None
        self._meta[uid] = (text, model, backend, sel.entry.tier)
        return uid

    # -- serve loop -----------------------------------------------------
    def step(self) -> List[GatewayResult]:
        """One serve-loop iteration: Algorithm-1 tick when due, then one
        scheduling + decode pass over the pool. Returns newly finished."""
        now = time.perf_counter()
        if now >= self._next_tick:
            before = {m: self.registry.model_replicas(m)
                      for m in self.registry.models}
            for m, target in self.orch.tick(now).items():
                self.orch_events.append(OrchEvent(now, m, before[m], target))
            self._next_tick = now + self.spin.tick_s
        out: List[GatewayResult] = []
        for (model, backend), res in self.scheduler.step(now):
            meta = self._meta.pop(res.uid, None)
            if meta is None:                      # warm-up probe etc.
                continue
            text, m, b, tier = meta
            gr = GatewayResult(
                text_prompt=text, model=m, backend=b, tier=tier,
                new_tokens=res.new_tokens, ttft_s=res.ttft,
                latency_s=res.latency, cold_start_s=0.0,
                completed=res.completed, uid=res.uid)
            self._results[res.uid] = gr
            out.append(gr)
        return out

    def poll(self, uid: int) -> Optional[GatewayResult]:
        """Fetch-and-remove the finished result for ``uid`` (None if
        unknown or still in flight) — results don't accumulate forever
        on a long-running serve plane."""
        return self._results.pop(uid, None)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def serve_all(self, max_steps: int = 1_000_000) -> List[GatewayResult]:
        """Synchronous driver: run the serve loop until all queues drain."""
        out: List[GatewayResult] = []
        steps = 0
        while self.has_work() and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    def settle(self, timeout_s: float = 5.0, poll_s: float = 0.02) -> bool:
        """Idle the serve loop so Spin's idle branch can fire (scale-to-
        zero / warm floors). True once no replicas above the configured
        warm floors remain live."""
        floor = self._floor_replicas()
        t_end = time.perf_counter() + timeout_s
        while time.perf_counter() < t_end:
            self.step()
            if self.pool.total_replicas() <= floor:
                return True
            time.sleep(poll_s)
        return self.pool.total_replicas() <= floor

    def _floor_replicas(self) -> int:
        """Total replicas Spin's idle branch would leave running."""
        total = 0
        for m in self.registry.models:
            tier = next(e.tier for e in self.registry.entries()
                        if e.model == m)
            floor = self.spin.warm_pool.get(tier, 0)
            if not self.spin.scale_to_zero:
                floor = max(1, floor)
            total += floor
        return total


def serve_open_loop(gw: AsyncGateway,
                    jobs: Sequence[Tuple[str, dict]],
                    arrivals: Sequence[float]
                    ) -> Tuple[List[Optional[int]], float]:
    """Open-loop driver: submit ``jobs[i]`` at offset ``arrivals[i]``
    (seconds, sorted) regardless of completions — arrivals do not wait
    for the system, so overload shows up as queueing/shedding, not as a
    slower workload. Drives the serve loop continuously in between.
    Returns (uids, wall_s); ``uids[i]`` is None if job i was shed."""
    t0 = time.perf_counter()
    uids: List[Optional[int]] = []
    i, n = 0, len(jobs)
    while i < n or gw.has_work():
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            text, kw = jobs[i]
            uids.append(gw.submit(text, **kw))
            i += 1
        gw.step()
        if not gw.has_work() and i < n:
            time.sleep(max(0.0, min(0.005, arrivals[i] - now)))
    return uids, time.perf_counter() - t0
