"""Service Registry: the deployment matrix M in R^{L x I} (paper Eq. 5).

Rows are model families, columns are inference backends; each element is a
``ServiceEntry`` (cost model + live replica/health state). Both the
orchestrator (Alg. 1) and the selection policies (Alg. 2) read it; scale
actions write it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.configs.registry import MODEL_TIERS
from repro.core.costmodel import InstanceCost, instance_cost
from repro.serving.backend import BACKENDS, BackendProfile


@dataclass
class ServiceEntry:
    model: str
    backend: str
    tier: str                       # small | medium | large
    cost: InstanceCost
    replicas: int = 0               # active replicas
    warm: int = 0                   # warm (params resident, not serving)
    healthy: bool = True
    active_requests: int = 0
    queued: int = 0                 # waiting in this service's FIFO
    # bookkeeping for cost integration (chip-seconds)
    last_change_t: float = 0.0
    chip_seconds: float = 0.0

    @property
    def capacity(self) -> int:
        return self.replicas * BACKENDS[self.backend].max_batch

    def has_capacity(self) -> bool:
        return self.healthy and self.replicas > 0 and \
            self.active_requests < self.capacity

    def accrue(self, now: float) -> None:
        """Integrate chip-seconds up to ``now`` (warm pools bill too)."""
        dt = max(0.0, now - self.last_change_t)
        self.chip_seconds += dt * self.cost.chips * (self.replicas + self.warm)
        self.last_change_t = now


class ServiceRegistry:
    def __init__(self, models: Dict[str, ModelConfig],
                 backends: Optional[Iterable[str]] = None):
        self.models = models
        self.backends = list(backends or BACKENDS)
        self.matrix: Dict[Tuple[str, str], ServiceEntry] = {}
        for name, cfg in models.items():
            for b in self.backends:
                self.matrix[(name, b)] = ServiceEntry(
                    model=name, backend=b, tier=MODEL_TIERS[name],
                    cost=instance_cost(cfg, BACKENDS[b]))

    def entries(self) -> List[ServiceEntry]:
        return list(self.matrix.values())

    def entry(self, model: str, backend: str) -> ServiceEntry:
        return self.matrix[(model, backend)]

    def model_replicas(self, model: str) -> int:
        return sum(e.replicas for (m, _), e in self.matrix.items() if m == model)

    def model_active(self, model: str) -> int:
        """In-flight requests across the model's backends."""
        return sum(e.active_requests for (m, _), e in self.matrix.items()
                   if m == model)

    def model_queued(self, model: str) -> int:
        return sum(e.queued for (m, _), e in self.matrix.items()
                   if m == model)

    def by_tier(self, tier: str) -> List[ServiceEntry]:
        return [e for e in self.entries() if e.tier == tier]

    def total_chip_seconds(self, now: float) -> float:
        for e in self.entries():
            e.accrue(now)
        return sum(e.chip_seconds for e in self.entries())
