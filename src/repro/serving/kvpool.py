"""Paged KV-cache plane: block-pool allocator + radix prefix cache.

The dense engine pre-allocates a ``(max_batch, max_seq)`` KV cache per
replica, so memory — not compute — caps concurrency, and identical
prompt prefixes (multi-turn chat, shared system prompts) are re-prefilled
on every request. This module provides the bookkeeping half of the paged
alternative (vLLM-style paging + SGLang-style radix reuse):

  * ``BlockPool`` — a fixed population of ``block_size``-token KV blocks
    with refcounts. Requests lease blocks; sharing is a refcount bump,
    not a copy. The actual KV tensors live in the engine's pool arrays
    (``models.transformer.init_paged_cache``); block ids index them.
  * ``RadixPrefixCache`` — a radix tree over full token blocks mapping
    prompt prefixes to cached KV blocks. A new request walks the tree,
    leases every matched block (refcount++) and prefills only the
    uncached suffix. Completed sequences are inserted back, so multi-turn
    histories and shared system prompts hit. Leaf blocks referenced only
    by the cache are evictable (LRU) when the pool runs dry.

Copy-on-write: shared blocks are read-only. When a request must append
into a partially-reused block (its prompt ends mid-block inside a cached
run), the engine allocates a fresh block, copies the shared contents and
writes there — ``BlockPool`` only tracks the refcounts; the data copy is
a jitted engine function.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


class PoolExhausted(RuntimeError):
    """No free block available (and nothing evictable)."""


class BlockPool:
    """Fixed-size population of KV blocks with refcounted ownership."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks
        self.version = 0               # bumped on every refcount change
        # actual device bytes one block occupies across the engine's pool
        # arrays (k + v + int8 scales), set by the owning engine from the
        # pool tensors' nbytes — int8 pools land at quantized width.
        self.bytes_per_block: int = 0

    # -- inspection ----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def used_frac(self) -> float:
        return 1.0 - len(self._free) / self.num_blocks

    @property
    def capacity_bytes(self) -> int:
        return self.num_blocks * self.bytes_per_block

    @property
    def used_bytes(self) -> int:
        return (self.num_blocks - len(self._free)) * self.bytes_per_block

    @property
    def free_bytes(self) -> int:
        return len(self._free) * self.bytes_per_block

    def refcount(self, bid: int) -> int:
        return self._ref[bid]

    # -- allocate / share / release ------------------------------------------
    def alloc(self) -> int:
        """Take one free block (refcount 1). Raises ``PoolExhausted``."""
        if not self._free:
            raise PoolExhausted(f"all {self.num_blocks} KV blocks in use")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.version += 1
        return bid

    def alloc_many(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} KV blocks, only {len(self._free)} free")
        return [self.alloc() for _ in range(n)]

    def incref(self, bid: int) -> None:
        assert self._ref[bid] > 0, f"incref on free block {bid}"
        self._ref[bid] += 1
        self.version += 1

    def decref(self, bid: int) -> bool:
        """Drop one reference; returns True if the block was freed."""
        assert self._ref[bid] > 0, f"decref on free block {bid}"
        self._ref[bid] -= 1
        self.version += 1
        if self._ref[bid] == 0:
            self._free.append(bid)
            return True
        return False


@dataclass
class _RadixNode:
    """One full KV block of tokens. Edge key = that block's token tuple."""
    key: Tuple[int, ...]
    block: int
    parent: Optional["_RadixNode"]
    children: Dict[Tuple[int, ...], "_RadixNode"] = field(default_factory=dict)
    t_access: int = 0


@dataclass
class PrefixStats:
    lookups: int = 0
    lookup_tokens: int = 0
    hit_tokens: int = 0
    inserted_blocks: int = 0
    evicted_blocks: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens else 0.0


class RadixPrefixCache:
    """Block-granular radix tree over token sequences.

    Nodes hold exactly one FULL block (``block_size`` tokens); partial
    tail blocks are never shared directly — a request that needs part of
    a cached block goes through the engine's copy-on-write path. The
    cache holds one refcount on every registered block; ``match`` takes
    an additional lease per matched block on behalf of the caller.
    """

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self.block_size = pool.block_size
        self._root = _RadixNode(key=(), block=-1, parent=None)
        self._clock = 0
        self._by_block: Dict[int, _RadixNode] = {}
        self.stats = PrefixStats()
        self._evictable_memo: Tuple[int, int] = (-1, 0)   # (pool.version, n)

    def __len__(self) -> int:
        return len(self._by_block)

    # -- lookup ---------------------------------------------------------
    def _walk(self, tokens: Sequence[int], touch: bool) -> List[_RadixNode]:
        bs = self.block_size
        node, path = self._root, []
        for i in range(0, len(tokens) - bs + 1, bs):
            child = node.children.get(tuple(tokens[i:i + bs]))
            if child is None:
                break
            if touch:
                self._clock += 1
                child.t_access = self._clock
            path.append(child)
            node = child
        return path

    def peek(self, tokens: Sequence[int]) -> int:
        """Matched-prefix length in tokens, without taking leases."""
        return len(self._walk(tokens, touch=False)) * self.block_size

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens`` in full blocks.

        Returns ``(block_ids, n_tokens)``; every returned block carries a
        new lease (refcount++) the caller must ``decref`` when done.
        """
        path = self._walk(tokens, touch=True)
        blocks = [n.block for n in path]
        for bid in blocks:
            self.pool.incref(bid)
        self.stats.lookups += 1
        self.stats.lookup_tokens += len(tokens)
        self.stats.hit_tokens += len(blocks) * self.block_size
        return blocks, len(blocks) * self.block_size

    # -- registration ---------------------------------------------------
    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Register ``blocks[i]`` as holding KV for tokens
        ``[i*bs, (i+1)*bs)``. Only full blocks may be passed. Existing
        nodes win (first writer keeps its block — both hold identical
        KV); new nodes take one cache refcount. Returns #registered."""
        bs = self.block_size
        assert len(blocks) * bs <= len(tokens)
        node, added = self._root, 0
        for i, bid in enumerate(blocks):
            key = tuple(tokens[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                self._clock += 1
                child = _RadixNode(key=key, block=bid, parent=node,
                                   t_access=self._clock)
                node.children[key] = child
                self._by_block[bid] = child
                self.pool.incref(bid)
                added += 1
            node = child
        self.stats.inserted_blocks += added
        return added

    # -- eviction -------------------------------------------------------
    def evictable_blocks(self) -> int:
        """Blocks reclaimable by cascading LRU leaf eviction: nodes whose
        entire subtree is referenced only by the cache. Single O(n) DFS,
        memoized on the pool's refcount version — the scheduler polls
        this on its admission hot path, usually with nothing changed
        (tree mutations always move a refcount, so the pool version
        covers insert/evict too)."""
        if self._evictable_memo[0] == self.pool.version:
            return self._evictable_memo[1]

        def walk(n: _RadixNode):
            total, all_free = 0, True
            for c in n.children.values():
                t, ok = walk(c)
                total += t
                all_free &= ok
            if all_free and self.pool.refcount(n.block) == 1:
                return total + 1, True
            return total, False

        n = sum(walk(c)[0] for c in self._root.children.values())
        self._evictable_memo = (self.pool.version, n)
        return n

    def evict(self, n: int) -> int:
        """Free up to ``n`` blocks, LRU leaves first. Returns #freed.
        One leaf scan up front; parents that become evictable leaves
        join the heap as their children go (no per-block rescans)."""
        heap = [(node.t_access, node.block) for node in self._by_block.values()
                if not node.children and self.pool.refcount(node.block) == 1]
        heapq.heapify(heap)
        freed = 0
        while freed < n and heap:
            _, bid = heapq.heappop(heap)
            victim = self._by_block[bid]
            parent = victim.parent
            self._remove(victim)
            freed += 1
            if (parent is not self._root and not parent.children
                    and self.pool.refcount(parent.block) == 1):
                heapq.heappush(heap, (parent.t_access, parent.block))
        self.stats.evicted_blocks += freed
        return freed

    def _remove(self, node: _RadixNode) -> None:
        assert not node.children
        del node.parent.children[node.key]
        del self._by_block[node.block]
        self.pool.decref(node.block)

    def clear(self) -> int:
        """Drop every cache-only entry (live leases keep their blocks)."""
        return self.evict(len(self._by_block))
