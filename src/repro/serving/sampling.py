"""Token sampling: greedy / temperature / top-k / top-p (nucleus).

Two entry points share the same math:

  * ``sample``      — one ``SamplingParams`` applied to a (B, V) batch;
    python-level branching on the static params (the host-side path).
  * ``sample_rows`` — PER-ROW params over a (B, V) batch with greedy and
    stochastic rows unified under masks, vmapped so the whole mixed
    batch samples in ONE device dispatch. This is the fused in-step
    sampler of the decode hot path (serving/engine.py): the params live
    in stacked device-resident buffers and the logits never reach the
    host. Row ``i`` with key ``k_i`` draws exactly the token
    ``sample(logits[i:i+1], params_i, k_i)`` would — the engine's
    per-request PRNG streams are unchanged by the fusion.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => disabled
    top_p: float = 1.0                # 1.0 => disabled
    max_new_tokens: int = 64
    eos_id: Optional[int] = None


def sample(logits: jnp.ndarray, params: SamplingParams, key) -> jnp.ndarray:
    """logits: (B, V) -> (B,) int32."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -params.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if params.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def _sample_row(logits: jnp.ndarray, temp, top_k, top_p, key) -> jnp.ndarray:
    """One row of ``sample_rows``: (V,) logits + traced per-row params.

    Mirrors ``sample`` op for op, with the static python branches turned
    into masks (``top_k == 0`` / ``top_p == 1.0`` / ``temp <= 0`` select
    the untouched logits or the argmax), so the fused sampler is
    token-for-token equivalent to the host path it replaces."""
    V = logits.shape[-1]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(temp > 0.0, temp, 1.0)
    sl = logits / safe_t
    # one descending sort serves both filters: top-k thresholds at the
    # k-th largest, and masking values below it touches only a SUFFIX of
    # the sorted row — so the filtered row is still sorted and top-p can
    # reuse it without a second sort
    desc = jnp.sort(sl)[::-1]
    kth = desc[jnp.clip(top_k - 1, 0, V - 1)]
    sl = jnp.where((top_k > 0) & (sl < kth), -jnp.inf, sl)
    sd = jnp.where((top_k > 0) & (desc < kth), -jnp.inf, desc)
    # top-p over the (already top-k-filtered) logits
    probs = jax.nn.softmax(sd)
    cutoff_idx = jnp.sum(jnp.cumsum(probs) < top_p)
    cutoff = sd[jnp.clip(cutoff_idx, 0, V - 1)]
    sl = jnp.where((top_p < 1.0) & (sl < cutoff), -jnp.inf, sl)
    # same draw the host path makes: categorical over a (1, V) row
    tok = jax.random.categorical(key, sl[None], axis=-1)[0].astype(jnp.int32)
    return jnp.where(temp > 0.0, tok, greedy_tok)


def sample_rows(logits: jnp.ndarray, temps: jnp.ndarray, top_ks: jnp.ndarray,
                top_ps: jnp.ndarray, keys: jnp.ndarray) -> jnp.ndarray:
    """Per-row sampling over a batch: logits (B, V), temps/top_ks/top_ps
    (B,), keys (B, 2) per-row PRNG keys -> (B,) int32. Greedy rows
    (temp <= 0) never consume their key.

    An all-greedy batch (the common serving case) short-circuits to one
    argmax under ``lax.cond`` — the sort/cumsum machinery of the
    stochastic path never executes, keeping the fused decode step as
    cheap as a pure-greedy sampler when nothing draws."""
    def stochastic(_):
        return jax.vmap(_sample_row)(logits, temps, top_ks, top_ps, keys)

    def all_greedy(_):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    return jax.lax.cond(jnp.any(temps > 0.0), stochastic, all_greedy,
                        operand=None)
