"""Inference-backend execution profiles.

The paper's service matrix pairs each model with one of three backends
(vLLM / TensorRT-LLM / TGI). We implement the analogous profiles as
genuinely different execution configs of our own JAX engine — not labels:

  throughput ("vllm-like")   large decode batch, batching wait, paged-ish
                             big KV blocks, bf16 cache — max tokens/s.
  latency    ("trt-like")    small batch, zero batching wait, fused decode
                             attention path, small q-chunk — min TTFT.
  memory     ("tgi-like")    bf16 KV + tighter batch — min HBM per replica.

These feed two places: (1) the real in-process engine (CPU, reduced
models) compiles different step functions per profile; (2) the cluster
simulator's cost model uses the profile's multipliers for the large archs
(calibrated from dry-run step costs; see core/costmodel.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class BackendProfile:
    name: str
    kind: str                 # throughput | latency | memory
    max_batch: int            # decode slots per replica
    q_chunk: int              # prefill chunking
    batch_wait_s: float       # how long the scheduler waits to fill a batch
    kv_dtype: str             # cache dtype
    # simulator multipliers relative to the `latency` profile
    ttft_mult: float
    tps_mult: float           # decode tokens/s multiplier (batch efficiency)
    mem_mult: float           # HBM footprint multiplier per replica
    # paged KV cache (block pool + radix prefix reuse). The profile split
    # mirrors the real engines: vLLM's PagedAttention and TGI's paging
    # are their signature memory features; TensorRT-LLM's latency profile
    # keeps the statically-planned dense cache (lowest per-step overhead)
    paged: bool = False


BACKENDS: Dict[str, BackendProfile] = {
    "vllm": BackendProfile(
        name="vllm", kind="throughput", max_batch=16, q_chunk=512,
        batch_wait_s=0.010, kv_dtype="bfloat16",
        ttft_mult=1.25, tps_mult=1.60, mem_mult=1.15, paged=True),
    "trt": BackendProfile(
        name="trt", kind="latency", max_batch=4, q_chunk=256,
        batch_wait_s=0.0, kv_dtype="bfloat16",
        ttft_mult=1.00, tps_mult=1.00, mem_mult=1.25, paged=False),
    "tgi": BackendProfile(
        name="tgi", kind="memory", max_batch=8, q_chunk=512,
        batch_wait_s=0.004, kv_dtype="bfloat16",
        ttft_mult=1.35, tps_mult=1.20, mem_mult=0.85, paged=True),
}


def get_backend(name: str) -> BackendProfile:
    return BACKENDS[name]
