from repro.serving.backend import BACKENDS, BackendProfile, get_backend  # noqa: F401
from repro.serving.sampling import SamplingParams, sample, sample_rows  # noqa: F401
from repro.serving.engine import (CompiledFns, GenResult, InferenceEngine,  # noqa: F401
                                  PagedCompiledFns, PagedInferenceEngine,
                                  Request, SpecConfig, SpecDraft, SpecFns,
                                  compile_fns, compile_paged_fns,
                                  compile_spec_fns)
from repro.serving.faults import (FaultInjector, FaultPlan,  # noqa: F401
                                  FaultSpec, InjectedFault)
from repro.serving.kvpool import (BlockPool, PoolExhausted,  # noqa: F401
                                  PrefixStats, RadixPrefixCache)
from repro.serving.replica_pool import (ReplicaHealth, ReplicaPool,  # noqa: F401
                                        ScaleEvent)
from repro.serving.scheduler import (RequestScheduler, SchedStats,  # noqa: F401
                                     SchedulerConfig)
