from repro.serving.backend import BACKENDS, BackendProfile, get_backend  # noqa: F401
from repro.serving.sampling import SamplingParams, sample  # noqa: F401
from repro.serving.engine import (CompiledFns, GenResult, InferenceEngine,  # noqa: F401
                                  Request, compile_fns)
from repro.serving.replica_pool import ReplicaPool, ScaleEvent  # noqa: F401
from repro.serving.scheduler import (RequestScheduler, SchedStats,  # noqa: F401
                                     SchedulerConfig)
