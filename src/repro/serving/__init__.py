from repro.serving.backend import BACKENDS, BackendProfile, get_backend  # noqa: F401
from repro.serving.engine import GenResult, InferenceEngine, Request  # noqa: F401
from repro.serving.sampling import SamplingParams, sample  # noqa: F401
