"""Int8 KV-cache quantization (per-token, per-head absmax scales).

§Perf H1 iteration 3: command-r decode_32k's dominant roofline term is the
KV-cache read (1.1 TB/step at batch 128 x 32k x 64L bf16). Int8 halves the
streamed bytes; absmax scales are per (token, kv-head), so the extra scale
traffic is D/1 = 128x smaller than the cache itself.

Contract: ``quantize(k) -> (q int8, scale f32)``, ``dequantize(q, scale)``;
attention consumes dequantized values (on TPU the dequant fuses into the
VMEM load of the decode kernel).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., D) -> (int8 (..., D), f32 scale (..., 1))."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
