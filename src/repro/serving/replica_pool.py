"""Replica pools: real engine lifecycle for the concurrent serve plane.

One ``ReplicaPool`` owns every live ``InferenceEngine`` replica in the
process, keyed by (model, backend) service. Spin-up is genuinely
expensive (param init/load + XLA compile) and measured; two warm layers
cut it down:

  * param cache — model weights stay resident after scale-to-zero (the
    paper's "warm pool"), so a re-spin skips ``init_model``;
  * code cache  — the jitted prefill/decode executables for a service
    are shared across its replicas and survive scale-to-zero, so only
    the FIRST replica of a service ever pays XLA compile (replica fork,
    analogous to reusing a baked engine image).

``scale()`` has exactly the ``scale_cb`` signature ``Orchestrator``
(Algorithm 1) calls with, so the same Spin control loop that drives the
discrete-event simulator drives these real engines. Every lifecycle
action is recorded as a ``ScaleEvent`` — the measured cold/warm start
log that calibrates the simulator's constants.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import jax

from repro.models import init_model
from repro.models.transformer import supports_paged
from repro.serving.backend import BACKENDS
from repro.serving.engine import (DEFAULT_BLOCK_SIZE, InferenceEngine,
                                  PagedInferenceEngine, Request, SpecConfig,
                                  SpecDraft, compile_fns, compile_paged_fns)
from repro.serving.faults import FaultPlan, InjectedFault
from repro.serving.sampling import SamplingParams

_Key = Tuple[str, str]


@dataclass
class ScaleEvent:
    t: float                 # wall time (perf_counter) the action started
    model: str
    backend: str
    before: int              # replicas before
    after: int               # replicas after
    kind: str                # spin-cold | spin-warm | down | zero |
    #                          quarantine | drain | drained | drain-timeout
    duration_s: float        # blocking cost of the action

    def __str__(self) -> str:
        return (f"[{self.kind:>9s}] {self.model}/{self.backend} "
                f"{self.before}->{self.after} ({self.duration_s:.3f}s)")


@dataclass
class ReplicaHealth:
    """Per-replica health record (attached to each engine at spin-up).

    ``healthy`` -> ``degraded`` on a step failure (the circuit breaker
    arming), back to ``healthy`` on the next clean step, ``quarantined``
    when consecutive failures cross the breaker threshold OR the engine
    poisoned itself mid-step (host/device state no longer trusted).
    Quarantine is terminal for the replica: it is evacuated, its meter
    settled, and a substitute spun by the repair path."""
    state: str = "healthy"            # healthy | degraded | quarantined
    consecutive_failures: int = 0
    failures: int = 0                 # lifetime step failures
    last_error: str = ""
    since: float = 0.0                # when `state` was entered


class ReplicaPool:
    """All live engine replicas, plus the warm param/code caches."""

    def __init__(self, models: Dict[str, object], registry,
                 max_seq: int = 256, seed: int = 0, paged="auto",
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 chunk_tokens: Optional[int] = None,
                 step_token_budget: Optional[int] = None,
                 decode_burst: int = 1, obs=None,
                 spec: Optional[SpecConfig] = None,
                 faults: Optional[FaultPlan] = None,
                 quarantine_after: int = 2,
                 drain_deadline_s: float = 30.0):
        self.models = models
        self.obs = obs                # Observability bundle (optional)
        self.reg = registry
        self.max_seq = max_seq
        self.seed = seed
        # paged KV-cache plane: "auto" pages every model family that
        # supports it (GQA transformer trunk), False forces dense engines
        self.paged = paged
        self.block_size = block_size
        # continuous-batching knobs threaded into every spun engine:
        # prefill chunk bound + per-step token budget (None: whole-prompt
        # prefill / unbounded step, the pre-chunking behavior), plus the
        # opt-in decode-burst depth (K fused decode iterations per step
        # when no prefill backlog is pending; 1 = stepwise)
        self.chunk_tokens = chunk_tokens
        self.step_token_budget = step_token_budget
        self.decode_burst = decode_burst
        # speculative decoding: one SpecConfig applies pool-wide; each
        # spun engine gets a resolved SpecDraft (draft params share the
        # warm param cache) and decides co-residency itself — a target
        # the draft can't pair with (vocab mismatch, KV pressure, or the
        # draft arch IS the target) falls back to plain fused stepwise
        self.spec = spec
        self._replicas: Dict[_Key, List[InferenceEngine]] = {
            (m, b): [] for m in models for b in registry.backends}
        self._params: Dict[str, object] = {}       # warm weights per model
        self._code: Dict[_Key, object] = {}        # compiled fns per service
        self.events: List[ScaleEvent] = []
        # (label, seconds) — same contract as Gateway.cold_starts
        self.cold_starts: List[Tuple[str, float]] = []
        # -- fault tolerance ------------------------------------------------
        # seeded chaos plan threaded into every spun engine (None: no
        # injection, zero overhead), circuit-breaker threshold, and the
        # graceful-drain deadline for scale-downs with in-flight work
        self.faults = faults
        self.quarantine_after = max(1, quarantine_after)
        self.drain_deadline_s = drain_deadline_s
        # incarnation counter per service: the Nth engine (or spin
        # attempt) ever started for (model, backend) — the identity
        # FaultSpec.replica targets, stable across quarantine/replace
        self._incarnations: Dict[_Key, int] = {}
        # draining replicas: out of placement, still stepping until
        # their in-flight work finishes (or the deadline kills them)
        self._draining: Dict[_Key, List[Tuple[InferenceEngine, float]]] = {}
        # quarantined replicas awaiting a substitute (count per service)
        self._pending_replace: Dict[_Key, int] = {}
        self.quarantines = 0              # lifetime count (all services)
        self._model_quarantines: Dict[str, int] = {}

    def _use_paged(self, model: str, backend: str) -> bool:
        """paged="auto": follow the backend profile (vllm/tgi page, trt
        keeps the dense static cache) for models whose family supports
        it; True forces paging everywhere; False forces dense."""
        if self.paged is False:
            return False
        ok = supports_paged(self.models[model]) and \
            self.max_seq % self.block_size == 0
        if self.paged == "auto":
            return ok and BACKENDS[backend].paged
        if not ok:
            raise ValueError(f"{model}: paged engines unsupported")
        return True

    # -- inspection ----------------------------------------------------------
    def replicas(self, model: str, backend: str) -> List[InferenceEngine]:
        """Replicas open for PLACEMENT (serving; draining excluded)."""
        return self._replicas[(model, backend)]

    def engines(self) -> Iterator[Tuple[_Key, InferenceEngine]]:
        """Every engine that must still be STEPPED: serving replicas
        plus draining ones (their in-flight work has to finish)."""
        for key, reps in self._replicas.items():
            for eng in reps:
                yield key, eng
        for key, dr in self._draining.items():
            for eng, _deadline in dr:
                yield key, eng

    def service_engines(self, model: str,
                        backend: str) -> List[InferenceEngine]:
        """Serving + draining engines of one service (the cancel/lookup
        surface — a request may live on a draining replica)."""
        key = (model, backend)
        return (list(self._replicas[key])
                + [e for e, _ in self._draining.get(key, ())])

    def free_slots(self, model: str, backend: str) -> int:
        return sum(e.free_slots() for e in self._replicas[(model, backend)])

    def total_replicas(self) -> int:
        return (sum(len(r) for r in self._replicas.values())
                + sum(len(d) for d in self._draining.values()))

    def has_params(self, model: str) -> bool:
        return model in self._params

    # -- paged KV-cache plane inspection ---------------------------------
    def paged_replicas(self, model: str, backend: str
                       ) -> List[PagedInferenceEngine]:
        return [e for e in self._replicas[(model, backend)] if e.paged]

    def kv_free_frac(self, model: str, backend: str) -> float:
        """Best allocatable block headroom across the service's paged
        replicas (1.0 for dense services / no live replicas — nothing to
        shed on)."""
        reps = self.paged_replicas(model, backend)
        if not reps:
            return 1.0
        return max(e.kv_free_frac() for e in reps)

    def kv_bound(self, model: str, backend: str) -> bool:
        """True when KV blocks — not decode slots — are the binding
        admission resource: compute sits idle while the pool can't back
        another sequence. A fully-leased pool with fully-busy slots is
        ordinary queueing, not block starvation."""
        reps = self.paged_replicas(model, backend)
        if not reps:
            return False
        slot_cap = sum(e.idle_slots() for e in reps)
        block_cap = sum(e.block_capacity() for e in reps)
        return block_cap < slot_cap

    def kv_stats(self, model: str) -> Optional[Dict[str, float]]:
        """Pool occupancy / prefix-cache telemetry aggregated over every
        live paged replica of ``model`` (all backend columns); None when
        the model has no live paged replicas."""
        reps = [e for b in self.reg.backends
                for e in self.paged_replicas(model, b)]
        if not reps:
            return None
        hit = sum(e.hit_tokens for e in reps)
        seen = sum(e.prompt_tokens for e in reps)

        def pressure(e) -> float:
            # bytes-grounded: fraction of the replica's KV-pool BYTES
            # that cannot back a new sequence (evictable prefix-cache
            # bytes are reclaimable, so they count as headroom)
            cap = e.pool.capacity_bytes
            if cap <= 0:
                return 1.0 - e.kv_free_frac()     # geometry not published
            free = e.pool.num_free
            if e.prefix:
                free += e.prefix.evictable_blocks()
            return 1.0 - (free * e.pool.bytes_per_block) / cap

        return {
            # pressure: headroom of the LEAST-squeezed replica — high
            # only when every replica is out of allocatable KV bytes
            "kv_pressure": min(pressure(e) for e in reps),
            "kv_occupancy": max(e.kv_used_frac() for e in reps),
            "kv_hit_rate": hit / seen if seen else 0.0,
            "kv_free_blocks": float(sum(e.pool.num_free for e in reps)),
        }

    def prefix_peek(self, model: str, backend: str, req: Request) -> int:
        """Best cached-prefix reuse (tokens) any replica offers ``req``."""
        reps = self.paged_replicas(model, backend)
        return max((e.prefix_peek(req) for e in reps), default=0)

    def backlog_tokens(self, model: str) -> int:
        """Prefill backlog in TOKENS across every live replica of
        ``model`` (engine-internal queues + unfilled prefill cursors) —
        the load measure that sees a half-prefilled 8k prompt where a
        free-slot count sees an almost-idle engine."""
        return sum(e.pending_tokens() for b in self.reg.backends
                   for e in self._replicas[(model, b)])

    # -- lifecycle (Orchestrator scale_cb target) -----------------------------
    def scale(self, model: str, backend: str, replicas: int,
              now: Optional[float] = None) -> int:
        """Bring the service to ``replicas`` live engines (blocking; real
        spin-up cost is paid inline and measured). Returns the achieved
        replica count. Scale-down retires idle replicas immediately and
        DRAINS busy ones: out of placement at once, stepped until their
        in-flight work finishes (deadline-bounded), then retired —
        nothing in flight is dropped. An injected spin failure stops
        the scale-up short (achieved < target; the next tick retries)."""
        now = time.perf_counter() if now is None else now
        entry = self.reg.entry(model, backend)
        entry.accrue(now)
        replicas = max(0, replicas)
        while len(self._replicas[(model, backend)]) < replicas:
            try:
                self._spin_up(model, backend, now)
            except InjectedFault:
                break                     # chaos: spin-up failed, no crash
        if len(self._replicas[(model, backend)]) > replicas:
            self._spin_down(model, backend, replicas, now)
        return len(self._replicas[(model, backend)])

    def evict(self, model: str) -> None:
        """Drop the warm param + code caches — next spin is a true cold."""
        self._params.pop(model, None)
        for key in [k for k in self._code if k[0] == model]:
            del self._code[key]
        for (m, _), e in self.reg.matrix.items():
            if m == model:
                e.warm = 0

    def _spec_draft(self, model: str) -> Optional[SpecDraft]:
        """Resolve the pool's SpecConfig into a SpecDraft for ``model``
        (None when spec is off or the draft arch IS the target — a model
        never drafts for itself). Draft weights ride the same warm param
        cache as serving models, so scale-to-zero keeps them resident."""
        if self.spec is None or self.spec.draft_arch == model:
            return None
        arch = self.spec.draft_arch
        dcfg = self.models.get(arch)
        if dcfg is None:
            import dataclasses

            from repro.configs.registry import ARCHS
            if arch not in ARCHS:
                raise ValueError(f"unknown spec draft arch {arch!r}")
            dcfg = dataclasses.replace(ARCHS[arch].reduced(),
                                       dtype=self.models[model].dtype)
        if arch not in self._params:
            self._params[arch] = init_model(dcfg, jax.random.PRNGKey(self.seed))
        return SpecDraft(cfg=dcfg, params=self._params[arch], k=self.spec.k)

    # -- internals -------------------------------------------------------
    def _spin_up(self, model: str, backend: str, now: float) -> None:
        key = (model, backend)
        reps = self._replicas[key]
        # incarnation: every spin ATTEMPT gets the next identity, so a
        # fault plan can target "the substitute of replica 0" stably
        incarnation = self._incarnations.get(key, 0)
        self._incarnations[key] = incarnation + 1
        if self.faults is not None and self.faults.spin_fails(
                model, backend, incarnation):
            if self.obs is not None:
                self.obs.registry.counter(
                    "fault_injected_total",
                    f"{model}|kind=spin_fail").inc()
                self.obs.events.append("fault", t=now, model=model,
                                       backend=backend, kind="spin_fail",
                                       incarnation=incarnation)
            raise InjectedFault(
                f"injected spin_fail for {model}/{backend}#{incarnation}")
        # servelint: disable=SL001 -- real wall interval: spin-up duration
        t0 = time.perf_counter()
        cfg = self.models[model]
        warm = model in self._params and key in self._code
        use_paged = self._use_paged(model, backend)
        if model not in self._params:
            self._params[model] = init_model(cfg, jax.random.PRNGKey(self.seed))
        if key not in self._code:
            self._code[key] = (
                compile_paged_fns(cfg, BACKENDS[backend], self.max_seq,
                                  self.block_size) if use_paged
                else compile_fns(cfg, BACKENDS[backend], self.max_seq))
        # ONE seed pool-wide: per-request PRNG streams are keyed by uid x
        # draw index, so equal seeds make replicas interchangeable — the
        # invariant deterministic retry-on-another-replica rests on
        kw = dict(max_seq=self.max_seq,
                  seed=self.seed,
                  fns=self._code[key],
                  chunk_tokens=self.chunk_tokens,
                  step_token_budget=self.step_token_budget,
                  decode_burst=self.decode_burst,
                  spec=self._spec_draft(model),
                  fault=(self.faults.injector(model, backend, incarnation)
                         if self.faults is not None else None),
                  obs=(self.obs.engine_obs(model, backend)
                       if self.obs is not None else None))
        if use_paged:
            eng = PagedInferenceEngine(cfg, self._params[model],
                                       BACKENDS[backend],
                                       block_size=self.block_size, **kw)
        else:
            eng = InferenceEngine(cfg, self._params[model], BACKENDS[backend],
                                  **kw)
        # trigger compile/execute of the step functions before the replica
        # counts as live (the dominant real cold-start cost when cold) —
        # with obs muted, so compile-bound probe steps never land in the
        # engine step-duration histograms
        probe_obs, eng._obs = eng._obs, None
        probe_fault, eng._fault = eng._fault, None   # probes aren't chaos targets
        eng.run([Request(uid=-1, tokens=[1, 2, 3],
                         sampling=SamplingParams(max_new_tokens=2))])
        eng._obs = probe_obs
        eng._fault = probe_fault
        eng.health = ReplicaHealth(since=now)
        eng.incarnation = incarnation
        # servelint: disable=SL001 -- real wall interval: spin-up duration
        dur = time.perf_counter() - t0
        reps.append(eng)
        entry = self.reg.entry(model, backend)
        entry.replicas = len(reps)
        entry.warm = 0
        kind = "spin-warm" if warm else "spin-cold"
        self.events.append(ScaleEvent(now, model, backend, len(reps) - 1,
                                      len(reps), kind, dur))
        self.cold_starts.append(
            (f"{model}/{backend}/{'warm' if warm else 'cold'}", dur))
        if self.obs is not None:
            self.obs.registry.histogram(
                "cold_start_s" if not warm else "warm_start_s",
                model).observe(dur)
            self.obs.events.append("scale", t=now, model=model,
                                   backend=backend, before=len(reps) - 1,
                                   after=len(reps), kind=kind,
                                   duration_s=dur)
            # open this replica's chip-second meter: the spin window
            # (param build + compile + probes) is COLD chip-seconds; the
            # metered clock starts now. perf_counter domain throughout —
            # the same clock engine.step() stamps with.
            eng._obs.meter = self.obs.ledger.replica_up(
                model, backend, chips=entry.cost.chips, cold_s=dur,
                t=time.perf_counter())  # servelint: disable=SL001 -- ledger is perf_counter domain (engine.step stamps feed it)
            self._update_memory_gauges(model, now)
            self._health_gauges(model)

    def _spin_down(self, model: str, backend: str, target: int,
                   now: float) -> None:
        key = (model, backend)
        reps = self._replicas[key]
        before = len(reps)
        # idle replicas retire immediately; BUSY excess drains instead
        # of being skipped (the old behavior) or killed: out of
        # placement now, stepped until in-flight work finishes, retired
        # by finish_drains() — deadline-bounded so a wedged request
        # can't pin a replica forever
        idle = [e for e in reps if not e.has_work()]
        excess = before - target
        for eng in idle[:max(0, excess)]:
            reps.remove(eng)
            self._settle_meter(eng)
        excess = len(reps) - target
        if excess > 0:
            # drain the least-loaded first: they free capacity soonest
            busy = sorted(reps, key=lambda e: e.pending_tokens())
            dr = self._draining.setdefault(key, [])
            for eng in busy[:excess]:
                reps.remove(eng)
                dr.append((eng, now + self.drain_deadline_s))
                self.events.append(ScaleEvent(now, model, backend,
                                              len(reps) + 1, len(reps),
                                              "drain", 0.0))
                if self.obs is not None:
                    self.obs.events.append("scale", t=now, model=model,
                                           backend=backend,
                                           before=len(reps) + 1,
                                           after=len(reps), kind="drain",
                                           duration_s=0.0)
        entry = self.reg.entry(model, backend)
        entry.replicas = len(reps)
        entry.warm = 1 if (not reps and model in self._params) else 0
        if len(reps) != before:
            kind = "zero" if not reps else "down"
            self.events.append(ScaleEvent(now, model, backend, before,
                                          len(reps), kind, 0.0))
            if self.obs is not None:
                self.obs.events.append("scale", t=now, model=model,
                                       backend=backend, before=before,
                                       after=len(reps), kind=kind,
                                       duration_s=0.0)
                self._update_memory_gauges(model, now)
                self._health_gauges(model)

    # -- fault tolerance: health, quarantine, repair, drain ---------------
    def _settle_meter(self, eng: InferenceEngine) -> None:
        """Close a retiring replica's chip-second meter exactly once —
        ``replica_down`` is idempotent, so the quarantine, drain and
        scale-down paths may all reach the same meter safely."""
        if (self.obs is not None and eng._obs is not None
                and eng._obs.meter is not None):
            self.obs.ledger.replica_down(
                eng._obs.meter,
                time.perf_counter())  # servelint: disable=SL001 -- ledger is perf_counter domain (engine.step stamps feed it)

    def _health_gauges(self, model: str) -> None:
        """Publish ``replica_health``: live replicas of ``model`` per
        health state (draining counted under their current state) plus
        the monotonic quarantined total."""
        if self.obs is None:
            return
        counts = {"healthy": 0, "degraded": 0}
        for b in self.reg.backends:
            for e in self.service_engines(model, b):
                h = getattr(e, "health", None)
                st = h.state if h is not None else "healthy"
                counts[st] = counts.get(st, 0) + 1
        counts["quarantined"] = self._model_quarantines.get(model, 0)
        for st, n in counts.items():
            self.obs.registry.gauge(
                "replica_health", f"{model}|state={st}").set(float(n))

    def note_step_ok(self, eng: InferenceEngine, now: float) -> None:
        """A clean step resets the circuit breaker (degraded -> healthy)."""
        h = getattr(eng, "health", None)
        if h is None or (h.consecutive_failures == 0
                         and h.state == "healthy"):
            return
        h.consecutive_failures = 0
        if h.state == "degraded":
            h.state = "healthy"
            h.since = now
            if eng._obs is not None:
                self._health_gauges(eng._obs.model)

    def report_step_failure(self, model: str, backend: str,
                            eng: InferenceEngine, exc: BaseException,
                            now: float):
        """Containment entry point for a step that raised. Counts the
        failure against the replica's breaker; returns the evacuated
        request list when the replica was quarantined (poisoned engines
        quarantine immediately — their host/device bookkeeping can no
        longer be trusted), else None (degraded; it keeps serving)."""
        h = getattr(eng, "health", None)
        if h is None:
            h = eng.health = ReplicaHealth(since=now)
        h.consecutive_failures += 1
        h.failures += 1
        h.last_error = repr(exc)
        if (getattr(eng, "poisoned", False)
                or h.consecutive_failures >= self.quarantine_after):
            return self.quarantine(model, backend, eng, now,
                                   reason=repr(exc))
        if h.state != "degraded":
            h.state = "degraded"
            h.since = now
            self._health_gauges(model)
        return None

    def quarantine(self, model: str, backend: str, eng: InferenceEngine,
                   now: float, reason: str = ""):
        """Remove a sick replica from service: evacuate its live
        requests (returned for resubmission), settle its cost meter,
        refresh the HBM/health gauges, and mark a substitute pending
        for the repair path. Idempotent per engine."""
        key = (model, backend)
        reps = self._replicas[key]
        found = False
        if eng in reps:
            reps.remove(eng)
            found = True
            self.reg.entry(model, backend).replicas = len(reps)
        else:
            dr = self._draining.get(key, [])
            for pair in dr:
                if pair[0] is eng:
                    dr.remove(pair)
                    found = True
                    break
        if not found:                     # already quarantined
            return []
        h = getattr(eng, "health", None)
        if h is None:
            h = eng.health = ReplicaHealth()
        h.state = "quarantined"
        h.since = now
        self.quarantines += 1
        self._model_quarantines[model] = \
            self._model_quarantines.get(model, 0) + 1
        evac = eng.evacuate()
        self._settle_meter(eng)
        self._pending_replace[key] = self._pending_replace.get(key, 0) + 1
        self.events.append(ScaleEvent(now, model, backend, len(reps) + 1,
                                      len(reps), "quarantine", 0.0))
        if self.obs is not None:
            self.obs.registry.counter("replicas_quarantined_total",
                                      model).inc()
            self.obs.events.append("quarantine", t=now, model=model,
                                   backend=backend,
                                   incarnation=getattr(eng, "incarnation",
                                                       -1),
                                   evacuated=len(evac), reason=reason)
            self._update_memory_gauges(model, now)
            self._health_gauges(model)
        return evac

    def replace_quarantined(self, now: Optional[float] = None
                            ) -> Dict[_Key, int]:
        """Repair path (called from the orchestrator tick): spin one
        substitute per pending quarantine — warm-pool aware, so a
        service whose params/code survived pays only the warm start.
        An injected spin failure leaves the replacement pending for the
        next tick. Returns {service: substitutes spun}."""
        now = time.perf_counter() if now is None else now
        done: Dict[_Key, int] = {}
        for key, n in list(self._pending_replace.items()):
            spun = 0
            for _ in range(n):
                try:
                    self._spin_up(key[0], key[1], now)
                    spun += 1
                except InjectedFault:
                    break                 # retry at the next tick
            if spun:
                left = n - spun
                if left > 0:
                    self._pending_replace[key] = left
                else:
                    del self._pending_replace[key]
                done[key] = spun
        return done

    def finish_drains(self, now: Optional[float] = None):
        """Retire draining replicas whose in-flight work finished; past
        the deadline, evacuate what's left so it can be resubmitted
        elsewhere (returned as ``[((model, backend), evac), ...]``)."""
        now = time.perf_counter() if now is None else now
        expired = []
        for key, dr in list(self._draining.items()):
            for eng, deadline in list(dr):
                started = deadline - self.drain_deadline_s
                if not eng.has_work():
                    dr.remove((eng, deadline))
                    self._retire_drained(key, eng, now, started, "drained")
                elif now >= deadline:
                    dr.remove((eng, deadline))
                    evac = eng.evacuate()
                    if evac:
                        expired.append((key, evac))
                    self._retire_drained(key, eng, now, started,
                                         "drain-timeout")
            if not dr:
                del self._draining[key]
        return expired

    def _retire_drained(self, key: _Key, eng: InferenceEngine, now: float,
                        started: float, kind: str) -> None:
        model, backend = key
        self._settle_meter(eng)
        n = len(self._replicas[key])
        self.events.append(ScaleEvent(now, model, backend, n + 1, n,
                                      kind, 0.0))
        if self.obs is not None:
            self.obs.registry.histogram(
                "drain_s", model,
                bounds=(0.01, 0.1, 0.5, 1.0, 5.0, 30.0)).observe(
                    max(0.0, now - started))
            self.obs.events.append("scale", t=now, model=model,
                                   backend=backend, before=n + 1, after=n,
                                   kind=kind, duration_s=0.0)
            self._update_memory_gauges(model, now)
            self._health_gauges(model)

    def _update_memory_gauges(self, model: str, now: float) -> None:
        """Refresh ``hbm_resident_bytes`` for ``model``: params + KV
        tensors summed over every live replica (all backends). Cheap —
        shape metadata only — and called on scale transitions, not per
        step.  Stamped with the caller's scale clock ``now`` so
        sim-clock drivers don't leak wall time into the gauge."""
        if self.obs is None:
            return
        total = float(sum(e.resident_bytes() for b in self.reg.backends
                          for e in self._replicas[(model, b)]))
        self.obs.registry.gauge("hbm_resident_bytes", model).set(
            total, stamp=now)

    def kv_bytes(self, model: str) -> Optional[Tuple[int, int]]:
        """(used, free) KV-pool bytes over every live replica of
        ``model``; None with no live replicas."""
        reps = [e for b in self.reg.backends
                for e in self._replicas[(model, b)]]
        if not reps:
            return None
        pairs = [e.kv_pool_bytes() for e in reps]
        return sum(u for u, _ in pairs), sum(f for _, f in pairs)
