"""Replica pools: real engine lifecycle for the concurrent serve plane.

One ``ReplicaPool`` owns every live ``InferenceEngine`` replica in the
process, keyed by (model, backend) service. Spin-up is genuinely
expensive (param init/load + XLA compile) and measured; two warm layers
cut it down:

  * param cache — model weights stay resident after scale-to-zero (the
    paper's "warm pool"), so a re-spin skips ``init_model``;
  * code cache  — the jitted prefill/decode executables for a service
    are shared across its replicas and survive scale-to-zero, so only
    the FIRST replica of a service ever pays XLA compile (replica fork,
    analogous to reusing a baked engine image).

``scale()`` has exactly the ``scale_cb`` signature ``Orchestrator``
(Algorithm 1) calls with, so the same Spin control loop that drives the
discrete-event simulator drives these real engines. Every lifecycle
action is recorded as a ``ScaleEvent`` — the measured cold/warm start
log that calibrates the simulator's constants.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import jax

from repro.models import init_model
from repro.models.transformer import supports_paged
from repro.serving.backend import BACKENDS
from repro.serving.engine import (DEFAULT_BLOCK_SIZE, InferenceEngine,
                                  PagedInferenceEngine, Request, SpecConfig,
                                  SpecDraft, compile_fns, compile_paged_fns)
from repro.serving.sampling import SamplingParams

_Key = Tuple[str, str]


@dataclass
class ScaleEvent:
    t: float                 # wall time (perf_counter) the action started
    model: str
    backend: str
    before: int              # replicas before
    after: int               # replicas after
    kind: str                # spin-cold | spin-warm | down | zero
    duration_s: float        # blocking cost of the action

    def __str__(self) -> str:
        return (f"[{self.kind:>9s}] {self.model}/{self.backend} "
                f"{self.before}->{self.after} ({self.duration_s:.3f}s)")


class ReplicaPool:
    """All live engine replicas, plus the warm param/code caches."""

    def __init__(self, models: Dict[str, object], registry,
                 max_seq: int = 256, seed: int = 0, paged="auto",
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 chunk_tokens: Optional[int] = None,
                 step_token_budget: Optional[int] = None,
                 decode_burst: int = 1, obs=None,
                 spec: Optional[SpecConfig] = None):
        self.models = models
        self.obs = obs                # Observability bundle (optional)
        self.reg = registry
        self.max_seq = max_seq
        self.seed = seed
        # paged KV-cache plane: "auto" pages every model family that
        # supports it (GQA transformer trunk), False forces dense engines
        self.paged = paged
        self.block_size = block_size
        # continuous-batching knobs threaded into every spun engine:
        # prefill chunk bound + per-step token budget (None: whole-prompt
        # prefill / unbounded step, the pre-chunking behavior), plus the
        # opt-in decode-burst depth (K fused decode iterations per step
        # when no prefill backlog is pending; 1 = stepwise)
        self.chunk_tokens = chunk_tokens
        self.step_token_budget = step_token_budget
        self.decode_burst = decode_burst
        # speculative decoding: one SpecConfig applies pool-wide; each
        # spun engine gets a resolved SpecDraft (draft params share the
        # warm param cache) and decides co-residency itself — a target
        # the draft can't pair with (vocab mismatch, KV pressure, or the
        # draft arch IS the target) falls back to plain fused stepwise
        self.spec = spec
        self._replicas: Dict[_Key, List[InferenceEngine]] = {
            (m, b): [] for m in models for b in registry.backends}
        self._params: Dict[str, object] = {}       # warm weights per model
        self._code: Dict[_Key, object] = {}        # compiled fns per service
        self.events: List[ScaleEvent] = []
        # (label, seconds) — same contract as Gateway.cold_starts
        self.cold_starts: List[Tuple[str, float]] = []

    def _use_paged(self, model: str, backend: str) -> bool:
        """paged="auto": follow the backend profile (vllm/tgi page, trt
        keeps the dense static cache) for models whose family supports
        it; True forces paging everywhere; False forces dense."""
        if self.paged is False:
            return False
        ok = supports_paged(self.models[model]) and \
            self.max_seq % self.block_size == 0
        if self.paged == "auto":
            return ok and BACKENDS[backend].paged
        if not ok:
            raise ValueError(f"{model}: paged engines unsupported")
        return True

    # -- inspection ----------------------------------------------------------
    def replicas(self, model: str, backend: str) -> List[InferenceEngine]:
        return self._replicas[(model, backend)]

    def engines(self) -> Iterator[Tuple[_Key, InferenceEngine]]:
        for key, reps in self._replicas.items():
            for eng in reps:
                yield key, eng

    def free_slots(self, model: str, backend: str) -> int:
        return sum(e.free_slots() for e in self._replicas[(model, backend)])

    def total_replicas(self) -> int:
        return sum(len(r) for r in self._replicas.values())

    def has_params(self, model: str) -> bool:
        return model in self._params

    # -- paged KV-cache plane inspection ---------------------------------
    def paged_replicas(self, model: str, backend: str
                       ) -> List[PagedInferenceEngine]:
        return [e for e in self._replicas[(model, backend)] if e.paged]

    def kv_free_frac(self, model: str, backend: str) -> float:
        """Best allocatable block headroom across the service's paged
        replicas (1.0 for dense services / no live replicas — nothing to
        shed on)."""
        reps = self.paged_replicas(model, backend)
        if not reps:
            return 1.0
        return max(e.kv_free_frac() for e in reps)

    def kv_bound(self, model: str, backend: str) -> bool:
        """True when KV blocks — not decode slots — are the binding
        admission resource: compute sits idle while the pool can't back
        another sequence. A fully-leased pool with fully-busy slots is
        ordinary queueing, not block starvation."""
        reps = self.paged_replicas(model, backend)
        if not reps:
            return False
        slot_cap = sum(e.idle_slots() for e in reps)
        block_cap = sum(e.block_capacity() for e in reps)
        return block_cap < slot_cap

    def kv_stats(self, model: str) -> Optional[Dict[str, float]]:
        """Pool occupancy / prefix-cache telemetry aggregated over every
        live paged replica of ``model`` (all backend columns); None when
        the model has no live paged replicas."""
        reps = [e for b in self.reg.backends
                for e in self.paged_replicas(model, b)]
        if not reps:
            return None
        hit = sum(e.hit_tokens for e in reps)
        seen = sum(e.prompt_tokens for e in reps)

        def pressure(e) -> float:
            # bytes-grounded: fraction of the replica's KV-pool BYTES
            # that cannot back a new sequence (evictable prefix-cache
            # bytes are reclaimable, so they count as headroom)
            cap = e.pool.capacity_bytes
            if cap <= 0:
                return 1.0 - e.kv_free_frac()     # geometry not published
            free = e.pool.num_free
            if e.prefix:
                free += e.prefix.evictable_blocks()
            return 1.0 - (free * e.pool.bytes_per_block) / cap

        return {
            # pressure: headroom of the LEAST-squeezed replica — high
            # only when every replica is out of allocatable KV bytes
            "kv_pressure": min(pressure(e) for e in reps),
            "kv_occupancy": max(e.kv_used_frac() for e in reps),
            "kv_hit_rate": hit / seen if seen else 0.0,
            "kv_free_blocks": float(sum(e.pool.num_free for e in reps)),
        }

    def prefix_peek(self, model: str, backend: str, req: Request) -> int:
        """Best cached-prefix reuse (tokens) any replica offers ``req``."""
        reps = self.paged_replicas(model, backend)
        return max((e.prefix_peek(req) for e in reps), default=0)

    def backlog_tokens(self, model: str) -> int:
        """Prefill backlog in TOKENS across every live replica of
        ``model`` (engine-internal queues + unfilled prefill cursors) —
        the load measure that sees a half-prefilled 8k prompt where a
        free-slot count sees an almost-idle engine."""
        return sum(e.pending_tokens() for b in self.reg.backends
                   for e in self._replicas[(model, b)])

    # -- lifecycle (Orchestrator scale_cb target) -----------------------------
    def scale(self, model: str, backend: str, replicas: int,
              now: Optional[float] = None) -> int:
        """Bring the service to ``replicas`` live engines (blocking; real
        spin-up cost is paid inline and measured). Returns the achieved
        replica count — scale-down skips replicas with in-flight work."""
        now = time.perf_counter() if now is None else now
        entry = self.reg.entry(model, backend)
        entry.accrue(now)
        replicas = max(0, replicas)
        while len(self._replicas[(model, backend)]) < replicas:
            self._spin_up(model, backend, now)
        if len(self._replicas[(model, backend)]) > replicas:
            self._spin_down(model, backend, replicas, now)
        return len(self._replicas[(model, backend)])

    def evict(self, model: str) -> None:
        """Drop the warm param + code caches — next spin is a true cold."""
        self._params.pop(model, None)
        for key in [k for k in self._code if k[0] == model]:
            del self._code[key]
        for (m, _), e in self.reg.matrix.items():
            if m == model:
                e.warm = 0

    def _spec_draft(self, model: str) -> Optional[SpecDraft]:
        """Resolve the pool's SpecConfig into a SpecDraft for ``model``
        (None when spec is off or the draft arch IS the target — a model
        never drafts for itself). Draft weights ride the same warm param
        cache as serving models, so scale-to-zero keeps them resident."""
        if self.spec is None or self.spec.draft_arch == model:
            return None
        arch = self.spec.draft_arch
        dcfg = self.models.get(arch)
        if dcfg is None:
            import dataclasses

            from repro.configs.registry import ARCHS
            if arch not in ARCHS:
                raise ValueError(f"unknown spec draft arch {arch!r}")
            dcfg = dataclasses.replace(ARCHS[arch].reduced(),
                                       dtype=self.models[model].dtype)
        if arch not in self._params:
            self._params[arch] = init_model(dcfg, jax.random.PRNGKey(self.seed))
        return SpecDraft(cfg=dcfg, params=self._params[arch], k=self.spec.k)

    # -- internals -------------------------------------------------------
    def _spin_up(self, model: str, backend: str, now: float) -> None:
        key = (model, backend)
        reps = self._replicas[key]
        # servelint: disable=SL001 -- real wall interval: spin-up duration
        t0 = time.perf_counter()
        cfg = self.models[model]
        warm = model in self._params and key in self._code
        use_paged = self._use_paged(model, backend)
        if model not in self._params:
            self._params[model] = init_model(cfg, jax.random.PRNGKey(self.seed))
        if key not in self._code:
            self._code[key] = (
                compile_paged_fns(cfg, BACKENDS[backend], self.max_seq,
                                  self.block_size) if use_paged
                else compile_fns(cfg, BACKENDS[backend], self.max_seq))
        kw = dict(max_seq=self.max_seq,
                  seed=self.seed + 101 * (len(reps) + 1),
                  fns=self._code[key],
                  chunk_tokens=self.chunk_tokens,
                  step_token_budget=self.step_token_budget,
                  decode_burst=self.decode_burst,
                  spec=self._spec_draft(model),
                  obs=(self.obs.engine_obs(model, backend)
                       if self.obs is not None else None))
        if use_paged:
            eng = PagedInferenceEngine(cfg, self._params[model],
                                       BACKENDS[backend],
                                       block_size=self.block_size, **kw)
        else:
            eng = InferenceEngine(cfg, self._params[model], BACKENDS[backend],
                                  **kw)
        # trigger compile/execute of the step functions before the replica
        # counts as live (the dominant real cold-start cost when cold) —
        # with obs muted, so compile-bound probe steps never land in the
        # engine step-duration histograms
        probe_obs, eng._obs = eng._obs, None
        eng.run([Request(uid=-1, tokens=[1, 2, 3],
                         sampling=SamplingParams(max_new_tokens=2))])
        eng._obs = probe_obs
        # servelint: disable=SL001 -- real wall interval: spin-up duration
        dur = time.perf_counter() - t0
        reps.append(eng)
        entry = self.reg.entry(model, backend)
        entry.replicas = len(reps)
        entry.warm = 0
        kind = "spin-warm" if warm else "spin-cold"
        self.events.append(ScaleEvent(now, model, backend, len(reps) - 1,
                                      len(reps), kind, dur))
        self.cold_starts.append(
            (f"{model}/{backend}/{'warm' if warm else 'cold'}", dur))
        if self.obs is not None:
            self.obs.registry.histogram(
                "cold_start_s" if not warm else "warm_start_s",
                model).observe(dur)
            self.obs.events.append("scale", t=now, model=model,
                                   backend=backend, before=len(reps) - 1,
                                   after=len(reps), kind=kind,
                                   duration_s=dur)
            # open this replica's chip-second meter: the spin window
            # (param build + compile + probes) is COLD chip-seconds; the
            # metered clock starts now. perf_counter domain throughout —
            # the same clock engine.step() stamps with.
            eng._obs.meter = self.obs.ledger.replica_up(
                model, backend, chips=entry.cost.chips, cold_s=dur,
                t=time.perf_counter())  # servelint: disable=SL001 -- ledger is perf_counter domain (engine.step stamps feed it)
            self._update_memory_gauges(model, now)

    def _spin_down(self, model: str, backend: str, target: int,
                   now: float) -> None:
        key = (model, backend)
        reps = self._replicas[key]
        before = len(reps)
        # retire idle replicas only — never kill in-flight work (the
        # orchestrator's idle branch already requires model_active == 0,
        # this guards the demand path and direct callers too)
        idle = [e for e in reps if not e.has_work()]
        for eng in idle[:max(0, before - target)]:
            reps.remove(eng)
            if (self.obs is not None and eng._obs is not None
                    and eng._obs.meter is not None):
                # close the meter: trailing idle accrues until here, the
                # reclaim point scale-to-zero exists to reach
                self.obs.ledger.replica_down(
                    eng._obs.meter,
                    time.perf_counter())  # servelint: disable=SL001 -- ledger is perf_counter domain (engine.step stamps feed it)
        entry = self.reg.entry(model, backend)
        entry.replicas = len(reps)
        entry.warm = 1 if (not reps and model in self._params) else 0
        if len(reps) != before:
            kind = "zero" if not reps else "down"
            self.events.append(ScaleEvent(now, model, backend, before,
                                          len(reps), kind, 0.0))
            if self.obs is not None:
                self.obs.events.append("scale", t=now, model=model,
                                       backend=backend, before=before,
                                       after=len(reps), kind=kind,
                                       duration_s=0.0)
                self._update_memory_gauges(model, now)

    def _update_memory_gauges(self, model: str, now: float) -> None:
        """Refresh ``hbm_resident_bytes`` for ``model``: params + KV
        tensors summed over every live replica (all backends). Cheap —
        shape metadata only — and called on scale transitions, not per
        step.  Stamped with the caller's scale clock ``now`` so
        sim-clock drivers don't leak wall time into the gauge."""
        if self.obs is None:
            return
        total = float(sum(e.resident_bytes() for b in self.reg.backends
                          for e in self._replicas[(model, b)]))
        self.obs.registry.gauge("hbm_resident_bytes", model).set(
            total, stamp=now)

    def kv_bytes(self, model: str) -> Optional[Tuple[int, int]]:
        """(used, free) KV-pool bytes over every live replica of
        ``model``; None with no live replicas."""
        reps = [e for b in self.reg.backends
                for e in self._replicas[(model, b)]]
        if not reps:
            return None
        pairs = [e.kv_pool_bytes() for e in reps]
        return sum(u for u, _ in pairs), sum(f for _, f in pairs)
