"""Seeded, schedulable fault injection for the serve plane.

The chaos substrate every fault-tolerance test and benchmark runs on: a
``FaultPlan`` is a declarative schedule of failures — step exceptions,
spin-up failures, stragglers, KV-allocation refusals — targeted at
chosen services/replicas/steps, and DETERMINISTIC: the same plan with
the same seed fires the same faults on the same (replica, step) pairs
every run, which is what lets tier-1 assert that a recovered completion
equals the fault-free one token-for-token.

Threading: ``GatewayConfig.faults`` -> ``ReplicaPool(faults=...)`` ->
each spun engine gets its own ``FaultInjector`` (bound to the replica's
service + incarnation number). The injector's ``begin_step()`` hook
runs at the TOP of ``engine.step()`` — before any device work — so an
injected ``step_error`` leaves the engine's host/device bookkeeping
exactly as the previous step left it (a "clean" crash; the containment
layer distinguishes these from mid-step poisonings). ``spin_fail`` is
consulted by the pool before it pays for a spin-up; ``kv_alloc_fail``
makes the engine refuse admissions for the step (the paged pool's
out-of-blocks behavior, injectable on demand); ``straggler`` sleeps
``delay_s`` per fired step (a slow replica, not a dead one).

Replicas are identified by INCARNATION: the Nth engine ever spun for a
(model, backend) service, counting from 0 across quarantines and
scale-downs — so "kill replica 0's substitute" is expressible as
``replica=1``.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from fnmatch import fnmatch
from typing import List, Optional, Tuple

KINDS = ("step_error", "spin_fail", "straggler", "kv_alloc_fail")


class InjectedFault(RuntimeError):
    """Raised by the injection hook — a scheduled, clean step failure."""


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled failure mode.

    ``at_step`` fires deterministically on that step number (1-based,
    per-replica) for ``for_steps`` consecutive steps; ``rate`` instead
    fires per-step Bernoulli draws from the spec's own seeded stream
    (still reproducible). ``count`` caps total firings per replica.
    ``replica`` selects one incarnation (None: every matching replica).
    """
    kind: str                       # one of KINDS
    model: str = "*"                # fnmatch pattern
    backend: str = "*"              # fnmatch pattern
    replica: Optional[int] = None   # incarnation index (None: any)
    at_step: Optional[int] = None   # 1-based engine-step number
    for_steps: int = 1              # consecutive steps from at_step
    rate: float = 0.0               # per-step probability when at_step is None
    delay_s: float = 0.0            # straggler: injected wall latency
    count: Optional[int] = None     # max firings per replica

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")

    def matches(self, model: str, backend: str, incarnation: int) -> bool:
        return (fnmatch(model, self.model) and fnmatch(backend, self.backend)
                and (self.replica is None or self.replica == incarnation))


class FaultInjector:
    """Per-replica injection state: a step counter plus one seeded RNG
    stream per matching spec, so firings never depend on what OTHER
    replicas or specs did."""

    def __init__(self, plan: "FaultPlan", model: str, backend: str,
                 incarnation: int,
                 specs: List[Tuple[int, FaultSpec]]):
        self.plan = plan
        self.model = model
        self.backend = backend
        self.incarnation = incarnation
        self.step_no = 0
        self.deny_kv = False            # set for the step by kv_alloc_fail
        self._specs = specs             # (plan index, spec) pairs
        self._fired_n = {i: 0 for i, _ in specs}
        self._rng = {
            i: random.Random(f"{plan.seed}|{i}|{model}|{backend}|"
                             f"{incarnation}")
            for i, s in specs if s.at_step is None}

    def begin_step(self) -> List[str]:
        """Advance the step counter and resolve this step's faults.
        Returns the fired kinds (caller raises on ``step_error`` after
        booking its metrics); sleeps stragglers inline; arms ``deny_kv``
        for the step."""
        self.step_no += 1
        self.deny_kv = False
        fired: List[FaultSpec] = []
        for i, spec in self._specs:
            if spec.count is not None and self._fired_n[i] >= spec.count:
                continue
            if spec.at_step is not None:
                hit = (spec.at_step <= self.step_no
                       < spec.at_step + spec.for_steps)
            else:
                hit = (spec.rate > 0.0
                       and self._rng[i].random() < spec.rate)
            if not hit:
                continue
            self._fired_n[i] += 1
            fired.append(spec)
            self.plan.fired.append((self.model, self.backend,
                                    self.incarnation, self.step_no,
                                    spec.kind))
        for spec in fired:
            if spec.kind == "straggler" and spec.delay_s > 0.0:
                time.sleep(spec.delay_s)
            elif spec.kind == "kv_alloc_fail":
                self.deny_kv = True
        return [s.kind for s in fired]


@dataclass
class FaultPlan:
    """A seeded schedule of ``FaultSpec``s plus the log of what fired
    (``fired``: (model, backend, incarnation, step, kind) tuples)."""
    specs: List[FaultSpec] = field(default_factory=list)
    seed: int = 0
    fired: List[Tuple[str, str, int, int, str]] = field(default_factory=list)

    def injector(self, model: str, backend: str,
                 incarnation: int) -> Optional[FaultInjector]:
        """Build the per-replica injector, or None when no spec can ever
        fire on this replica — the engine then skips the hook entirely."""
        specs = [(i, s) for i, s in enumerate(self.specs)
                 if s.kind != "spin_fail"
                 and s.matches(model, backend, incarnation)]
        if not specs:
            return None
        return FaultInjector(self, model, backend, incarnation, specs)

    def spin_fails(self, model: str, backend: str, incarnation: int) -> bool:
        """Should this spin-up attempt fail? Consulted by the pool
        BEFORE it pays for param init/compile. ``at_step``/``rate`` are
        reinterpreted per-attempt: attempt number == incarnation."""
        for i, s in enumerate(self.specs):
            if s.kind != "spin_fail" or not s.matches(model, backend,
                                                      incarnation):
                continue
            if s.count is not None:
                used = sum(1 for f in self.fired if f[4] == "spin_fail"
                           and (f[0], f[1]) == (model, backend))
                if used >= s.count:
                    continue
            if s.rate > 0.0:
                rng = random.Random(f"{self.seed}|{i}|{model}|{backend}|"
                                    f"{incarnation}")
                if rng.random() >= s.rate:
                    continue
            self.fired.append((model, backend, incarnation, 0, "spin_fail"))
            return True
        return False
