"""Request scheduler: bounded admission queues + the step-driven serve loop.

Sits between the Pick layer (router + Algorithm-2 policy, which choose a
(model, backend) service per request) and the ``ReplicaPool`` of real
engines. Responsibilities:

  * per-service admission queues with a bounded depth measured in BOTH
    requests and TOKENS (``max_queue_tokens``): one 8k-token prompt
    loads a queue like hundreds of chat turns, so counting requests
    alone hides the backlog that actually determines waiting time under
    chunked prefill. Beyond either bound requests are SHED at admission
    (backpressure instead of unbounded latency collapse). Queues are
    PRIORITY-ordered: dispatch serves the highest priority class first
    (FIFO within a class), and under pressure a full queue sheds
    strictly low-before-high — an arriving high-priority request evicts
    the newest queued request of the lowest class rather than being
    rejected. Every shed is a structured result (``GenResult.shed``)
    delivered through the serve loop, never a silent drop;
  * deadline-aware dispatch: queued requests already past their deadline
    are dropped before ever touching an engine slot;
  * cancellation: ``cancel()`` aborts a request wherever it lives —
    still queued (removed before touching a slot) or mid-decode (the
    engine frees its slot and KV blocks the same call);
  * scale-from-zero on demand: work queued on a service with no live
    replicas spins one up (the Orchestrator adds capacity beyond that);
  * the serve loop: ``step()`` admits queued work into free slots (least
    loaded replica first) and runs ONE decode iteration on every engine
    with work — iteration-level continuous batching across the whole
    pool, so many requests genuinely overlap;
  * KV-cache awareness (paged services): queued requests with the
    largest cached-prefix reuse are dispatched first (they prefill the
    least and free their slot soonest), placement prefers the replica
    whose radix cache holds the request's prefix, and a block-watermark
    shed policy tightens the admission queue when the pool runs dry —
    backpressure arrives BEFORE the engines thrash on eviction.

The scheduler also keeps the registry's ``queued``/``active_requests``
live and reports finish latencies plus KV pool occupancy / prefix
hit-rate gauges to telemetry, which is exactly what Algorithm 1 reads on
each tick.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Deque, Dict, List, Optional, Tuple

from repro.serving.engine import GenResult, Request
from repro.serving.replica_pool import ReplicaPool

_Key = Tuple[str, str]


@dataclass
class SchedulerConfig:
    max_queue_depth: int = 64     # per-service bound; beyond this we shed
    # per-service queue bound in TOKENS (prompt tokens waiting to
    # prefill) — the request bound's blind spot. None disables.
    max_queue_tokens: Optional[int] = 16384
    shed_expired: bool = True     # drop queued requests already past deadline
    spin_on_demand: bool = True   # scale 0->1 when work queues on a dead svc
    prefix_aware: bool = True     # dispatch best-cached-prefix first
    block_watermark: float = 0.05  # free-block frac below which we shed early
    watermark_depth_div: int = 8  # queue depth divisor under block pressure
    # fault containment: a replica whose step raises is reported to the
    # pool's circuit breaker (degrade -> quarantine) and its in-flight
    # requests are RESUBMITTED — deterministic retry. False re-raises
    # (the chaos bench's no-containment baseline).
    contain_failures: bool = True
    max_retries: int = 3          # resubmissions per request before FAILED
    retry_backoff_s: float = 0.0  # linear backoff between resubmissions
    # "replay" re-runs the ORIGINAL request (same uid -> same per-request
    # PRNG stream, same served prompt -> bit-identical computation) and
    # suppresses the already-delivered stream deltas: token-for-token
    # identical to the unfailed run BY CONSTRUCTION, greedy and seeded
    # stochastic alike. "chain" instead prefills the emitted tokens onto
    # the prompt (the session-chaining trick) and resumes the PRNG draw
    # counter past them — cheaper (no re-decode), and exact whenever the
    # chained KV is served verbatim from the prefix cache; but KV it
    # must RECOMPUTE goes through prefill under different bucket shapes
    # than the baseline's decode steps, and that numeric drift can flip
    # a near-tie for stochastic sampling (greedy argmax is robust in
    # practice). The determinism guarantee is only unconditional under
    # "replay", so that is the default.
    retry_mode: str = "replay"    # "replay" | "chain"


@dataclass
class SchedStats:
    submitted: int = 0
    shed: int = 0                 # rejected/evicted at admission
    shed_blocks: int = 0          # ...of which under KV block pressure
    shed_tokens: int = 0          # ...of which over the token bound
    preempted: int = 0            # ...of which queued low-priority evictions
    expired: int = 0              # dropped from queue past deadline
    cancelled: int = 0            # aborted by the caller
    dispatched: int = 0
    completed: int = 0
    steps: int = 0
    retries: int = 0              # containment resubmissions
    quarantines: int = 0          # replicas quarantined via this scheduler
    failed: int = 0               # retry budget exhausted


@dataclass
class _RetryCtx:
    """Per-uid retry bookkeeping. ``prior`` is the longest token run
    already DELIVERED to the caller across attempts (replay mode) or the
    accumulated chain (chain mode); ``prompt_len0`` is the original
    served prompt length (chain mode grows the request's tokens);
    ``to_skip`` counts stream deltas the current replay attempt must
    suppress — the caller already received them before the failure."""
    prior: List[int]
    retries: int
    prompt_len0: int
    to_skip: int = 0


class RequestScheduler:
    def __init__(self, pool: ReplicaPool, registry, telemetry,
                 cfg: Optional[SchedulerConfig] = None, obs=None):
        self.pool = pool
        self.reg = registry
        self.tel = telemetry
        self.cfg = cfg or SchedulerConfig()
        self._obs = obs               # Observability bundle (optional)
        self._queues: Dict[_Key, Deque[Request]] = {
            key: deque() for key in pool._replicas}
        # requests resolved OFF the engines (deadline-expired, priority-
        # evicted): surfaced as structured results on the next step
        self._reaped: List[Tuple[_Key, GenResult]] = []
        # (uid, token) streaming increments of the latest step
        self._deltas: List[Tuple[int, int]] = []
        # uid -> retry bookkeeping for requests resubmitted after a
        # replica failure (popped when the final result flushes)
        self._retry_ctx: Dict[int, _RetryCtx] = {}
        self.stats = SchedStats()

    def _note(self, event: str, model: str, now: Optional[float],
              **fields) -> None:
        """Structured decision record: every shed / preempt / expire /
        cancel lands in the event log AND a per-model counter, so
        control-loop behavior is reconstructable after the fact."""
        if self._obs is None:
            return
        self._obs.registry.counter("sched_" + event, model).inc()
        self._obs.events.append(event, t=now, model=model, **fields)

    # -- admission ----------------------------------------------------------
    def enqueue(self, model: str, backend: str, req: Request,
                now: Optional[float] = None) -> bool:
        """Admit a routed request. Returns False if shed (queue full and
        nothing of lower priority to evict). When the queue is full but
        holds a LOWER-priority request, that one is evicted instead
        (shed low before high) and surfaced as a ``shed`` result."""
        key = (model, backend)
        q = self._queues[key]
        self.stats.submitted += 1
        # resolve the clock ONCE, up front: a shed below this point must
        # log the caller's (possibly simulated) timestamp, not a stray
        # perf_counter interleaved into sim time (the PR-6 bug class)
        now = time.perf_counter() if now is None else now
        # fast path: nothing waiting and a free slot -> straight in
        if not q and self.pool.free_slots(model, backend) > 0:
            self._to_engine(key, req, now)
            self.stats.dispatched += 1
            self._flight_admit(False, now)
            return True
        over_tokens = (self.cfg.max_queue_tokens is not None and q and
                       self._queue_tokens(q) + self._req_tokens(req)
                       > self._token_limit(model, backend))
        if len(q) >= self._depth_limit(model, backend) or over_tokens:
            victims = self._shed_victims(model, backend, q, req)
            if victims is None:
                self.stats.shed += 1
                reason = "queue_full"
                if over_tokens:
                    self.stats.shed_tokens += 1
                    reason = "queue_tokens"
                # block-pressure shed = the TIGHTENED bound did it (an
                # ordinary queue-full shed at max depth is not the pool's)
                elif len(q) < self.cfg.max_queue_depth:
                    self.stats.shed_blocks += 1
                    reason = "block_pressure"
                self._note("shed", model, now, uid=req.uid, reason=reason)
                self._flight_admit(True, now)
                return False
            entry = self.reg.entry(model, backend)
            for victim in victims:
                q.remove(victim)
                res = GenResult(uid=victim.uid,
                                prompt_len=len(victim.tokens), shed=True)
                res.latency = now - victim.arrival_t
                self._reaped.append((key, res))
                self.stats.shed += 1
                self.stats.preempted += 1
                self._note("preempt", model, now, uid=victim.uid,
                           by=req.uid)
            q.append(req)
            entry.queued = max(0, entry.queued - len(victims) + 1)
            self._flight_admit(False, now)
            return True
        q.append(req)
        self.reg.entry(model, backend).queued += 1
        self._flight_admit(False, now)
        return True

    def _flight_admit(self, shed: bool, now: float) -> None:
        """Feed the flight recorder's shed-storm trigger."""
        if self._obs is not None and self._obs.flight is not None:
            self._obs.flight.note_admission(shed, now)

    def _shed_victims(self, model: str, backend: str, q: Deque[Request],
                      req: Request) -> Optional[List[Request]]:
        """Queued requests of STRICTLY lower priority classes whose
        eviction makes room for ``req`` under BOTH bounds — lowest class
        first, newest first within a class (FIFO fairness: equal
        priority never preempts). One victim frees a seat; the token
        bound may need several (one 8k prompt displaces many chat
        turns). None when no such set exists — then the ARRIVAL is shed
        and nobody already queued is punished for an infeasible one."""
        cands = [r for r in q if r.priority < req.priority]
        if not cands:
            return None
        cands.sort(key=lambda r: (r.priority, -r.arrival_t))
        token_limit = (self._token_limit(model, backend)
                       if self.cfg.max_queue_tokens is not None else None)
        depth = self._depth_limit(model, backend)
        tokens = self._queue_tokens(q)
        arriving = self._req_tokens(req)
        victims: List[Request] = []
        for r in cands:
            seat_ok = len(q) - len(victims) < depth
            tokens_ok = (token_limit is None
                         or tokens + arriving <= token_limit)
            if seat_ok and tokens_ok:
                return victims
            victims.append(r)
            tokens -= self._req_tokens(r)
        seat_ok = len(q) - len(victims) < depth
        tokens_ok = (token_limit is None
                     or tokens + arriving <= token_limit)
        return victims if seat_ok and tokens_ok else None

    def _under_block_pressure(self, model: str, backend: str) -> bool:
        """True when a paged service's pool is below the free-block
        watermark AND blocks (not slots) are the binding resource —
        compute idle, pool dry. A busy-slots busy-pool burst is ordinary
        queueing, not block starvation."""
        return (self.pool.kv_free_frac(model, backend)
                < self.cfg.block_watermark
                and self.pool.kv_bound(model, backend))

    def _depth_limit(self, model: str, backend: str) -> int:
        """Block-watermark shed policy: under block pressure, queued work
        would only sit behind block-starved admission. Tighten the queue
        bound so callers see backpressure now instead of latency collapse
        later."""
        depth = self.cfg.max_queue_depth
        if self._under_block_pressure(model, backend):
            depth = max(1, depth // self.cfg.watermark_depth_div)
        return depth

    def _token_limit(self, model: str, backend: str) -> int:
        """Token-denominated queue bound, tightened by the same
        watermark divisor under block pressure."""
        limit = self.cfg.max_queue_tokens
        if self._under_block_pressure(model, backend):
            limit = max(1, limit // self.cfg.watermark_depth_div)
        return limit

    def _req_tokens(self, r: Request) -> int:
        """Prompt tokens the engine will actually prefill: engines keep
        only the last ``max_seq - budget - 1`` tokens (budget = decode
        tokens still owed, which shrinks on retries whose emitted chain
        rides in the prompt), so counting a raw oversized prompt would
        shed real work over phantom load."""
        budget = max(r.sampling.max_new_tokens - r.prefix_draws, 1)
        return min(len(r.tokens), max(self.pool.max_seq - budget - 1, 1))

    def _queue_tokens(self, q: Deque[Request]) -> int:
        return sum(self._req_tokens(r) for r in q)

    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def queued_tokens(self) -> int:
        """Total prompt tokens waiting in admission queues — queue depth
        in the unit that predicts prefill work, not request count."""
        return sum(self._queue_tokens(q) for q in self._queues.values())

    def has_work(self) -> bool:
        return (any(self._queues.values()) or bool(self._reaped)
                or any(eng.has_work() for _, eng in self.pool.engines()))

    # -- cancellation ---------------------------------------------------
    def cancel(self, model: str, backend: str, uid: int,
               now: Optional[float] = None) -> Optional[GenResult]:
        """Abort ``uid`` on the given service: removed from the admission
        queue, or cancelled mid-flight on whichever replica holds it
        (slot + KV blocks freed immediately). Returns the partial
        ``GenResult`` (``cancelled=True``), or None if unknown/finished."""
        now = time.perf_counter() if now is None else now
        key = (model, backend)
        q = self._queues[key]
        entry = self.reg.entry(*key)
        for r in q:
            if r.uid == uid:
                q.remove(r)
                entry.queued = max(0, entry.queued - 1)
                res = GenResult(uid=uid, prompt_len=len(r.tokens),
                                cancelled=True)
                res.latency = now - r.arrival_t
                self.stats.cancelled += 1
                self._note("cancel", model, now, uid=uid, where="queue")
                return self._absorb_retries(res)
        # cancel reaches DRAINING replicas too, not just placement —
        # a request riding out a drain is still the caller's to abort
        for eng in self.pool.service_engines(*key):
            res = eng.cancel(uid, now)
            if res is not None:
                entry.active_requests = max(0, entry.active_requests - 1)
                self.stats.cancelled += 1
                self._note("cancel", model, now, uid=uid, where="engine")
                return self._absorb_retries(res)
        return None

    # -- serve loop -----------------------------------------------------
    def dispatch(self, now: float) -> int:
        """Move queued requests into free engine slots (deadline-aware
        FIFO). Spins a replica from zero when demand requires it."""
        moved = 0
        for key, q in self._queues.items():
            if not q:
                continue
            model, backend = key
            entry = self.reg.entry(model, backend)
            # sweep expired requests FIRST: a queue of only-dead work
            # must not pay a spin-up it will never use
            if self.cfg.shed_expired:
                live = [r for r in q if not self._expire(key, r, now)]
                if len(live) != len(q):
                    q.clear()
                    q.extend(live)
                    entry.queued = len(q)
            if not q:
                continue
            if self.cfg.spin_on_demand and not self.pool.replicas(*key):
                self.pool.scale(model, backend, 1, now)
            # dispatch order: priority class first (high before low),
            # then cache-aware within a class — the biggest cached-prefix
            # reuse goes first (it skips most of its prefill, holding its
            # slot for the least time). Stable sort keeps FIFO fairness
            # between equal keys; only worth the radix walks when
            # something can actually dispatch.
            if len(q) > 1 and self.pool.free_slots(model, backend) > 0:
                prefix = (self.cfg.prefix_aware
                          and bool(self.pool.paged_replicas(*key)))
                if prefix or any(r.priority != q[0].priority for r in q):
                    ordered = sorted(q, key=lambda r: (
                        -r.priority,
                        -self.pool.prefix_peek(model, backend, r)
                        if prefix else 0))
                    q.clear()
                    q.extend(ordered)
            # retry backoff: requests still inside their not_before
            # window are held aside (and re-queued in order), never
            # dispatched early and never blocking the requests behind
            held: List[Request] = []
            while q and self.pool.free_slots(model, backend) > 0:
                req = q.popleft()
                if req.not_before > now:
                    held.append(req)
                    continue
                entry.queued = max(0, entry.queued - 1)
                self._to_engine(key, req, now)
                self.stats.dispatched += 1
                moved += 1
            for r in reversed(held):
                q.appendleft(r)
        return moved

    def _expire(self, key: _Key, req: Request, now: float) -> bool:
        if req.deadline_s is None or now - req.arrival_t <= req.deadline_s:
            return False
        res = GenResult(uid=req.uid, prompt_len=len(req.tokens),
                        timed_out=True)
        res.latency = now - req.arrival_t
        self._reaped.append((key, res))
        self.stats.expired += 1
        self._note("expire", key[0], now, uid=req.uid)
        if self._obs is not None and self._obs.flight is not None:
            self._obs.flight.note_expiry(now)
        return True

    def step(self, now: Optional[float] = None) -> List[Tuple[_Key, GenResult]]:
        """One serve-loop iteration over the whole pool: admit queued work,
        run ONE batched decode on every engine with work, reap finished."""
        now = time.perf_counter() if now is None else now
        self.stats.steps += 1
        self.dispatch(now)
        out: List[Tuple[_Key, GenResult]]
        out, self._reaped = self._reaped, []
        out = [(k, self._absorb_retries(r)) for k, r in out]
        self._deltas = []
        flight = self._obs.flight if self._obs is not None else None
        for key, eng in list(self.pool.engines()):
            if not eng.has_work():
                continue
            entry = self.reg.entry(*key)
            try:
                results = eng.step()
            except Exception as exc:
                # the flight ring holds the steps leading INTO the crash;
                # dump before anything else happens to the replica
                if flight is not None:
                    flight.note_exception(key[0], exc, now)
                report = getattr(self.pool, "report_step_failure", None)
                if not self.cfg.contain_failures or report is None:
                    raise
                # containment: the circuit breaker degrades (replica keeps
                # its state, retries next step) or quarantines (replica
                # leaves placement; its in-flight work comes back as an
                # evacuation list we resubmit deterministically). Results
                # and deltas booked BEFORE a mid-step crash are salvaged —
                # their device work completed, and retry dedup means
                # nothing is ever emitted twice.
                self._note("step_error", key[0], now, error=repr(exc))
                evac = report(key[0], key[1], eng, exc, now)
                results = eng.drain_finished()
                self._deltas.extend(self._filter_deltas(eng.drain_deltas()))
                if evac is not None:
                    self.stats.quarantines += 1
                    self._resubmit(key, evac, now)
            else:
                ok = getattr(self.pool, "note_step_ok", None)
                if ok is not None:
                    ok(eng, now)
                self._deltas.extend(self._filter_deltas(eng.drain_deltas()))
            for res in results:
                res = self._absorb_retries(res)
                entry.active_requests = max(0, entry.active_requests - 1)
                # stamp with the step's OWN clock: mixing perf_counter
                # into a simulated `now` skewed the telemetry window
                self.tel.record_latency(key[0], now, res.latency)
                self.stats.completed += 1
                if res.timed_out and flight is not None:
                    flight.note_expiry(now)
                out.append((key, res))
        # draining replicas that emptied (or blew their deadline) retire
        # here; deadline evacuations are resubmitted like quarantines
        drains = getattr(self.pool, "finish_drains", None)
        if drains is not None:
            for dkey, evac in drains(now):
                self._resubmit(dkey, evac, now)
        # paged-plane gauges: pool pressure / occupancy / prefix hit-rate
        # land in the same telemetry the Orchestrator ticks on, so Spin
        # can treat a block-starved service as a loaded one
        for model in {m for m, _ in self._queues}:
            stats = self.pool.kv_stats(model)
            if stats:
                for name, value in stats.items():
                    self.tel.record_gauge(model, name, now, value)
            # token-denominated load: queued prompt tokens + unfilled
            # prefill backlog on the engines — the gauge that actually
            # predicts time-to-first-token under chunked prefill
            qtok = sum(self._queue_tokens(q)
                       for (m, _b), q in self._queues.items() if m == model)
            self.tel.record_gauge(model, "queue_tokens", now, float(qtok))
            self.tel.record_gauge(model, "backlog_tokens", now,
                                  float(qtok + self.pool.backlog_tokens(model)))
            # resident KV bytes, labeled by occupancy state (composite
            # label -> kv_pool_bytes{model=...,state=used|free} in the
            # exposition)
            if self._obs is not None:
                # getattr: stub pools in tests duck-type ReplicaPool
                kv_bytes = getattr(self.pool, "kv_bytes", None)
                kb = kv_bytes(model) if kv_bytes is not None else None
                if kb is not None:
                    used, free = kb
                    reg = self._obs.registry
                    reg.gauge("kv_pool_bytes",
                              f"{model}|state=used").set(float(used), now)
                    reg.gauge("kv_pool_bytes",
                              f"{model}|state=free").set(float(free), now)
        return out

    def drain_deltas(self) -> List[Tuple[int, int]]:
        """Fetch the latest step's (uid, token) streaming increments, in
        generation order per request."""
        out, self._deltas = self._deltas, []
        return out

    # -- fault containment ------------------------------------------------
    def _resubmit(self, key: _Key, evac, now: float) -> None:
        """Deterministic retry: every request evacuated off a failed (or
        drain-expired) replica goes back to the FRONT of its admission
        queue. Under ``retry_mode="replay"`` (default) the ORIGINAL
        request is resubmitted verbatim — same uid, same served prompt —
        so the substitute replica runs a bit-identical computation and
        regenerates the same tokens; deltas the caller already received
        are suppressed on the way out. Under ``"chain"`` the emitted
        tokens are chained onto the prompt and the per-request PRNG draw
        counter advanced past them (``prefix_draws``) — see
        SchedulerConfig for the exactness trade-off. Requests over the
        retry budget become structured FAILED results."""
        model, backend = key
        q = self._queues[key]
        entry = self.reg.entry(*key)
        replay = self.cfg.retry_mode != "chain"
        front: List[Request] = []
        for req, served, emitted in evac:
            entry.active_requests = max(0, entry.active_requests - 1)
            if served is None:
                # still queued inside the engine: requeue verbatim — an
                # evacuation is not a failed ATTEMPT for this request
                front.append(req)
                continue
            ctx = self._retry_ctx.get(req.uid)
            if replay:
                # a replay attempt regenerates from token 0, so the
                # delivered run is the LONGEST seen, not a concatenation
                prior = (ctx.prior if ctx is not None
                         and len(ctx.prior) >= len(emitted)
                         else list(emitted))
                prompt_len0 = (ctx.prompt_len0 if ctx is not None
                               else len(req.tokens))
            else:
                prior = (ctx.prior if ctx is not None else []) + list(emitted)
                prompt_len0 = (ctx.prompt_len0 if ctx is not None
                               else len(served))
            if req.retries >= self.cfg.max_retries:
                # budget exhausted: structured failure carrying every
                # token emitted so far (absorbed when the result flushes)
                self._retry_ctx[req.uid] = _RetryCtx(prior, req.retries,
                                                     prompt_len0)
                res = GenResult(uid=req.uid, prompt_len=len(req.tokens),
                                failed=True)
                res.latency = now - req.arrival_t
                self._reaped.append((key, res))
                self.stats.failed += 1
                self._note("retry_exhausted", model, now, uid=req.uid,
                           retries=req.retries)
                continue
            self._retry_ctx[req.uid] = _RetryCtx(
                prior, req.retries + 1, prompt_len0,
                to_skip=len(prior) if replay else 0)
            if replay:
                nreq = replace(
                    req, retries=req.retries + 1,
                    not_before=now + self.cfg.retry_backoff_s
                    * (req.retries + 1))
            else:
                nreq = replace(
                    req, tokens=list(served) + list(emitted),
                    prefix_draws=req.prefix_draws + len(emitted),
                    retries=req.retries + 1,
                    not_before=now + self.cfg.retry_backoff_s
                    * (req.retries + 1))
            front.append(nreq)
            self.stats.retries += 1
            if self._obs is not None:
                self._obs.registry.counter("retries_total", model).inc()
            self._note("retry", model, now, uid=req.uid,
                       emitted=len(emitted), retries=req.retries + 1)
        for r in reversed(front):
            q.appendleft(r)
        entry.queued += len(front)

    def _absorb_retries(self, res: GenResult) -> GenResult:
        """Fold retry history into a result leaving the scheduler. Replay
        mode: the final attempt regenerated the full token run, so the
        result is already whole unless it died early (budget exhaustion /
        cancel while queued), in which case the longest delivered run is
        restored. Chain mode: tokens emitted on earlier replicas rode in
        the retried prompt, so they are prepended here and ``prompt_len``
        is restored to the ORIGINAL served prompt."""
        ctx = self._retry_ctx.pop(res.uid, None)
        if ctx is None:
            return res
        if (self._obs is not None and not res.failed
                and not res.cancelled and not res.timed_out):
            # a retried request actually finishing = recovery succeeded
            self._obs.registry.counter("retries_recovered_total",
                                       "all").inc()
        if self.cfg.retry_mode != "chain":
            if len(res.new_tokens) < len(ctx.prior):
                res.new_tokens = list(ctx.prior)
        else:
            res.new_tokens = ctx.prior + res.new_tokens
            res.prompt_len = ctx.prompt_len0
            res.cached_tokens = min(res.cached_tokens, ctx.prompt_len0)
        res.retries = ctx.retries
        return res

    def _filter_deltas(self, deltas):
        """Drop stream deltas a replay retry re-generates for tokens the
        caller already received from the failed attempt."""
        if not self._retry_ctx:
            return deltas
        out = []
        for uid, tok in deltas:
            ctx = self._retry_ctx.get(uid)
            if ctx is not None and ctx.to_skip > 0:
                ctx.to_skip -= 1
                continue
            out.append((uid, tok))
        return out

    # -- internals -------------------------------------------------------
    def _to_engine(self, key: _Key, req: Request,
                   now: Optional[float] = None) -> None:
        if self._obs is not None:
            t = time.perf_counter() if now is None else now
            self._obs.registry.histogram(
                "sched_queue_wait_s",
                key[0]).observe(max(0.0, t - req.arrival_t))
        # cache-affine, token-aware, pack-first placement: prefer the
        # replica whose radix cache already holds this request's prefix
        # (its prefill mostly vanishes), then the one with the smallest
        # prefill backlog in TOKENS (two replicas with equal free slots
        # can differ 100x in pending prefill work under chunking), then
        # fill the busiest replica with a free slot. Densest batches
        # extract the most from iteration-level batching, and replicas
        # the pool may retire stay drained.
        cands = [g for g in self.pool.replicas(*key) if g.free_slots() > 0]
        eng = min(cands, key=lambda g: (
            -(g.prefix_peek(req) if g.paged else 0),
            g.pending_tokens(), g.free_slots()))
        eng.submit(req)
        self.reg.entry(*key).active_requests += 1
