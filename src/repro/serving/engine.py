"""In-process inference engine: continuous batching under a token budget.

Real execution (CPU here, TPU mesh in production): one global KV-cache
pool of ``max_batch`` slots. Every ``step()`` spends ONE token budget
across the whole batch — one decode token per active slot, committed
first, plus bounded CHUNKS of pending prefills with what remains. Long
prompts amortize over many steps instead of stalling every in-flight
decode behind a whole-prompt prefill (the head-of-line blocking the old
admit-then-decode split had), which is exactly the iteration-level
discipline vLLM/Sarathi-style chunked prefill uses.

The unified schedule per step:

  1. admission — queued requests claim free slots (state only: the paged
     engine leases its KV blocks here; no model compute);
  2. prefill   — mid-prefill slots advance their cursor by up to
     ``chunk_tokens``, oldest admission first, throttled by what the
     decode tokens left of ``step_token_budget``. A chunk attends the
     slot's cached KV plus itself (causal); the LAST chunk's logits
     sample the request's first token — that is when TTFT is stamped;
  3. decode    — one batched decode over every slot whose prefill is
     complete (including slots that finished in step 2: their first
     token joins this batch, matching the old admit-then-decode flow
     token for token).

The engine reports per-request TTFT / latency / completion, which is
exactly the telemetry the Pick-and-Spin control loop consumes.

Two cache disciplines share the slot/step/chunk machinery:
``InferenceEngine`` keeps the dense per-slot (max_batch, max_seq) cache
(chunks append through ``dense_gather_slot``/``dense_scatter_slot``),
while ``PagedInferenceEngine`` leases fixed-size KV blocks from a global
``kvpool.BlockPool`` with radix prefix reuse and copy-on-write sharing —
admission gated on free blocks, blocks freed the step a request
finishes, prefix hits skipping the cached part of prefill, and every
completed chunk's full blocks registered for reuse as soon as their KV
is valid.

Sampling uses a PER-REQUEST PRNG stream (engine seed x uid x token
index), so a request's sampled tokens never depend on which other
requests share its batch — serve it alone or under load, same tokens.

DEVICE-RESIDENT DECODE HOT PATH: the decode inner loop is ONE fused
jitted step — model decode plus per-row sampling (``sampling.sample_rows``,
greedy and stochastic unified under masks) over persistent device-side
state buffers (last tokens, positions, active mask, per-slot
``SamplingParams`` fields, PRNG uid-keys and draw counters, block tables
on the paged engine), updated by jitted index ops at admission /
activation / reap instead of host ``np`` staging arrays rebuilt and
re-uploaded every step. Only the sampled ``(max_batch,)`` int32 token
ids cross the host boundary per decode iteration — the ``(max_batch,
V)`` logits never leave the device. With ``decode_burst=K`` and no
prefill backlog pending, ``step()`` runs K decode iterations inside one
``lax.scan`` dispatch with on-device EOS/length retirement (deltas
flushed per burst; K bounds how stale a cancel or deadline can go), the
throughput path for ``run()``/offline serving. Burst and stepwise
decoding are token-for-token equivalent under greedy and fixed seeds.

DEVICE-SIDE TERMINATION (both modes): every decode entry point — fused
step, burst, the batched first-token sample, and the speculative verify
— computes the EOS / max_new / out-of-room finish decision ON DEVICE
(``_finish_bits``) and retires the row there; the host receives the
reason bits alongside the token ids and is a pure bookkeeping consumer
(``_consume_reason``), adding only the wall-clock deadline the device
cannot see.

SPECULATIVE DECODING (``spec=SpecDraft(...)``): a small resident draft
model shares the engine's device state — its own KV cache (paged: a
second small ``BlockPool``, slots leased/retired with the target's) —
and each decode step becomes draft-K + one multi-token target verify
(``lm_paged_verify``/``lm_dense_verify``, logits at every fed position).
Acceptance is an on-device prefix mask: at each fed position the target
samples its would-be token with the SAME per-request PRNG key plain
decode would use (``fold_in(key, draws + j)``), a drafted token is
accepted iff it equals that sample, and the emitted tokens are exactly
the target's samples — so spec output is token-for-token identical to
plain decode under greedy AND seeded stochastic sampling by
construction, for ANY draft (only speed varies with draft quality).
Only one ``(max_batch, K+1)`` int32 id matrix (+ reason bits) crosses
to host per verify; the transfer guard stays in force.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_cache, model_decode, model_prefill
from repro.models.attention import (dense_gather_slot, dense_scatter_slot,
                                    paged_gather_ctx, paged_scatter)
from repro.models.transformer import (copy_paged_block, init_paged_cache,
                                      lm_chunk_prefill, lm_dense_verify,
                                      lm_paged_decode, lm_paged_verify,
                                      supports_chunked, supports_paged)
from repro.serving.backend import BackendProfile
from repro.serving.kvpool import BlockPool, RadixPrefixCache
from repro.serving.sampling import SamplingParams, sample_rows


@dataclass
class Request:
    uid: int
    tokens: List[int]
    sampling: SamplingParams
    deadline_s: Optional[float] = None
    arrival_t: float = 0.0
    priority: int = 1                             # api.Priority class (int)
    src_embeds: Optional[np.ndarray] = None       # encdec stub input
    cancelled: bool = False                       # queue tombstone (cancel())
    # deterministic retry-from-prefix: a resubmitted request carries its
    # already-emitted tokens at the END of ``tokens`` and starts its
    # per-request PRNG stream at draw index ``prefix_draws`` — so token
    # N of the recovered run samples with the SAME folded key as token N
    # of the unfailed one. ``max_new_tokens`` stays the ORIGINAL total
    # (the device's draws>=max_new check is absolute).
    prefix_draws: int = 0
    retries: int = 0                              # containment resubmissions
    not_before: float = 0.0                       # retry backoff gate


@dataclass
class GenResult:
    uid: int
    prompt_len: int
    new_tokens: List[int] = field(default_factory=list)
    ttft: float = 0.0
    latency: float = 0.0
    completed: bool = False                       # finished within limits
    timed_out: bool = False
    cancelled: bool = False                       # caller aborted it
    shed: bool = False                            # evicted at admission
    cached_tokens: int = 0                        # prompt tokens from prefix cache
    prefill_chunks: int = 0                       # prefill passes the prompt took
    kv_bytes: int = 0                             # peak KV bytes held (at release)
    drafted_tokens: int = 0                       # spec: draft proposals verified
    accepted_tokens: int = 0                      # spec: drafted tokens committed
    failed: bool = False                          # retry budget exhausted
    retries: int = 0                              # containment resubmissions


@dataclass
class _Slot:
    req: Optional[Request] = None
    res: Optional[GenResult] = None
    pos: int = 0                 # tokens with valid KV (next write position)
    done: bool = True
    # chunked-prefill cursor
    prompt: List[int] = field(default_factory=list)
    filled: int = 0              # prompt tokens cached so far (prefix incl.)
    prefilling: bool = False
    order: int = 0               # admission sequence (FIFO chunk scheduling)
    idx: int = 0                 # batch row (device-state buffer index)
    spec_ok: bool = False        # draft cache co-residency secured


@dataclass
class _PagedSlot(_Slot):
    table: Optional[np.ndarray] = None            # (blocks_per_seq,) int32
    blocks: List[int] = field(default_factory=list)   # ids this req refs
    spec_blocks: List[int] = field(default_factory=list)  # draft-pool leases


def _insert_impl(cache, rcache, slot):
    def put(path, g, r):
        axis = 0 if any(getattr(k, "key", None) == "prefix" for k in path) else 1
        return jax.lax.dynamic_update_slice_in_dim(g, r.astype(g.dtype),
                                                   slot, axis=axis)
    return jax.tree_util.tree_map_with_path(put, cache, rcache)


# ---------------------------------------------------------------------------
# device-resident decode state
#
# One stacked buffer per per-slot quantity the fused decode step needs, so
# the hot loop never rebuilds host arrays: admission/activation/reap touch
# single rows through jitted index ops, and the step itself reads/advances
# everything on device. ``draws`` mirrors ``len(res.new_tokens)`` (the
# PRNG token index), so a row's key for its n-th token is
# fold_in(fold_in(fold_in(seed, uid), n)) — identical to the host-side
# per-request streams this replaces, and independent of batch composition.


def init_device_state(max_batch: int, blocks_per_seq: Optional[int] = None):
    state = {
        "tokens": jnp.zeros((max_batch, 1), jnp.int32),   # last sampled token
        "pos": jnp.zeros((max_batch,), jnp.int32),        # next KV write slot
        "active": jnp.zeros((max_batch,), jnp.bool_),     # decoding rows
        "temp": jnp.zeros((max_batch,), jnp.float32),     # SamplingParams...
        "top_k": jnp.zeros((max_batch,), jnp.int32),
        "top_p": jnp.ones((max_batch,), jnp.float32),
        "key": jnp.zeros((max_batch, 2), jnp.uint32),     # fold_in(seed, uid)
        "draws": jnp.zeros((max_batch,), jnp.int32),      # tokens sampled
        "eos": jnp.full((max_batch,), -1, jnp.int32),     # -1: no eos_id
        "max_new": jnp.zeros((max_batch,), jnp.int32),
    }
    if blocks_per_seq is not None:                        # paged engines
        state["tables"] = jnp.zeros((max_batch, blocks_per_seq), jnp.int32)
    return state


def _occupy_impl(state, slot, base_key, uid, temp, top_k, top_p, eos,
                 max_new, pos0, draws0):
    """Admission index-op: load one row's sampling fields + uid key.
    ``draws0`` resumes a retried request's PRNG stream mid-way: its
    next token samples at draw index ``draws0`` — the index the token
    would have had on the unfailed replica."""
    return dict(
        state,
        tokens=state["tokens"].at[slot].set(0),
        pos=state["pos"].at[slot].set(pos0),
        active=state["active"].at[slot].set(False),
        temp=state["temp"].at[slot].set(temp),
        top_k=state["top_k"].at[slot].set(top_k),
        top_p=state["top_p"].at[slot].set(top_p),
        key=state["key"].at[slot].set(jax.random.fold_in(base_key, uid)),
        draws=state["draws"].at[slot].set(draws0),
        eos=state["eos"].at[slot].set(eos),
        max_new=state["max_new"].at[slot].set(max_new))


def _deactivate_impl(state, slot):
    """Reap index-op: retire one row from the decode batch. The row's
    temperature is zeroed too — a stale temp > 0 on a vacated slot would
    defeat ``sample_rows``'s all-greedy argmax short-circuit for every
    later step until the row is reoccupied."""
    return dict(state, active=state["active"].at[slot].set(False),
                temp=state["temp"].at[slot].set(0.0))


def _first_tokens_impl(state, logits, idx, pos_vals, tables):
    """Batched first-token sampling for every slot whose prefill just
    completed: one fused dispatch samples all of them from their final-
    chunk logits and activates their rows (token, position, draw counter,
    and — paged — block table). ``idx`` entries equal to ``max_batch``
    are pow2-bucket pads: their gathers clip harmlessly and their
    scatters drop."""
    keys = jax.vmap(jax.random.fold_in)(state["key"][idx],
                                        state["draws"][idx])
    toks = sample_rows(logits, state["temp"][idx], state["top_k"][idx],
                       state["top_p"][idx], keys)
    new = dict(
        state,
        tokens=state["tokens"].at[idx, 0].set(toks, mode="drop"),
        pos=state["pos"].at[idx].set(pos_vals, mode="drop"),
        active=state["active"].at[idx].set(True, mode="drop"),
        draws=state["draws"].at[idx].set(state["draws"][idx] + 1,
                                         mode="drop"))
    if tables is not None:
        new["tables"] = state["tables"].at[idx].set(tables, mode="drop")
    return toks, new


def _advance_impl(state, logits):
    """Fused sample-in-step: draw every row's next token ON DEVICE from
    the decode logits (greedy/stochastic unified under masks, per-row
    keys folded from the uid streams) and advance the cursors of active
    rows. The logits are consumed here — they are never materialized on
    host."""
    active = state["active"]
    keys = jax.vmap(jax.random.fold_in)(state["key"], state["draws"])
    nxt = sample_rows(logits, state["temp"], state["top_k"], state["top_p"],
                      keys)
    nxt = jnp.where(active, nxt, 0).astype(jnp.int32)
    state = dict(state,
                 tokens=nxt[:, None],
                 pos=jnp.where(active, state["pos"] + 1, state["pos"]),
                 draws=jnp.where(active, state["draws"] + 1, state["draws"]))
    return nxt, state


# finish-reason bit protocol (device -> host): the host never re-derives
# termination from token values; it consumes these bits verbatim.
FINISH_EOS = 1
FINISH_MAX_NEW = 2
FINISH_ROOM = 4


def _finish_bits(state, nxt, max_seq):
    """On-device termination decision after a token lands — EOS /
    max_new_tokens / out of cache room as a per-row int32 bitmask
    (0: keep decoding). Wall-clock deadlines are the one rule that
    stays host-side (the device has no clock). Applied identically by
    the fused step, the burst scan, the first-token sample and the
    speculative verify, so stepwise and burst serving share one
    termination source of truth."""
    hit_eos = (state["eos"] >= 0) & (nxt == state["eos"])
    full = state["draws"] >= state["max_new"]
    room = state["pos"] >= max_seq - 1
    bits = (jnp.where(hit_eos, FINISH_EOS, 0)
            | jnp.where(full, FINISH_MAX_NEW, 0)
            | jnp.where(room, FINISH_ROOM, 0))
    return jnp.where(state["active"], bits, 0).astype(jnp.int32)


def _retire_impl(state, nxt, max_seq):
    """On-device retirement: drop rows whose finish bits fired."""
    bits = _finish_bits(state, nxt, max_seq)
    return dict(state, active=state["active"] & (bits == 0)), bits


@dataclass(frozen=True)
class CompiledFns:
    """Jitted step functions for one (config, backend, max_seq) service.

    Shareable across replicas: a second replica of a live service reuses
    the first replica's XLA executables, so only the first spin-up of a
    service ever pays compile — the dominant real cold-start cost. The
    replica pool caches these across scale-to-zero (its "code cache").

    ``prefill``/``insert`` are the whole-prompt path (families without a
    chunk-append layout, and ``chunk_tokens=None``); the ``*_slot`` trio
    is the chunk-append path over the dense per-slot cache, compiled only
    when the family supports it.

    The decode hot path is the fused trio: ``fused_step`` (decode +
    in-step sampling, one dispatch per token), ``fused_burst`` (K fused
    iterations under one ``lax.scan`` dispatch; K is a static argument)
    and ``first_tokens`` (batched first-token sampling for prefills
    completing this step). ``occupy``/``deactivate`` are the index ops
    that maintain the device-resident state between steps.
    ``trace_counts`` counts ACTUAL retraces of the fused functions — the
    regression guard that ``step()`` isn't silently recompiling per
    step.
    """
    prefill: object
    decode: object
    insert: object
    gather_slot: object = None
    chunk_prefill: object = None
    scatter_slot: object = None
    fused_step: object = None
    fused_burst: object = None
    first_tokens: object = None
    occupy: object = None
    deactivate: object = None
    trace_counts: object = None


def _fused_fns(step_fn, max_seq: int):
    """Build the fused decode fields of a CompiledFns/PagedCompiledFns
    from ONE per-engine step closure ``step_fn(params, cache, state) ->
    (nxt, cache, state)`` (decode + ``_advance_impl``): ``fused_step``
    jits it with ``_retire_impl`` appended (device-side termination for
    STEPWISE serving too — the host consumes the reason bits instead of
    replaying EOS/length checks), ``fused_burst`` scans it K times with
    the same retirement between iterations — a single source of truth,
    so burst and stepwise can never diverge. The state-maintenance index
    ops are shared too (the state pytree layout differs only by the
    paged ``tables`` leaf, which they pass through untouched)."""
    traces = {"fused_step": 0, "fused_burst": 0}

    def _fused(params, cache, state):
        traces["fused_step"] += 1
        nxt, cache, state = step_fn(params, cache, state)
        state, bits = _retire_impl(state, nxt, max_seq)
        return nxt, bits, cache, state

    def _burst(params, cache, state, k):
        traces["fused_burst"] += 1

        def body(carry, _):
            cache, state = carry
            was = state["active"]
            nxt, cache, state = step_fn(params, cache, state)
            state, bits = _retire_impl(state, nxt, max_seq)
            # -1 marks rows that were not decoding this iteration, so
            # the whole burst transfer stays int32 (ids + reason bits)
            return (cache, state), (jnp.where(was, nxt, -1), bits)

        (cache, state), (toks, bits) = jax.lax.scan(body, (cache, state),
                                                    None, length=k)
        return toks, bits, cache, state

    def _first(state, logits, idx, pos_vals, tables):
        toks, state = _first_tokens_impl(state, logits, idx, pos_vals,
                                         tables)
        # device-side termination for first tokens too: an EOS straight
        # out of prefill (or max_new_tokens=1) retires the row before it
        # ever joins a decode batch. Non-idx active rows re-check their
        # last token — a no-op by invariant (they survived their own
        # step's bits or they would not be active).
        allbits = _finish_bits(state, state["tokens"][:, 0], max_seq)
        state = dict(state, active=state["active"] & (allbits == 0))
        return toks, allbits[idx], state

    return dict(
        fused_step=jax.jit(_fused, donate_argnums=(1, 2)),
        fused_burst=jax.jit(_burst, static_argnums=(3,),
                            donate_argnums=(1, 2)),
        first_tokens=jax.jit(_first, donate_argnums=(0,)),
        occupy=jax.jit(_occupy_impl, donate_argnums=(0,)),
        deactivate=jax.jit(_deactivate_impl, donate_argnums=(0,)),
        trace_counts=traces)


def compile_fns(cfg: ModelConfig, backend: BackendProfile,
                max_seq: int) -> CompiledFns:
    qc = backend.q_chunk

    def _prefill(params, batch):
        return model_prefill(params, cfg, batch, max_seq, q_chunk=qc)

    def _decode(params, token, cache, pos):
        return model_decode(params, cfg, token, cache, pos)

    def _step(params, cache, state):
        # inactive rows park their ignored write at max_seq-1, a position
        # no live request ever stores KV in (prompts are capped at
        # max_seq - max_new - 1 and decode finishes before writing it)
        safe = jnp.where(state["active"], state["pos"], max_seq - 1)
        logits, cache = model_decode(params, cfg, state["tokens"], cache,
                                     safe)
        nxt, state = _advance_impl(state, logits)
        return nxt, cache, state

    extra = _fused_fns(_step, max_seq)
    if supports_chunked(cfg):
        def _chunk(params, tokens, ctx_kv, start, s_real):
            return lm_chunk_prefill(params, cfg, tokens, ctx_kv, start, s_real)

        extra.update(
            gather_slot=jax.jit(dense_gather_slot),
            chunk_prefill=jax.jit(_chunk),
            scatter_slot=jax.jit(dense_scatter_slot, donate_argnums=(0,)))
    return CompiledFns(prefill=jax.jit(_prefill), decode=jax.jit(_decode),
                       insert=jax.jit(_insert_impl, donate_argnums=(0,)),
                       **extra)


@dataclass(frozen=True)
class PagedCompiledFns:
    """Jitted step functions of a paged-cache service (same sharing story
    as ``CompiledFns``: one compile per service, reused across replicas
    and across scale-to-zero).

    Prefill is three functions, and that split is the perf point of the
    paged plane: ``gather`` READS the request's context blocks out of
    the pool (output is O(context)), ``prefill`` runs the model over one
    uncached CHUNK only, and ``scatter`` writes the new KV into the
    request's blocks with the pool buffer DONATED — an in-place O(chunk)
    update. The dense engine's whole-prompt admission rewrites its whole
    (max_batch, max_seq) cache per insert; here the pool is never
    re-materialized.

    The ``fused_*``/``first_tokens``/``occupy``/``deactivate`` fields
    carry the same device-resident decode hot path as ``CompiledFns``
    (the state pytree additionally holds the per-row block tables)."""
    gather: object           # (cache, table_ctx) -> ctx_kv
    prefill: object          # (params, tokens, ctx_kv, start, s_real)
    scatter: object          # (cache, new_kv, table, start, s_real)
    decode: object           # (params, token, cache, tables, pos)
    copy: object             # (cache, src_block, dst_block) — COW
    fused_step: object = None
    fused_burst: object = None
    first_tokens: object = None
    occupy: object = None
    deactivate: object = None
    trace_counts: object = None


def compile_paged_fns(cfg: ModelConfig, backend: BackendProfile,
                      max_seq: int, block_size: int) -> PagedCompiledFns:
    def _prefill(params, tokens, ctx_kv, start, s_real):
        return lm_chunk_prefill(params, cfg, tokens, ctx_kv, start, s_real)

    def _decode(params, token, cache, tables, pos):
        return lm_paged_decode(params, cfg, token, cache, tables, pos)

    def _step(params, cache, state):
        # -1 marks inactive rows: their pool write is dropped entirely
        pos = jnp.where(state["active"], state["pos"], -1)
        logits, cache = lm_paged_decode(params, cfg, state["tokens"], cache,
                                        state["tables"], pos)
        nxt, state = _advance_impl(state, logits)
        return nxt, cache, state

    return PagedCompiledFns(
        gather=jax.jit(paged_gather_ctx),
        prefill=jax.jit(_prefill),
        scatter=jax.jit(paged_scatter, donate_argnums=(0,)),
        decode=jax.jit(_decode, donate_argnums=(2,)),
        copy=jax.jit(copy_paged_block, donate_argnums=(0,)),
        **_fused_fns(_step, max_seq))


# ---------------------------------------------------------------------------
# speculative decoding: resident draft model + on-device verify


@dataclass(frozen=True)
class SpecConfig:
    """Serve-plane speculative-decoding request: which registry arch
    drafts, and how many tokens per verify. Threaded ReplicaPool ->
    GatewayConfig -> ``launch/serve.py --spec-draft/--spec-k``; the pool
    resolves it into a ``SpecDraft`` (config + initialized params) per
    replica."""
    draft_arch: str
    k: int = 4


@dataclass
class SpecDraft:
    """Resolved draft model an engine co-residents with its target:
    the draft's config + params, the drafted-token count K, and (paged)
    an optional draft-pool size override — the KV-pressure knob tests
    use to force the co-residency refusal path."""
    cfg: ModelConfig
    params: object
    k: int = 4
    num_blocks: Optional[int] = None


@dataclass(frozen=True)
class SpecFns:
    """Jitted functions of one (target, draft, K) speculative pair.
    ``step`` is the whole hot path — draft-K (a ``lax.scan`` of small-
    model decodes) + one multi-token target verify + on-device accept/
    emit/retire — in ONE dispatch; the ``gather``/``prefill``/
    ``scatter`` trio runs the draft's whole-prompt prefill into its own
    cache at admission time; ``set_table`` (paged) loads one row of the
    device-resident draft block table."""
    step: object
    gather: object = None
    prefill: object = None
    scatter: object = None
    set_table: object = None
    trace_counts: object = None


def compile_spec_fns(cfg: ModelConfig, dcfg: ModelConfig, max_seq: int,
                     k: int, block_size: Optional[int] = None) -> SpecFns:
    """Compile the draft/verify pair (paged when ``block_size`` is set).

    THE ACCEPTANCE RULE (exactness by construction): the verify forward
    yields target logits at every fed position j (conditioned on the
    true prefix t0, d1..dj). At each position the target samples its
    would-be token ``s_j = sample_rows(logits_j, ..., fold_in(key,
    draws+j))`` — byte-identical to what plain decode would have drawn
    there, greedy or stochastic. Draft d_{j+1} is accepted iff it EQUALS
    s_j, and the emitted tokens are the s_j themselves up to (and
    including) the first non-match — so the output stream never depends
    on the draft at all; the draft only decides how many of the K+1
    computed tokens are committable per dispatch. For stochastic
    sampling this is the rejection rule specialized to proposal ==
    target-with-same-key: the draft samples its OWN logits with the SAME
    per-request keys, so a well-aligned draft agrees with high
    probability and an identity draft agrees always.

    The draft scan runs K+1 iterations: iteration j feeds token j of
    [t0, d1..dK] (writing its KV into the draft cache — iteration K
    exists so d_K's KV lands for the all-accepted case) and samples the
    next draft; the last sample is discarded.
    """
    traces = {"spec_step": 0}
    S = k + 1

    def _span_sample(state, logits):
        """Target samples at all K+1 fed positions with the per-request
        keys plain decode would use (one flattened sample_rows call)."""
        B, V = logits.shape[0], logits.shape[-1]
        di = state["draws"][:, None] + jnp.arange(S)[None, :]
        keys = jax.vmap(jax.vmap(jax.random.fold_in, in_axes=(None, 0)))(
            state["key"], di)
        flat = sample_rows(logits.reshape(B * S, V),
                           jnp.repeat(state["temp"], S),
                           jnp.repeat(state["top_k"], S),
                           jnp.repeat(state["top_p"], S),
                           keys.reshape(B * S, 2))
        return flat.reshape(B, S)

    def _accept_emit(state, s_tok, drafts):
        """On-device accept-prefix + emission mask + retirement.

        ``acc`` = length of the matching draft prefix; candidate j may
        emit if j <= acc AND no earlier emitted candidate finished the
        request (EOS / max_new / room — the same ``_finish_bits`` rules,
        evaluated per candidate position). Returns the (B, S) id matrix
        (-1 past the emitted prefix) and the (B,) reason bits — the only
        buffers that cross to host."""
        active = state["active"]
        offs = jnp.arange(S)[None, :]
        match = (drafts == s_tok[:, :k]).astype(jnp.int32)
        acc = jnp.cumprod(match, axis=1).sum(axis=1)
        allowed = offs <= acc[:, None]
        hit_eos = ((state["eos"][:, None] >= 0)
                   & (s_tok == state["eos"][:, None]))
        full = state["draws"][:, None] + offs + 1 >= state["max_new"][:, None]
        room = state["pos"][:, None] + offs + 1 >= max_seq - 1
        bits = (jnp.where(hit_eos, FINISH_EOS, 0)
                | jnp.where(full, FINISH_MAX_NEW, 0)
                | jnp.where(room, FINISH_ROOM, 0)).astype(jnp.int32)
        stop = (allowed & (bits != 0)).astype(jnp.int32)
        prior = jnp.cumsum(stop, axis=1) - stop       # stops before j
        emit = active[:, None] & allowed & (prior == 0)
        n_emit = emit.sum(axis=1).astype(jnp.int32)   # >= 1 on active rows
        out = jnp.where(emit, s_tok, -1).astype(jnp.int32)
        last = jnp.clip(n_emit - 1, 0, k)[:, None]
        reason = jnp.where(
            active & (jnp.take_along_axis(stop, last, 1)[:, 0] != 0),
            jnp.take_along_axis(bits, last, 1)[:, 0], 0).astype(jnp.int32)
        last_tok = jnp.take_along_axis(out, last, 1)[:, 0]
        state = dict(
            state,
            tokens=jnp.where(active, last_tok,
                             state["tokens"][:, 0])[:, None].astype(jnp.int32),
            pos=jnp.where(active, state["pos"] + n_emit, state["pos"]),
            draws=jnp.where(active, state["draws"] + n_emit, state["draws"]),
            active=active & (reason == 0))
        return out, reason, state

    def _draft_next(state, active, logits, j):
        """Draft's proposal for global draw index draws+j: its own
        logits sampled under the target's key/params for that draw."""
        keys = jax.vmap(jax.random.fold_in)(state["key"],
                                            state["draws"] + j)
        nt = sample_rows(logits, state["temp"], state["top_k"],
                         state["top_p"], keys)
        return jnp.where(active, nt, 0).astype(jnp.int32)

    if block_size is not None:
        def _step(params, dparams, cache, dcache, state, dtables):
            traces["spec_step"] += 1
            active = state["active"]
            pos = jnp.where(active, state["pos"], -1)
            # last position a row may legitimately write: the fed span
            # can overrun a short request's leased blocks (zero-padded
            # tables alias block 0 — another request's KV); emission
            # stops at max_new before any capped-out position matters
            cap = state["pos"] + (state["max_new"] - state["draws"])

            def dbody(carry, j):
                dc, tok, dp = carry
                dpw = jnp.where(active & (dp <= cap), dp, -1)
                logits, dc = lm_paged_decode(dparams, dcfg, tok, dc,
                                             dtables, dpw)
                nt = _draft_next(state, active, logits, j)
                return (dc, nt[:, None], jnp.where(active, dp + 1, dp)), nt

            (dcache, _, _), dseq = jax.lax.scan(
                dbody, (dcache, state["tokens"], pos), jnp.arange(S))
            drafts = dseq[:k].swapaxes(0, 1)          # (B, K): d_1..d_K
            fed = jnp.concatenate([state["tokens"], drafts], axis=1)
            logits, cache = lm_paged_verify(params, cfg, fed, cache,
                                            state["tables"], pos, cap)
            s_tok = _span_sample(state, logits)
            out, reason, state = _accept_emit(state, s_tok, drafts)
            return out, reason, cache, dcache, state

        def _dprefill(dparams, tokens, ctx_kv, start, s_real):
            return lm_chunk_prefill(dparams, dcfg, tokens, ctx_kv, start,
                                    s_real)

        return SpecFns(
            step=jax.jit(_step, donate_argnums=(2, 3, 4)),
            gather=jax.jit(paged_gather_ctx),
            prefill=jax.jit(_dprefill),
            scatter=jax.jit(paged_scatter, donate_argnums=(0,)),
            set_table=jax.jit(lambda tabs, i, row: tabs.at[i].set(row),
                              donate_argnums=(0,)),
            trace_counts=traces)

    def _step(params, dparams, cache, dcache, state):
        traces["spec_step"] += 1
        active = state["active"]

        def dbody(carry, j):
            dc, tok, dp = carry
            safe = jnp.where(active, dp, max_seq - 1)
            logits, dc = model_decode(dparams, dcfg, tok, dc, safe)
            nt = _draft_next(state, active, logits, j)
            return (dc, nt[:, None], jnp.where(active, dp + 1, dp)), nt

        (dcache, _, _), dseq = jax.lax.scan(
            dbody, (dcache, state["tokens"], state["pos"]), jnp.arange(S))
        drafts = dseq[:k].swapaxes(0, 1)
        fed = jnp.concatenate([state["tokens"], drafts], axis=1)
        pos = jnp.where(active, state["pos"], -1)
        logits, cache = lm_dense_verify(params, cfg, fed, cache, pos)
        s_tok = _span_sample(state, logits)
        out, reason, state = _accept_emit(state, s_tok, drafts)
        return out, reason, cache, dcache, state

    def _dprefill(dparams, tokens, ctx_kv, start, s_real):
        return lm_chunk_prefill(dparams, dcfg, tokens, ctx_kv, start, s_real)

    return SpecFns(
        step=jax.jit(_step, donate_argnums=(2, 3, 4)),
        gather=jax.jit(dense_gather_slot),
        prefill=jax.jit(_dprefill),
        scatter=jax.jit(dense_scatter_slot, donate_argnums=(0,)),
        trace_counts=traces)


class InferenceEngine:
    """Continuous-batching engine for one (model x backend) instance.

    ``chunk_tokens`` bounds how many prompt tokens one prefill pass may
    cover (None: whole prompt in one pass). ``step_token_budget`` caps
    the tokens one ``step()`` spends across decode + prefill (None:
    unbounded — decode everything, prefill everything admitted).
    ``decode_burst=K`` (opt-in, default 1) lets a step with NO prefill
    backlog run K fused decode iterations in one device dispatch.
    """

    paged = False

    def __init__(self, cfg: ModelConfig, params, backend: BackendProfile,
                 max_seq: int = 512, seed: int = 0, fns=None,
                 chunk_tokens: Optional[int] = None,
                 step_token_budget: Optional[int] = None,
                 decode_burst: int = 1, obs=None,
                 spec: Optional[SpecDraft] = None, fault=None):
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.max_seq = max_seq
        self.max_batch = backend.max_batch
        # fault injection (repro.serving.faults.FaultInjector): the
        # seeded chaos hook at the top of step(). None (the default) is
        # one attribute test per step. ``poisoned`` flips when a step
        # dies MID-flight — host/device bookkeeping may have diverged,
        # so containment must quarantine rather than re-place on it.
        self._fault = fault
        self.poisoned = False
        # observability (repro.obs.EngineObs): shared metrics registry +
        # request tracer + this engine's service labels. None (the
        # default for standalone engines) keeps every hook a single
        # attribute test — and every hook is HOST-side bookkeeping on
        # values the step already pulled, never a new device sync.
        self._obs = obs
        # 0 means "whole prompt" (the launcher's CLI convention); a raw 0
        # reaching the chunk sizing would stall the cursor forever
        self.chunk_tokens = max(1, chunk_tokens) if chunk_tokens else None
        self.step_token_budget = (max(1, step_token_budget)
                                  if step_token_budget else None)
        self.decode_burst = max(1, decode_burst)
        self._base_key = jax.random.PRNGKey(seed)
        self._slots = [self._make_slot() for _ in range(self.max_batch)]
        for i, s in enumerate(self._slots):
            s.idx = i
        self._queue: Deque[Request] = deque()
        self._queue_tomb = 0                   # cancelled-in-queue count
        # O(1) cancel index: uid -> queued Request, or the _Slot serving it
        self._by_uid: Dict[int, object] = {}
        self._order = 0
        self._kv_dtype = jnp.bfloat16 if backend.kv_dtype == "bfloat16" else jnp.float32
        self.cache = self._init_cache()
        # resident KV bytes from the pool tensors' own shape metadata —
        # int8 quantized pools (k/v int8 + f32 scales) land at their true
        # width. Shape inspection only: no device sync.
        self._cache_bytes = int(sum(
            x.nbytes for x in jax.tree_util.tree_leaves(self.cache)))
        self._register_cache_bytes()
        self._dstate = self._init_dstate()
        self._finished: List[GenResult] = []
        # (uid, token) streaming deltas of the CURRENT step — cleared at
        # the top of each step(), so a caller draining between steps sees
        # exactly one decode iteration's worth of tokens (one BURST's
        # worth under decode_burst)
        self._deltas: List[Tuple[int, int]] = []
        # slots whose prefill completes this step, awaiting the batched
        # first-token sample: (slot, final-chunk logits) pairs
        self._pending_first: List[Tuple["_Slot", object]] = []
        self.fns = fns or self._compile()
        self._bind_fns()
        # speculative decoding: a viable draft co-residents its own KV
        # cache beside the target's; an unviable one degrades to plain
        # fused stepwise (self.spec stays None — no other path changes)
        self.spec: Optional[SpecDraft] = None
        self._spec_bytes = 0
        self._spec_drafted = 0            # lifetime drafted/accepted (gauge)
        self._spec_accepted = 0
        self._spec_win = [0, 0]           # draft-collapse detection window
        if spec is not None and self._spec_viable(spec):
            self.spec = spec
            self._init_spec()

    # hooks a paged subclass overrides ------------------------------------
    def _make_slot(self) -> "_Slot":
        return _Slot()

    def _init_cache(self):
        return init_cache(self.cfg, self.max_batch, self.max_seq,
                          self._kv_dtype)

    def _init_dstate(self):
        return init_device_state(self.max_batch)

    def _compile(self):
        return compile_fns(self.cfg, self.backend, self.max_seq)

    def _bind_fns(self) -> None:
        self._prefill = self.fns.prefill
        self._decode = self.fns.decode
        self._insert = self.fns.insert
        self._gather_slot = self.fns.gather_slot
        self._chunk_prefill = self.fns.chunk_prefill
        self._scatter_slot = self.fns.scatter_slot
        self._bind_fused()

    def _bind_fused(self) -> None:
        self._fused_step = self.fns.fused_step
        self._fused_burst = self.fns.fused_burst
        self._first_fn = self.fns.first_tokens
        self._occupy_fn = self.fns.occupy
        self._deactivate_fn = self.fns.deactivate

    def _chunkable(self) -> bool:
        """Chunk-append available AND requested for this engine."""
        return self.chunk_tokens is not None and self.fns.chunk_prefill is not None

    # -- speculative decoding hooks ---------------------------------------
    def _spec_viable(self, spec: SpecDraft) -> bool:
        """Can this draft co-reside? Vocab must match (acceptance compares
        token ids) and both models need the multi-token chunk/verify
        trunk. Failing the gate is graceful: plain fused stepwise."""
        return (spec.cfg.vocab_size == self.cfg.vocab_size
                and supports_chunked(spec.cfg)
                and supports_chunked(self.cfg))

    def _build_spec_cache(self, spec: SpecDraft):
        """Draft KV storage (dense: its own per-slot cache)."""
        return init_cache(spec.cfg, self.max_batch, self.max_seq,
                          self._kv_dtype)

    def _init_spec(self) -> None:
        """Allocate the draft's device residency and compile the pair.
        KV-pressure gate: if the draft cache would outweigh the target's
        own, the draft cannot co-reside — drop to plain decode rather
        than let the helper starve the helped."""
        spec = self.spec
        dcache = self._build_spec_cache(spec)
        nbytes = int(sum(
            x.nbytes for x in jax.tree_util.tree_leaves(dcache)))
        if dcache is None or nbytes > self._cache_bytes:
            self.spec = None
            return
        self._spec_cache = dcache
        self._spec_bytes = nbytes
        self.sfns = self._compile_spec(spec)
        self._sgather = self.sfns.gather
        self._sprefill = self.sfns.prefill
        self._sscatter = self.sfns.scatter

    def _compile_spec(self, spec: SpecDraft) -> SpecFns:
        return compile_spec_fns(self.cfg, spec.cfg, self.max_seq, spec.k)

    def _spec_dispatch(self):
        """One fused draft-K + verify dispatch over the engine's device
        state. Returns (out ids, reason bits, cache, dcache, state)."""
        return self.sfns.step(self.params, self.spec.params, self.cache,
                              self._spec_cache, self._dstate)

    def _spec_ready(self, active: List[int]) -> bool:
        """Spec runs only when EVERY active row has draft residency —
        a row without a draft-cache lease would read/clobber another
        row's draft KV. Mixed batches fall back to plain stepwise."""
        return (self.spec is not None
                and all(self._slots[i].spec_ok for i in active))

    def _spec_prefill_slot(self, slot: "_Slot") -> None:
        """Whole-prompt draft prefill at admission-completion time: the
        draft needs KV for the ENTIRE prompt (including any part the
        target skipped via prefix cache — the draft pool has no radix),
        in one bucketed pass into its own cache."""
        n = slot.filled
        sb = self._bucket_up(n)
        padded = np.zeros((1, sb), np.int32)
        padded[0, :n] = slot.prompt[:n]
        ctx = self._sgather(self._spec_cache, jnp.int32(slot.idx))
        _, new_kv = self._sprefill(self.spec.params, jnp.asarray(padded),
                                   ctx, jnp.int32(0), jnp.int32(n))
        self._spec_cache = self._sscatter(self._spec_cache, new_kv,
                                          jnp.int32(slot.idx), jnp.int32(0),
                                          jnp.int32(n))

    def _register_cache_bytes(self) -> None:
        """Hook: publish cache geometry (paged sets bytes_per_block)."""

    def _slot_kv_bytes(self, slot: "_Slot") -> int:
        """KV bytes a slot holds at release — dense: its fixed share of
        the pre-allocated (max_batch, max_seq) cache."""
        return self._cache_bytes // self.max_batch

    def _release(self, slot: "_Slot", register_prefix: bool = True) -> None:
        """Reap hook: account the request's peak KV footprint, then free
        per-request cache resources (nothing to free on dense). Paged
        overrides MUST call super() before dropping block leases."""
        if slot.res is not None:
            b = self._slot_kv_bytes(slot)
            slot.res.kv_bytes = b
            if self._obs is not None:
                from repro.obs.cost import KV_BYTE_BUCKETS
                self._obs.registry.histogram(
                    "kv_bytes_per_request", self._obs.model,
                    bounds=KV_BYTE_BUCKETS).observe(float(b))

    # -- resident-memory accounting --------------------------------------
    def resident_bytes(self) -> int:
        """HBM this replica pins: params (config param count x dtype
        width) + the KV cache/pool tensors — and, under speculative
        decoding, the resident draft's params + its own KV cache."""
        from repro.obs.cost import param_bytes
        total = param_bytes(self.cfg) + self._cache_bytes
        if self.spec is not None:
            total += param_bytes(self.spec.cfg) + self._spec_bytes
        return total

    def kv_pool_bytes(self) -> Tuple[int, int]:
        """(used, free) KV bytes — dense: occupied-slot shares of the
        pre-allocated cache."""
        share = self._cache_bytes // self.max_batch
        busy = sum(1 for s in self._slots if not s.done)
        return busy * share, (self.max_batch - busy) * share

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrival_t = req.arrival_t or time.perf_counter()
        self._queue.append(req)
        self._by_uid[req.uid] = req

    def cancel(self, uid: int, now: Optional[float] = None
               ) -> Optional[GenResult]:
        """Abort a request wherever it is, O(1) at any occupancy via the
        uid index. Queued: tombstoned (skipped at admission) before ever
        touching a slot. In a slot (mid-prefill or mid-decode): the slot
        is freed immediately and — on the paged engine — its KV blocks go
        back to the pool without registering in the prefix cache (the
        caller abandoned the work). Returns the partial ``GenResult``
        (``cancelled=True``), or None if ``uid`` is unknown/already
        finished here."""
        now = time.perf_counter() if now is None else now
        obj = self._by_uid.pop(uid, None)
        if obj is None:
            return None
        if isinstance(obj, Request):          # still queued: tombstone
            obj.cancelled = True
            self._queue_tomb += 1
            while self._queue and self._queue[0].cancelled:
                self._queue.popleft()         # amortized front sweep
                self._queue_tomb -= 1
            res = GenResult(uid=uid, prompt_len=len(obj.tokens),
                            cancelled=True)
            res.latency = now - obj.arrival_t
            return res
        slot = obj
        res = slot.res
        res.latency = now - slot.req.arrival_t
        res.cancelled = True
        res.completed = False
        self._release(slot, register_prefix=False)
        self._clear_slot(slot)
        slot.res = None
        return res

    def evacuate(self) -> List[Tuple[Request, Optional[List[int]],
                                     List[int]]]:
        """Containment dump: hand back every live request this engine
        holds so a healthy replica can take them over. Queued requests
        come back untouched ``(request, None, [])``; in-slot ones as
        ``(request, served prompt, emitted tokens)`` — the served prompt
        is the post-cap/post-bucket token list actually prefilled, which
        is what a deterministic retry must chain onto. All slot
        resources are released WITHOUT registering prefixes (this
        replica's cache dies with it); the engine is empty afterwards."""
        out: List[Tuple[Request, Optional[List[int]], List[int]]] = []
        for r in self._queue:
            self._by_uid.pop(r.uid, None)
            if not r.cancelled:
                out.append((r, None, []))
        self._queue.clear()
        self._queue_tomb = 0
        for s in self._slots:
            if s.done or s.req is None:
                continue
            emitted = list(s.res.new_tokens) if s.res is not None else []
            out.append((s.req, list(s.prompt), emitted))
            self._release(s, register_prefix=False)
            self._clear_slot(s)
            s.res = None
        self._pending_first = []
        return out

    def drain_deltas(self) -> List[Tuple[int, int]]:
        """Fetch-and-clear the current step's (uid, token) stream deltas."""
        out, self._deltas = self._deltas, []
        return out

    def _queued(self) -> int:
        """Live (non-tombstoned) queued requests."""
        return len(self._queue) - self._queue_tomb

    def has_work(self) -> bool:
        return self._queued() > 0 or any(not s.done for s in self._slots)

    def idle_slots(self) -> int:
        """Raw free decode slots (no queue/capacity accounting)."""
        return sum(1 for s in self._slots if s.done)

    def free_slots(self) -> int:
        """Slots a scheduler may still fill (free minus already queued),
        clamped at 0: the internal queue can exceed the free slots, and a
        negative count would corrupt scheduler admission math."""
        return max(0, self.idle_slots() - self._queued())

    def pending_tokens(self) -> int:
        """Prefill backlog in TOKENS: queued prompt tokens plus the
        unfilled remainder of every mid-prefill slot. The scheduler's
        token-budget load gauge — two replicas with equal free slots can
        hide a 100x difference here."""
        # queued prompts count at their SERVED size (admission keeps only
        # the last max_seq - max_new - 1 tokens; raw oversized prompts
        # would report phantom load)
        queued = sum(
            min(len(r.tokens),
                max(self.max_seq - self._decode_budget(r) - 1, 1))
            for r in self._queue if not r.cancelled)
        inflight = sum(len(s.prompt) - s.filled for s in self._slots
                       if not s.done and s.prefilling)
        return queued + inflight

    def step(self) -> List[GenResult]:
        """One token-budget iteration: admit, prefill chunks, decode.

        Fault-injection hook first (BEFORE any device work, so an
        injected crash is clean: state is exactly as the previous step
        left it), then the real step with a poison latch — any
        mid-flight exception marks the engine unrecoverable for the
        containment layer."""
        if self._fault is not None:
            fired = self._fault.begin_step()
            if fired and self._obs is not None:
                for kind in fired:
                    self._obs.registry.counter(
                        "fault_injected_total",
                        f"{self._obs.model}|kind={kind}").inc()
            if "step_error" in fired:
                from repro.serving.faults import InjectedFault
                raise InjectedFault(
                    f"injected step_error at step {self._fault.step_no}")
        try:
            return self._step_inner()
        except BaseException:
            self.poisoned = True
            raise

    def _step_inner(self) -> List[GenResult]:
        t0 = time.perf_counter() if self._obs is not None else 0.0
        self._deltas = []                 # this step's streaming increments
        self._pending_first = []
        # 1) admission (a paged engine may refuse — out of KV blocks — in
        #    which case the request stays queued for a later step).
        #    Tombstoned (cancelled-in-queue) entries drain here for free.
        deny_kv = self._fault is not None and self._fault.deny_kv
        for slot in self._slots:
            while self._queue and self._queue[0].cancelled:
                self._queue.popleft()
                self._queue_tomb -= 1
            if not self._queue:
                break
            if deny_kv:        # injected allocation failure: stay queued
                break
            if slot.done:
                if not self._begin(slot.idx, self._queue[0]):
                    break
                self._queue.popleft()
        # requests sharing this step's batch — the cost ledger splits the
        # step's wall duration evenly across them (host-side list of ids
        # already in slot state; no device traffic)
        step_uids = ([s.req.uid for s in self._slots if not s.done]
                     if self._obs is not None and self._obs.meter is not None
                     else None)
        # 2) budget: decode tokens are committed first — in-flight decodes
        #    must never stall behind prefill (that's the whole point);
        #    the remainder throttles prefill chunks. Slots whose LAST
        #    chunk completes below join this step's decode uncharged
        #    (bounded by max_batch; the overdraft buys them the same
        #    admit-then-decode cadence the old engine had).
        decoding = sum(1 for s in self._slots
                       if not s.done and not s.prefilling)
        rem = (None if self.step_token_budget is None
               else max(self.step_token_budget - decoding, 0))
        # 3) prefill chunks, oldest admission first
        for i in sorted((i for i, s in enumerate(self._slots)
                         if not s.done and s.prefilling),
                        key=lambda i: self._slots[i].order):
            if rem is not None and rem <= 0:
                break
            rem = self._prefill_step(i, self._slots[i], rem)
        # 3b) ONE batched dispatch samples the first token of every slot
        #     whose last chunk just ran; they join this step's decode
        self._finish_first_tokens()
        # 4) decode all fully-prefilled slots: one fused device step per
        #    token, or a K-iteration burst when nothing is waiting to
        #    prefill (the offline/throughput path)
        active = [i for i, s in enumerate(self._slots)
                  if not s.done and not s.prefilling]
        if active:
            if self._spec_ready(active):
                self._decode_spec(active)
            elif (self.decode_burst > 1 and self._queued() == 0
                    and not any(s.prefilling for s in self._slots
                                if not s.done)):
                self._decode_burst(active)
            else:
                self._decode_once(active)
        if self._obs is not None:
            self._record_step(t0, step_uids, rem)
        return self.drain_finished()

    def _record_step(self, t0: float, step_uids=None, rem=None) -> None:
        """Per-step host-side metrics: step wall time, tokens emitted
        (decode + first tokens, i.e. this step's delta count), and the
        fused-fn retrace total surfaced as a gauge (a climbing value
        under steady traffic is the silent-recompile regression the
        PR-5 trace-count guard tests for).  Also feeds the chip-second
        ledger (wall interval split across ``step_uids``) and the flight
        recorder's snapshot ring — both pure host-side appends."""
        reg, m = self._obs.registry, self._obs.model
        t1 = time.perf_counter()
        reg.histogram("engine_step_s", m).observe(t1 - t0)
        ntok = len(self._deltas)
        reg.histogram("engine_tokens_per_step", m).observe(float(ntok))
        if ntok:
            reg.counter("engine_tokens", m).inc(ntok)
        if self.fns.trace_counts:
            reg.gauge("engine_retraces", m).set(
                float(sum(self.fns.trace_counts.values())))
        meter = self._obs.meter
        if meter is not None:
            self._obs.cost.on_step(meter, t0, t1, step_uids or ())
        fl = self._obs.flight
        if fl is not None:
            spent = (self.step_token_budget - rem
                     if self.step_token_budget is not None and rem is not None
                     else ntok)
            snap = dict(
                active=sum(1 for s in self._slots if not s.done),
                pending_tokens=self.pending_tokens(),
                free_blocks=getattr(getattr(self, "pool", None),
                                    "num_free", -1),
                tokens=ntok, budget_spent=spent, burst=self.decode_burst)
            if self.spec is not None:
                # draft-collapse forensics ride the snapshot ring: the
                # accept rate at every step leading up to an anomaly dump
                snap["spec_accept_rate"] = (
                    self._spec_accepted / self._spec_drafted
                    if self._spec_drafted else -1.0)
            fl.record_step(m, t1, **snap)

    # -- fused decode (device-resident hot path) --------------------------
    def _decode_once(self, active: List[int]) -> None:
        """One fused decode+sample dispatch; the ONLY device->host
        traffic is the (max_batch,) int32 token-id vector plus the
        (max_batch,) int32 finish-reason bits — termination is decided
        on device, the host just books the result."""
        nxt, bits, self.cache, self._dstate = self._fused_step(
            self.params, self.cache, self._dstate)
        # servelint: disable=SL002 -- the designed per-step sync point
        toks, bits = jax.device_get((nxt, bits))
        t = time.perf_counter()
        tracer = self._obs.tracer if self._obs is not None else None
        for i in active:
            s = self._slots[i]
            tok = int(toks[i])
            uid = s.req.uid
            s.res.new_tokens.append(tok)
            self._deltas.append((uid, tok))
            s.pos += 1
            if tracer is not None:
                tracer.on_tokens(uid, t)
            self._consume_reason(s, t, int(bits[i]))

    def _decode_burst(self, active: List[int]) -> None:
        """K fused decode iterations inside one ``lax.scan`` dispatch,
        with on-device EOS/length retirement; the host replays the
        (K, max_batch) token ids (-1: row not decoding that iteration)
        and consumes the matching reason bits. Wall-clock deadlines
        resolve only at the burst boundary — K bounds that staleness,
        which is why the burst stays opt-in and bounded rather than
        running to EOS."""
        k = self.decode_burst
        toks, bits, self.cache, self._dstate = self._fused_burst(
            self.params, self.cache, self._dstate, k)
        # servelint: disable=SL002 -- the designed per-burst sync point
        toks, bits = jax.device_get((toks, bits))
        counts: Dict[int, int] = {}
        for j in range(k):
            t = time.perf_counter()
            for i in active:
                s = self._slots[i]
                # s.done: the host finished this row at an earlier burst
                # iteration (e.g. a lapsed deadline the device couldn't
                # see) — any tokens the device over-ran are dropped
                if s.done or toks[j, i] < 0:
                    continue
                tok = int(toks[j, i])
                uid = s.req.uid
                s.res.new_tokens.append(tok)
                self._deltas.append((uid, tok))
                s.pos += 1
                counts[uid] = counts.get(uid, 0) + 1
                self._consume_reason(s, t, int(bits[j, i]))
        if self._obs is not None:
            # one tracer call per request per burst: the replay wall
            # since the request's previous token spreads evenly over its
            # K accepted tokens (per-iteration replay stamps would report
            # ~0 ITL for every token after the first)
            t = time.perf_counter()
            tracer = self._obs.tracer
            for uid, n in counts.items():
                tracer.on_tokens(uid, t, n)
            self._obs.registry.gauge("engine_burst_depth",
                                     self._obs.model).set(float(k))

    def _decode_spec(self, active: List[int]) -> None:
        """One speculative draft-K + verify dispatch: up to K+1 tokens
        per active row for ONE target forward. The only device->host
        traffic is the (max_batch, K+1) int32 id matrix (-1 past each
        row's emitted prefix) and the (max_batch,) reason bits — the
        draft's logits, the verify logits and the acceptance mask all
        stay on device."""
        out, reason, self.cache, self._spec_cache, self._dstate = \
            self._spec_dispatch()
        # servelint: disable=SL002 -- the designed per-verify sync point
        out, reason = jax.device_get((out, reason))
        t = time.perf_counter()
        k = self.spec.k
        counts: Dict[int, int] = {}
        drafted = accepted = 0
        for i in active:
            s = self._slots[i]
            uid = s.req.uid
            n = 0
            for tok in out[i]:             # emitted prefix, then -1 pads
                if tok < 0:
                    break
                tok = int(tok)
                s.res.new_tokens.append(tok)
                self._deltas.append((uid, tok))
                s.pos += 1
                n += 1
            counts[uid] = n
            s.res.drafted_tokens += k
            s.res.accepted_tokens += max(n - 1, 0)
            drafted += k
            accepted += max(n - 1, 0)
            self._consume_reason(s, t, int(reason[i]))
        self._spec_drafted += drafted
        self._spec_accepted += accepted
        # draft-collapse watch: a draft that stops agreeing makes every
        # verify pay K+1 positions for ~1 token — flag it for the flight
        # recorder once enough evidence accumulates
        self._spec_win[0] += drafted
        self._spec_win[1] += accepted
        if self._obs is not None:
            reg, m = self._obs.registry, self._obs.model
            tracer = self._obs.tracer
            hist = reg.histogram("spec_accept_len", m,
                                 bounds=(0.0, 1.0, 2.0, 4.0, 8.0, 16.0))
            for uid, n in counts.items():
                if n:
                    tracer.on_tokens(uid, t, n)
                hist.observe(float(max(n - 1, 0)))
            if self._spec_drafted:
                reg.gauge("spec_accept_rate", m).set(
                    self._spec_accepted / self._spec_drafted)
            fl = self._obs.flight
            if (fl is not None and self._spec_win[0] >= 64
                    and self._spec_win[1] / self._spec_win[0] < 0.05):
                fl.trigger("spec_draft_collapse", t,
                           accept_rate=self._spec_win[1] / self._spec_win[0],
                           drafted=self._spec_win[0])
        if self._spec_win[0] >= 64:
            self._spec_win = [0, 0]

    def drain_finished(self) -> List[GenResult]:
        out, self._finished = self._finished, []
        return out

    def run(self, requests: List[Request], max_steps: int = 100000
            ) -> List[GenResult]:
        """Synchronous convenience wrapper: serve everything to completion."""
        for r in requests:
            self.submit(r)
        results: List[GenResult] = []
        steps = 0
        while self.has_work() and steps < max_steps:
            results.extend(self.step())
            steps += 1
        return results

    # -- batched first-token sampling -------------------------------------
    def _finish_first_tokens(self) -> None:
        """Drain ``_pending_first``: every slot whose prefill completed
        this step samples its first token in ONE fused dispatch (stacked
        final-chunk logits rows, per-slot params/keys gathered from the
        device state) and activates its decode row. Replaces the old
        per-slot ``_sample_one`` round-trips."""
        pend, self._pending_first = self._pending_first, []
        if not pend:
            return
        n = len(pend)
        nb = 1                           # pow2 pad bounds retraces by count
        while nb < n:
            nb *= 2
        idx = np.full((nb,), self.max_batch, np.int32)   # max_batch: pad
        pos_vals = np.zeros((nb,), np.int32)
        rows = []
        for j, (slot, logits) in enumerate(pend):
            idx[j] = slot.idx
            pos_vals[j] = slot.filled
            rows.append(logits)
        rows.extend([jnp.zeros_like(rows[0])] * (nb - n))
        stacked = jnp.concatenate(rows, axis=0)
        toks, bits, self._dstate = self._first_fn(
            self._dstate, stacked, jnp.asarray(idx), jnp.asarray(pos_vals),
            self._stack_tables(pend, nb))
        # servelint: disable=SL002 -- first-token ids must reach the host here
        toks, bits = jax.device_get((toks, bits))
        t = time.perf_counter()
        tracer = self._obs.tracer if self._obs is not None else None
        for j, (slot, _) in enumerate(pend):
            tok = int(toks[j])
            uid = slot.req.uid
            slot.res.new_tokens.append(tok)
            self._deltas.append((uid, tok))
            slot.prefilling = False
            if tracer is not None:
                tracer.on_first_token(uid, t)
            self._consume_reason(slot, t, int(bits[j]))

    def _stack_tables(self, pend, nb: int):
        """Paged hook: block tables to sync into the device state when
        the pending slots activate (None on the dense engine)."""
        return None

    # -- termination ------------------------------------------------------
    def _consume_reason(self, s: "_Slot", t: float, reason: int) -> bool:
        """Book a DEVICE-REPORTED finish reason (``FINISH_EOS`` /
        ``FINISH_MAX_NEW`` / ``FINISH_ROOM`` bits; 0: still going). The
        device already retired the row; the host's only original
        contribution is the wall-clock deadline it alone can see. Pure
        bookkeeping — no token-value re-derivation, no device sync."""
        timed_out = (s.req.deadline_s is not None and
                     t - s.req.arrival_t > s.req.deadline_s)
        if reason == 0 and not timed_out:
            return False
        s.res.latency = t - s.req.arrival_t
        s.res.completed = (bool(reason & (FINISH_EOS | FINISH_MAX_NEW))
                           and not timed_out)
        s.res.timed_out = timed_out
        self._finished.append(s.res)
        self._release(s)
        self._clear_slot(s)
        return True

    def _clear_slot(self, s: "_Slot") -> None:
        if s.req is not None:
            self._by_uid.pop(s.req.uid, None)
        self._dstate = self._deactivate_fn(self._dstate, s.idx)
        s.done = True
        s.req = None
        s.prefilling = False
        s.prompt = []
        s.filled = 0
        s.spec_ok = False

    # -- admission (state only; compute happens in _prefill_step) ---------
    @staticmethod
    def _bucket(n: int) -> int:
        """Power-of-2 length bucket (floor, min 8) so prefill compiles a
        bounded number of specializations. Prompts are truncated from the
        left to the bucket (kept suffix), which preserves the systems
        metrics this engine exists to measure."""
        b = 8
        while b * 2 <= n:
            b *= 2
        return b

    @staticmethod
    def _bucket_up(n: int) -> int:
        """Power-of-2 ceiling bucket (min 8): prefill CHUNKS and the
        paged suffix pad up instead of truncating, so prompt tokens keep
        their absolute positions."""
        b = 8
        while b < n:
            b *= 2
        return b

    def _occupy(self, slot: "_Slot", req: Request, prompt: List[int],
                filled: int, cached: int = 0) -> None:
        """Claim a slot for ``req`` with its prefill cursor at
        ``filled`` (prefix hits start past the cached tokens). The
        slot's device-state row is loaded here (sampling fields + the
        uid-level PRNG fold) by one jitted index op; the row activates
        only when its first token lands."""
        slot.req = req
        slot.res = GenResult(uid=req.uid, prompt_len=len(prompt),
                             cached_tokens=cached)
        slot.prompt = prompt
        slot.filled = filled
        slot.pos = filled
        slot.prefilling = True
        slot.done = False
        # dense draft cache has a row per slot; the paged _begin replaces
        # this with the outcome of its draft-pool lease
        slot.spec_ok = self.spec is not None
        slot.order = self._order
        self._order += 1
        sp = req.sampling
        self._dstate = self._occupy_fn(
            self._dstate, slot.idx, self._base_key, np.int32(req.uid),
            np.float32(sp.temperature), np.int32(sp.top_k),
            np.float32(sp.top_p),
            np.int32(-1 if sp.eos_id is None else sp.eos_id),
            np.int32(sp.max_new_tokens), np.int32(filled),
            np.int32(req.prefix_draws))
        self._by_uid[req.uid] = slot
        if self._obs is not None:
            # admit event: queue wait ends here (a span opens lazily for
            # requests that never passed a frontend submit)
            self._obs.tracer.on_admit(req.uid, time.perf_counter(),
                                      arrival_t=req.arrival_t,
                                      model=self._obs.model,
                                      backend=self._obs.backend)

    @staticmethod
    def _decode_budget(req: Request) -> int:
        """Tokens the request may still draw: a retried request already
        emitted ``prefix_draws`` of its ``max_new_tokens`` (they ride in
        its prompt now), so only the remainder needs cache room."""
        return max(req.sampling.max_new_tokens - req.prefix_draws, 1)

    def _begin(self, slot_id: int, req: Request) -> bool:
        prompt = req.tokens[-(self.max_seq - self._decode_budget(req) - 1):]
        if req.prefix_draws == 0:
            prompt = prompt[-self._bucket(len(prompt)):]
        # a RETRY skips the pow2 truncation: its prompt is the original
        # (already bucketed) prompt plus the emitted chain — truncating
        # again would shift token positions off the unfailed run's
        self._occupy(self._slots[slot_id], req, prompt, filled=0)
        return True

    # -- prefill ----------------------------------------------------------
    def _prefill_step(self, slot_id: int, slot: "_Slot",
                      rem: Optional[int]) -> Optional[int]:
        """Advance one slot's prefill cursor by (up to) one chunk; on the
        last chunk, sample the request's first token. Returns the
        remaining token budget."""
        req, res = slot.req, slot.res
        t = time.perf_counter()
        # deadline sweep at the chunk boundary: budget must not be burnt
        # prefilling a request that already missed its deadline
        if req.deadline_s is not None and t - req.arrival_t > req.deadline_s:
            res.latency = t - req.arrival_t
            res.timed_out = True
            res.completed = False
            self._finished.append(res)
            self._release(slot)
            self._clear_slot(slot)
            return rem
        remaining = len(slot.prompt) - slot.filled
        if self._chunkable():
            n = min(self.chunk_tokens, remaining)
            if rem is not None:
                n = max(1, min(n, rem))
        else:
            n = remaining              # whole-prompt prefill is atomic; it
            #                            may overdraw the budget (rem goes
            #                            negative and the loop stops)
        logits = self._prefill_chunk(slot_id, slot, n)
        slot.filled += n
        slot.pos = slot.filled
        res.prefill_chunks += 1
        if self._obs is not None:
            self._obs.tracer.on_chunk(req.uid, time.perf_counter(), n)
            self._obs.registry.counter("engine_prefill_chunks",
                                       self._obs.model).inc()
        if rem is not None:
            rem -= n
        if slot.filled >= len(slot.prompt):
            self._finish_prefill(slot, logits)
        return rem

    def _prefill_chunk(self, slot_id: int, slot: "_Slot", n: int):
        """Run the model over ``n`` prompt tokens at the cursor; returns
        the last live token's logits (meaningful on the final chunk)."""
        if not self._chunkable():
            return self._whole_prefill(slot_id, slot)
        start = slot.filled
        chunk = slot.prompt[start:start + n]
        sb = self._bucket_up(n)
        padded = np.zeros((1, sb), np.int32)
        padded[0, :n] = chunk
        ctx = self._gather_slot(self.cache, jnp.int32(slot_id))
        logits, new_kv = self._chunk_prefill(self.params, jnp.asarray(padded),
                                             ctx, jnp.int32(start),
                                             jnp.int32(n))
        self._stamp_ttft(slot, start + n)
        self.cache = self._scatter_slot(self.cache, new_kv, jnp.int32(slot_id),
                                        jnp.int32(start), jnp.int32(n))
        return logits

    def _stamp_ttft(self, slot: "_Slot", filled_after: int) -> None:
        """TTFT convention: the clock stops when the last chunk's logits
        are produced — the first token is determined there. The scatter
        that follows is cache bookkeeping for FUTURE steps (it blocks on
        the donated pool buffer) and must not count against TTFT, same
        as the pre-chunking engine."""
        if filled_after >= len(slot.prompt):
            slot.res.ttft = time.perf_counter() - slot.req.arrival_t

    def _whole_prefill(self, slot_id: int, slot: "_Slot"):
        """Legacy one-shot prefill + whole-row insert (non-chunkable
        families, and ``chunk_tokens=None`` where it skips the per-chunk
        gather)."""
        req = slot.req
        batch = {"tokens": jnp.asarray(np.asarray(slot.prompt, np.int32)[None])}
        if self.cfg.family == "encdec":
            se = (req.src_embeds if req.src_embeds is not None
                  else np.zeros((self.cfg.frontend_seq, self.cfg.d_model),
                                np.float32))
            batch["src_embeds"] = jnp.asarray(se[None])
        logits, rcache = self._prefill(self.params, batch)
        self.cache = self._insert(self.cache, rcache, slot_id)
        self._stamp_ttft(slot, len(slot.prompt))   # after insert: the slot
        #       row must be live before the first decode (old convention)
        return logits

    def _finish_prefill(self, slot: "_Slot", logits) -> None:
        """The last chunk just ran: register the prefix, stamp TTFT, and
        queue the slot for this step's BATCHED first-token sample
        (``_finish_first_tokens`` — one fused dispatch for every prefill
        that completed this step). The usual termination rules apply
        when the token lands there (max_new_tokens=1 must return exactly
        one token, an EOS straight out of prefill must stop
        generation)."""
        res, req = slot.res, slot.req
        self._register_prefix(slot)
        if not res.ttft:                 # _prefill_chunk stamps pre-scatter
            res.ttft = time.perf_counter() - req.arrival_t
        if slot.spec_ok:
            # draft residency secured at admission: give the draft its
            # whole-prompt KV now, off the guarded decode path
            self._spec_prefill_slot(slot)
        self._pending_first.append((slot, logits))

    def _register_prefix(self, slot: "_Slot") -> None:
        """Paged hook: register completed full blocks for prefix reuse."""


# ---------------------------------------------------------------------------
# paged engine


DEFAULT_BLOCK_SIZE = 16


class PagedInferenceEngine(InferenceEngine):
    """Continuous-batching engine over a paged (block-pool) KV cache.

    Differences from the dense engine:
      * one global pool of ``num_blocks`` KV blocks instead of a dense
        (max_batch, max_seq) cache — admission is gated on free blocks,
        blocks are freed the step a request finishes;
      * a radix prefix cache: the cached prefix of a prompt (multi-turn
        history, shared system prompt) is leased by refcount and only the
        uncached suffix is prefilled — this is where the TTFT win on
        shared-prefix traffic comes from. The lookup runs at admission
        (leases protect the prefix from eviction, gating counts only the
        blocks actually needed) and again at first-chunk time as an
        EXTENSION, so a prompt admitted while its twin is still
        prefilling adopts every full block the twin registers chunk by
        chunk;
      * prompts are NOT bucket-truncated (truncation would shift token
        positions and break prefix identity); instead each prefill chunk
        is right-padded to a power-of-2 bucket and masked, which bounds
        compile specializations the same way.
    """

    paged = True

    def __init__(self, cfg: ModelConfig, params, backend: BackendProfile,
                 max_seq: int = 512, seed: int = 0, fns=None,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 chunk_tokens: Optional[int] = None,
                 step_token_budget: Optional[int] = None,
                 decode_burst: int = 1, obs=None,
                 spec: Optional[SpecDraft] = None, fault=None):
        if not supports_paged(cfg):
            raise ValueError(f"{cfg.name}: family/attention has no paged path")
        if max_seq % block_size:
            raise ValueError(f"max_seq {max_seq} % block_size {block_size}")
        self.block_size = block_size
        self.blocks_per_seq = max_seq // block_size
        self.num_blocks = num_blocks or backend.max_batch * self.blocks_per_seq
        if self.num_blocks < self.blocks_per_seq:
            raise ValueError("pool smaller than one full sequence")
        self.pool = BlockPool(self.num_blocks, block_size)
        self.prefix: Optional[RadixPrefixCache] = (
            RadixPrefixCache(self.pool) if prefix_cache else None)
        self.hit_tokens = 0                       # prefix tokens NOT prefilled
        self.prompt_tokens = 0
        super().__init__(cfg, params, backend, max_seq, seed, fns,
                         chunk_tokens=chunk_tokens,
                         step_token_budget=step_token_budget,
                         decode_burst=decode_burst, obs=obs, spec=spec,
                         fault=fault)

    # -- hooks ----------------------------------------------------------
    def _make_slot(self) -> _PagedSlot:
        return _PagedSlot()

    def _init_cache(self):
        return init_paged_cache(self.cfg, self.num_blocks, self.block_size,
                                self._kv_dtype)

    def _register_cache_bytes(self) -> None:
        # measured block width: pool tensor bytes / population — int8
        # pools (quantized k/v + f32 scales) come out at true width
        self.pool.bytes_per_block = self._cache_bytes // self.num_blocks

    def _init_dstate(self):
        # per-row block tables ride in the device state so the fused
        # decode never re-stages them from host
        return init_device_state(self.max_batch, self.blocks_per_seq)

    def _compile(self) -> PagedCompiledFns:
        return compile_paged_fns(self.cfg, self.backend, self.max_seq,
                                 self.block_size)

    def _bind_fns(self) -> None:
        self._gather = self.fns.gather
        self._prefill = self.fns.prefill
        self._scatter = self.fns.scatter
        self._decode = self.fns.decode
        self._copy = self.fns.copy
        self._bind_fused()

    def _chunkable(self) -> bool:
        # the paged prefill is ALWAYS a chunk-append (gather/compute/
        # scatter); chunk_tokens only bounds how much one pass covers
        return self.chunk_tokens is not None

    # -- speculative decoding (paged residency) -------------------------
    def _build_spec_cache(self, spec: SpecDraft):
        """Draft KV storage: its own small block pool. Same block size
        and (by default) population as the target's, but each block is
        the DRAFT's width — for a 10x smaller draft that is ~10x fewer
        bytes. ``spec.num_blocks`` overrides the population (the
        KV-pressure test knob)."""
        self.spec_blocks = spec.num_blocks or self.num_blocks
        if self.spec_blocks < self.blocks_per_seq:
            return None
        return init_paged_cache(spec.cfg, self.spec_blocks, self.block_size,
                                self._kv_dtype)

    def _init_spec(self) -> None:
        super()._init_spec()
        if self.spec is None:             # KV-pressure gate refused
            return
        self.spec_pool = BlockPool(self.spec_blocks, self.block_size)
        # device-resident draft block tables: updated by a jitted row op
        # at admission (off the guarded decode path), read by every
        # verify dispatch — never re-staged from host per step
        self._spec_tables = jnp.zeros((self.max_batch, self.blocks_per_seq),
                                      jnp.int32)

    def _compile_spec(self, spec: SpecDraft) -> SpecFns:
        return compile_spec_fns(self.cfg, spec.cfg, self.max_seq, spec.k,
                                self.block_size)

    def _spec_dispatch(self):
        return self.sfns.step(self.params, self.spec.params, self.cache,
                              self._spec_cache, self._dstate,
                              self._spec_tables)

    def _spec_prefill_slot(self, slot: _PagedSlot) -> None:
        n = slot.filled
        sb = self._bucket_up(n)
        padded = np.zeros((1, sb), np.int32)
        padded[0, :n] = slot.prompt[:n]
        stab = np.zeros((self.blocks_per_seq,), np.int32)
        stab[:len(slot.spec_blocks)] = slot.spec_blocks
        # start=0: no cached draft context — gather one block for shape
        ctx_kv = self._sgather(self._spec_cache, jnp.asarray(stab[:1]))
        _, new_kv = self._sprefill(self.spec.params, jnp.asarray(padded),
                                   ctx_kv, jnp.int32(0), jnp.int32(n))
        self._spec_cache = self._sscatter(self._spec_cache, new_kv,
                                          jnp.asarray(stab), jnp.int32(0),
                                          jnp.int32(n))

    def _stack_tables(self, pend, nb: int):
        """Sync each activating slot's (possibly extension-rewritten)
        block table into the device state alongside its first token —
        the one point every mid-prefill table edit funnels through."""
        t = np.zeros((nb, self.blocks_per_seq), np.int32)
        for j, (slot, _) in enumerate(pend):
            t[j] = slot.table
        return jnp.asarray(t)

    # -- capacity -------------------------------------------------------
    def kv_free_frac(self) -> float:
        """Allocatable fraction of the pool — evictable prefix-cache
        blocks count as free (they are reclaimed on demand)."""
        free = self.pool.num_free
        if self.prefix:
            free += self.prefix.evictable_blocks()
        return free / self.num_blocks

    def kv_used_frac(self) -> float:
        return self.pool.used_frac

    def _slot_kv_bytes(self, slot: _PagedSlot) -> int:
        return len(slot.blocks) * self.pool.bytes_per_block

    def kv_pool_bytes(self) -> Tuple[int, int]:
        """(used, free) bytes over the block population; evictable
        prefix-cache blocks count as used until actually reclaimed."""
        return self.pool.used_bytes, self.pool.free_bytes

    def prefix_hit_rate(self) -> float:
        return self.hit_tokens / self.prompt_tokens if self.prompt_tokens else 0.0

    def prefix_peek(self, req: Request) -> int:
        """Cached-prefix tokens this request would reuse if admitted now
        (same prompt capping as admission). 0 without a prefix cache."""
        if not self.prefix:
            return 0
        prompt = req.tokens[-(self.max_seq - self._decode_budget(req) - 1):]
        return min(self.prefix.peek(prompt), max(len(prompt) - 1, 0))

    def block_capacity(self) -> int:
        """Worst-case admissions the pool can still back (a request may
        need blocks_per_seq fresh blocks; evictable cache blocks count)."""
        blocks_free = self.pool.num_free
        if self.prefix:
            blocks_free += self.prefix.evictable_blocks()
        return blocks_free // self.blocks_per_seq

    def free_slots(self) -> int:
        """Admission capacity: free decode slots AND block headroom."""
        cap = min(self.idle_slots(), self.block_capacity())
        return max(0, cap - len(self._queue))

    # -- admission ------------------------------------------------------
    def _begin(self, slot_id: int, req: Request) -> bool:
        bs = self.block_size
        budget = self._decode_budget(req)
        prompt = req.tokens[-(self.max_seq - budget - 1):]
        plen = len(prompt)
        total = min(plen + budget, self.max_seq)
        # prefix lookup AT ADMISSION: the leases protect the matched
        # blocks from the eviction below (a repeat prompt must never
        # evict its own cached prefix to make room for itself), and the
        # gating counts only the blocks actually needed — a mostly-
        # cached prompt admits on a nearly-full pool
        matched, keep, cow_src = self._match_prefix(prompt)
        n_need = math.ceil(total / bs) - len(matched)
        short = n_need - self.pool.num_free
        if short > 0 and self.prefix is not None:
            self.prefix.evict(short)
        if n_need > self.pool.num_free:
            for b in matched:             # out of blocks: stay queued
                self.pool.decref(b)
            if cow_src is not None:
                self.pool.decref(cow_src)
            return False
        fresh = self.pool.alloc_many(n_need)
        owned = matched + fresh
        slot = self._slots[slot_id]
        # leak guard: from here to the end of admission the slot holds
        # leases that are not yet reachable through _release — a raise
        # (device OOM in the COW copy / occupy index op) must hand every
        # block back and leave the slot reusable, or the pool leaks its
        # way to a wedged replica
        try:
            if cow_src is not None:       # copy-on-write the shared tail
                self.cache = self._copy(self.cache, jnp.int32(cow_src),
                                        jnp.int32(fresh[0]))
                self.pool.decref(cow_src)
                cow_src = None
            table = np.zeros((self.blocks_per_seq,), np.int32)
            table[:len(owned)] = owned
            self._occupy(slot, req, prompt, filled=keep, cached=keep)
            slot.table = table
            slot.blocks = owned
            self.hit_tokens += keep
            self.prompt_tokens += plen
            # draft residency: lease the request's full span from the
            # draft pool (no prefix sharing there — the draft prefills
            # the whole prompt itself). A dry draft pool is NOT an
            # admission failure: the slot runs plain stepwise (spec_ok
            # False falls the whole batch back) rather than stalling
            # the target.
            slot.spec_ok = False
            if self.spec is not None:
                n_blk = math.ceil(total / bs)
                if n_blk <= self.spec_pool.num_free:
                    slot.spec_blocks = self.spec_pool.alloc_many(n_blk)
                    stab = np.zeros((self.blocks_per_seq,), np.int32)
                    stab[:n_blk] = slot.spec_blocks
                    self._spec_tables = self.sfns.set_table(
                        self._spec_tables, slot.idx, jnp.asarray(stab))
                    slot.spec_ok = True
        except BaseException:
            for b in owned:
                self.pool.decref(b)
            if cow_src is not None:
                self.pool.decref(cow_src)
            for b in slot.spec_blocks:
                self.spec_pool.decref(b)
            slot.table = None
            slot.blocks = []
            slot.spec_blocks = []
            if slot.req is req:           # roll back a partial occupy
                self._clear_slot(slot)
                slot.res = None
            raise
        return True

    def _match_prefix(self, prompt: List[int]):
        """Longest cached prefix of ``prompt`` trimmed to reusable form:
        always recompute >= 1 token (the last token's logits seed
        generation), so a fully-cached prompt keeps ``plen - 1``.
        Returns ``(leased full blocks, keep tokens, cow_src)`` —
        ``cow_src`` is a leased partially-needed block the caller must
        copy-on-write into an owned block (or decref)."""
        if self.prefix is None:
            return [], 0, None
        bs = self.block_size
        plen = len(prompt)
        matched, m = self.prefix.match(prompt)
        keep = min(m, plen - 1)
        n_keep = keep // bs
        cow_src = None
        if keep < m:                      # match overshoots the kept run
            if keep % bs:
                cow_src = matched[n_keep]      # partial block -> COW
                drop = matched[n_keep + 1:]
            else:
                drop = matched[n_keep:]
            for b in drop:
                self.pool.decref(b)
            matched = matched[:n_keep]
        return matched, keep, cow_src

    # -- prefill --------------------------------------------------------
    def _extend_prefix(self, slot: _PagedSlot) -> None:
        """Chunk-boundary re-lookup: adopt full blocks a concurrent twin
        registered since this slot's LAST prefill pass (progressive
        chunk-by-chunk sharing — a twin that finishes registering while
        this request is mid-prefill is picked up at the next boundary,
        not just at first-chunk time). Aligned extension only — when
        admission copy-on-wrote a partial tail, what it decided stands;
        an unaligned cursor also skips (adoption would orphan the
        partial block's freshly-written KV)."""
        if self.prefix is None or slot.filled % self.block_size:
            return
        bs = self.block_size
        prompt, plen = slot.prompt, len(slot.prompt)
        n0 = slot.filled // bs
        matched, m = self.prefix.match(prompt)
        n_keep = min(m, plen - 1) // bs
        if n_keep > n0:
            for b in slot.blocks[n0:n_keep]:   # fresh blocks now covered
                self.pool.decref(b)
            slot.blocks[n0:n_keep] = matched[n0:n_keep]
            slot.table[n0:n_keep] = matched[n0:n_keep]
            gained = n_keep * bs - slot.filled
            slot.filled = n_keep * bs
            slot.pos = slot.filled
            slot.res.cached_tokens += gained
            self.hit_tokens += gained
            adopted = set(range(n0, n_keep))
            for i, b in enumerate(matched):    # release unadopted leases
                if i not in adopted:
                    self.pool.decref(b)
        else:
            for b in matched:
                self.pool.decref(b)

    def _prefill_chunk(self, slot_id: int, slot: _PagedSlot, n: int):
        bs = self.block_size
        start = slot.filled
        chunk = slot.prompt[start:start + n]
        sb = self._bucket_up(n)
        padded = np.zeros((1, sb), np.int32)
        padded[0, :n] = chunk
        # pow2 bound on the table entries holding CACHED context (the
        # chunk attends itself inside the compute core), so the gather
        # reads ~the cached prefix, not the full max_seq span
        ctx = 1
        while ctx * bs < start:
            ctx *= 2
        ctx = min(ctx, self.blocks_per_seq)
        ctx_kv = self._gather(self.cache, jnp.asarray(slot.table[:ctx]))
        logits, new_kv = self._prefill(self.params, jnp.asarray(padded),
                                       ctx_kv, jnp.int32(start), jnp.int32(n))
        self._stamp_ttft(slot, start + n)
        self.cache = self._scatter(self.cache, new_kv,
                                   jnp.asarray(slot.table), jnp.int32(start),
                                   jnp.int32(n))
        return logits

    def _prefill_step(self, slot_id: int, slot: _PagedSlot,
                      rem: Optional[int]) -> Optional[int]:
        # extension lookup at EVERY chunk boundary, before the base
        # class sizes the chunk: blocks a twin registered since the last
        # pass move the cursor, so only the remainder is charged (the
        # radix lookup is host-side and O(matched tokens) — cheap next
        # to the chunk it can save)
        self._extend_prefix(slot)
        rem = super()._prefill_step(slot_id, slot, rem)
        # register full blocks the moment their KV is valid (the radix
        # insert dedupes), so a twin prompt admitted in the same step
        # reuses this one's blocks chunk by chunk instead of waiting for
        # the whole prefill to land
        if not slot.done and slot.prefilling:
            self._register_prefix(slot)
        return rem

    def _register_prefix(self, slot: _PagedSlot) -> None:
        if self.prefix is not None and slot.filled >= self.block_size:
            n_full = slot.filled // self.block_size
            self.prefix.insert(slot.prompt[:n_full * self.block_size],
                               slot.table[:n_full].tolist())

    # -- reap -----------------------------------------------------------
    def _release(self, slot: _PagedSlot, register_prefix: bool = True) -> None:
        if slot.table is None:
            return
        super()._release(slot, register_prefix)   # account KV bytes first
        if register_prefix and self.prefix is not None and slot.res is not None:
            # everything written (prompt + generated-but-last) is valid
            # KV; register its full blocks for future prefix hits
            seq = (slot.prompt + slot.res.new_tokens)[: slot.pos]
            n_full = len(seq) // self.block_size
            if n_full:
                self.prefix.insert(seq, slot.table[:n_full].tolist())
        for b in slot.blocks:
            self.pool.decref(b)
        for b in slot.spec_blocks:        # draft co-retires with target
            self.spec_pool.decref(b)
        slot.table = None
        slot.blocks = []
        slot.spec_blocks = []
        slot.spec_ok = False
