"""In-process inference engine with slot-based continuous batching.

Real execution (CPU here, TPU mesh in production): one global KV-cache
pool of ``max_batch`` slots; requests prefill individually (B=1) and are
inserted into a free slot; every engine step runs ONE batched decode over
all active slots with per-slot positions (ragged batching — the model
decode path accepts a (B,) position vector). Finished/expired requests
free their slot immediately; waiting requests join mid-flight. This is
iteration-level (Orca-style) continuous batching, the same discipline
vLLM/TGI use.

The engine reports per-request TTFT / latency / completion, which is
exactly the telemetry the Pick-and-Spin control loop consumes.

Two cache disciplines share the same slot/step machinery:
``InferenceEngine`` keeps the dense per-slot (max_batch, max_seq) cache
(the latency profile's statically-planned layout), while
``PagedInferenceEngine`` leases fixed-size KV blocks from a global
``kvpool.BlockPool`` with radix prefix reuse and copy-on-write sharing —
admission gated on free blocks, blocks freed the step a request
finishes, prefix hits skipping the shared part of prefill.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_cache, model_decode, model_prefill
from repro.models.attention import paged_gather_ctx, paged_scatter
from repro.models.transformer import (copy_paged_block, init_paged_cache,
                                      lm_paged_decode, lm_paged_prefill,
                                      supports_paged)
from repro.serving.backend import BackendProfile
from repro.serving.kvpool import BlockPool, RadixPrefixCache
from repro.serving.sampling import SamplingParams, sample


@dataclass
class Request:
    uid: int
    tokens: List[int]
    sampling: SamplingParams
    deadline_s: Optional[float] = None
    arrival_t: float = 0.0
    priority: int = 1                             # api.Priority class (int)
    src_embeds: Optional[np.ndarray] = None       # encdec stub input


@dataclass
class GenResult:
    uid: int
    prompt_len: int
    new_tokens: List[int] = field(default_factory=list)
    ttft: float = 0.0
    latency: float = 0.0
    completed: bool = False                       # finished within limits
    timed_out: bool = False
    cancelled: bool = False                       # caller aborted it
    shed: bool = False                            # evicted at admission
    cached_tokens: int = 0                        # prompt tokens from prefix cache


@dataclass
class _Slot:
    req: Optional[Request] = None
    res: Optional[GenResult] = None
    pos: int = 0                                  # next write position
    done: bool = True


@dataclass
class _PagedSlot(_Slot):
    prompt: List[int] = field(default_factory=list)
    table: Optional[np.ndarray] = None            # (blocks_per_seq,) int32
    blocks: List[int] = field(default_factory=list)   # ids this req refs


def _insert_impl(cache, rcache, slot):
    def put(path, g, r):
        axis = 0 if any(getattr(k, "key", None) == "prefix" for k in path) else 1
        return jax.lax.dynamic_update_slice_in_dim(g, r.astype(g.dtype),
                                                   slot, axis=axis)
    return jax.tree_util.tree_map_with_path(put, cache, rcache)


@dataclass(frozen=True)
class CompiledFns:
    """Jitted step functions for one (config, backend, max_seq) service.

    Shareable across replicas: a second replica of a live service reuses
    the first replica's XLA executables, so only the first spin-up of a
    service ever pays compile — the dominant real cold-start cost. The
    replica pool caches these across scale-to-zero (its "code cache").
    """
    prefill: object
    decode: object
    insert: object


def compile_fns(cfg: ModelConfig, backend: BackendProfile,
                max_seq: int) -> CompiledFns:
    qc = backend.q_chunk

    def _prefill(params, batch):
        return model_prefill(params, cfg, batch, max_seq, q_chunk=qc)

    def _decode(params, token, cache, pos):
        return model_decode(params, cfg, token, cache, pos)

    return CompiledFns(prefill=jax.jit(_prefill), decode=jax.jit(_decode),
                       insert=jax.jit(_insert_impl))


@dataclass(frozen=True)
class PagedCompiledFns:
    """Jitted step functions of a paged-cache service (same sharing story
    as ``CompiledFns``: one compile per service, reused across replicas
    and across scale-to-zero).

    Prefill is three functions, and that split is the perf point of the
    paged plane: ``gather`` READS the request's context blocks out of
    the pool (output is O(context)), ``prefill`` runs the model over the
    uncached suffix only, and ``scatter`` writes the new KV into the
    request's blocks with the pool buffer DONATED — an in-place O(suffix)
    update. The dense engine's admission rewrites its whole
    (max_batch, max_seq) cache per insert; here the pool is never
    re-materialized."""
    gather: object           # (cache, table_ctx) -> ctx_kv
    prefill: object          # (params, tokens, ctx_kv, start, s_real)
    scatter: object          # (cache, new_kv, table, start, s_real)
    decode: object           # (params, token, cache, tables, pos)
    copy: object             # (cache, src_block, dst_block) — COW


def compile_paged_fns(cfg: ModelConfig, backend: BackendProfile,
                      max_seq: int, block_size: int) -> PagedCompiledFns:
    def _prefill(params, tokens, ctx_kv, start, s_real):
        return lm_paged_prefill(params, cfg, tokens, ctx_kv, start, s_real)

    def _decode(params, token, cache, tables, pos):
        return lm_paged_decode(params, cfg, token, cache, tables, pos)

    return PagedCompiledFns(
        gather=jax.jit(paged_gather_ctx),
        prefill=jax.jit(_prefill),
        scatter=jax.jit(paged_scatter, donate_argnums=(0,)),
        decode=jax.jit(_decode, donate_argnums=(2,)),
        copy=jax.jit(copy_paged_block, donate_argnums=(0,)))


class InferenceEngine:
    """Continuous-batching engine for one (model x backend) instance."""

    paged = False

    def __init__(self, cfg: ModelConfig, params, backend: BackendProfile,
                 max_seq: int = 512, seed: int = 0, fns=None):
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.max_seq = max_seq
        self.max_batch = backend.max_batch
        self.key = jax.random.PRNGKey(seed)
        self._slots = [self._make_slot() for _ in range(self.max_batch)]
        self._queue: List[Request] = []
        self._kv_dtype = jnp.bfloat16 if backend.kv_dtype == "bfloat16" else jnp.float32
        self.cache = self._init_cache()
        self._finished: List[GenResult] = []
        # (uid, token) streaming deltas of the CURRENT step — cleared at
        # the top of each step(), so a caller draining between steps sees
        # exactly one decode iteration's worth of tokens
        self._deltas: List[Tuple[int, int]] = []
        self.fns = fns or self._compile()
        self._bind_fns()

    # hooks a paged subclass overrides ------------------------------------
    def _make_slot(self) -> "_Slot":
        return _Slot()

    def _init_cache(self):
        return init_cache(self.cfg, self.max_batch, self.max_seq,
                          self._kv_dtype)

    def _compile(self):
        return compile_fns(self.cfg, self.backend, self.max_seq)

    def _bind_fns(self) -> None:
        self._prefill = self.fns.prefill
        self._decode = self.fns.decode
        self._insert = self.fns.insert

    def _run_decode(self, tokens: np.ndarray, pos: np.ndarray):
        return self._decode(self.params, jnp.asarray(tokens), self.cache,
                            jnp.asarray(pos))

    def _release(self, slot: "_Slot", register_prefix: bool = True) -> None:
        """Reap hook: free per-request cache resources (no-op dense)."""

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrival_t = req.arrival_t or time.perf_counter()
        self._queue.append(req)

    def cancel(self, uid: int, now: float = None) -> Optional[GenResult]:
        """Abort a request wherever it is. Queued: removed before ever
        touching a slot. In a slot: the slot is freed immediately and —
        on the paged engine — its KV blocks go back to the pool without
        registering in the prefix cache (the caller abandoned the work).
        Returns the partial ``GenResult`` (``cancelled=True``), or None
        if ``uid`` is unknown/already finished here."""
        now = time.perf_counter() if now is None else now
        for i, r in enumerate(self._queue):
            if r.uid == uid:
                self._queue.pop(i)
                res = GenResult(uid=uid, prompt_len=len(r.tokens),
                                cancelled=True)
                res.latency = now - r.arrival_t
                return res
        for slot in self._slots:
            if not slot.done and slot.req is not None and slot.req.uid == uid:
                res = slot.res
                res.latency = now - slot.req.arrival_t
                res.cancelled = True
                res.completed = False
                self._release(slot, register_prefix=False)
                slot.done = True
                slot.req = None
                slot.res = None
                return res
        return None

    def drain_deltas(self) -> List[Tuple[int, int]]:
        """Fetch-and-clear the current step's (uid, token) stream deltas."""
        out, self._deltas = self._deltas, []
        return out

    def has_work(self) -> bool:
        return bool(self._queue) or any(not s.done for s in self._slots)

    def idle_slots(self) -> int:
        """Raw free decode slots (no queue/capacity accounting)."""
        return sum(1 for s in self._slots if s.done)

    def free_slots(self) -> int:
        """Slots a scheduler may still fill (free minus already queued),
        clamped at 0: the internal queue can exceed the free slots, and a
        negative count would corrupt scheduler admission math."""
        return max(0, self.idle_slots() - len(self._queue))

    def step(self) -> List[GenResult]:
        """Admit waiting requests, run one batched decode, reap finished."""
        now = time.perf_counter()
        self._deltas = []                 # this step's streaming increments
        # 1) admit (a paged engine may refuse — out of KV blocks — in
        #    which case the request stays queued for a later step)
        for slot_id, slot in enumerate(self._slots):
            if not self._queue:
                break
            if slot.done:
                if not self._admit(slot_id, self._queue[0]):
                    break
                self._queue.pop(0)
        # 2) decode one token for all active slots
        active = [i for i, s in enumerate(self._slots) if not s.done]
        if active:
            tokens = np.zeros((self.max_batch, 1), np.int32)
            pos = np.full((self.max_batch,), -1, np.int32)   # -1: idle slot
            for i, s in enumerate(self._slots):
                if not s.done:
                    last = (s.res.new_tokens[-1] if s.res.new_tokens
                            else s.req.tokens[-1])
                    tokens[i, 0] = last
                    pos[i] = s.pos
            logits, self.cache = self._run_decode(tokens, pos)
            # sample per request: group active slots by their SamplingParams
            # so mixed batches honor each request's temperature/top-k/top-p
            # (a single sample() over the batch would silently apply the
            # first active slot's params to everyone)
            nxt = np.zeros((self.max_batch,), np.int32)
            groups: Dict[SamplingParams, List[int]] = {}
            for i in active:
                groups.setdefault(self._slots[i].req.sampling, []).append(i)
            for sp, idxs in groups.items():
                self.key, sk = jax.random.split(self.key)
                toks = np.asarray(sample(logits[np.asarray(idxs)], sp, sk))
                for j, i in enumerate(idxs):
                    nxt[i] = toks[j]
            t = time.perf_counter()
            for i in active:
                s = self._slots[i]
                s.res.new_tokens.append(int(nxt[i]))
                self._deltas.append((s.req.uid, int(nxt[i])))
                s.pos += 1
                sp = s.req.sampling
                hit_eos = sp.eos_id is not None and int(nxt[i]) == sp.eos_id
                full = len(s.res.new_tokens) >= sp.max_new_tokens
                timed_out = (s.req.deadline_s is not None and
                             t - s.req.arrival_t > s.req.deadline_s)
                out_of_room = s.pos >= self.max_seq - 1
                if hit_eos or full or timed_out or out_of_room:
                    s.res.latency = t - s.req.arrival_t
                    s.res.completed = (hit_eos or full) and not timed_out
                    s.res.timed_out = timed_out
                    self._finished.append(s.res)
                    self._release(s)
                    s.done = True
                    s.req = None
        return self.drain_finished()

    def drain_finished(self) -> List[GenResult]:
        out, self._finished = self._finished, []
        return out

    def run(self, requests: List[Request], max_steps: int = 100000
            ) -> List[GenResult]:
        """Synchronous convenience wrapper: serve everything to completion."""
        for r in requests:
            self.submit(r)
        results: List[GenResult] = []
        steps = 0
        while self.has_work() and steps < max_steps:
            results.extend(self.step())
            steps += 1
        return results

    # -- internals -------------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        """Power-of-2 length bucket (floor, min 8) so prefill compiles a
        bounded number of specializations. Prompts are truncated from the
        left to the bucket (kept suffix), which preserves the systems
        metrics this engine exists to measure."""
        b = 8
        while b * 2 <= n:
            b *= 2
        return b

    def _admit(self, slot_id: int, req: Request) -> bool:
        prompt = req.tokens[-(self.max_seq - req.sampling.max_new_tokens - 1):]
        prompt = prompt[-self._bucket(len(prompt)):]
        batch = {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])}
        if self.cfg.family == "encdec":
            se = (req.src_embeds if req.src_embeds is not None
                  else np.zeros((self.cfg.frontend_seq, self.cfg.d_model), np.float32))
            batch["src_embeds"] = jnp.asarray(se[None])
        logits, rcache = self._prefill(self.params, batch)
        self.cache = self._insert(self.cache, rcache, slot_id)
        res = GenResult(uid=req.uid, prompt_len=len(prompt))
        res.ttft = time.perf_counter() - req.arrival_t
        # first token comes from the prefill logits
        self.key, sk = jax.random.split(self.key)
        first = int(np.asarray(sample(logits, req.sampling, sk))[0])
        res.new_tokens.append(first)
        self._deltas.append((req.uid, first))
        # the first token is subject to the same termination rules as
        # decoded ones: max_new_tokens=1 must return exactly one token,
        # and an EOS straight out of prefill must stop generation
        sp = req.sampling
        t = time.perf_counter()
        hit_eos = sp.eos_id is not None and first == sp.eos_id
        full = len(res.new_tokens) >= sp.max_new_tokens
        timed_out = (req.deadline_s is not None and
                     t - req.arrival_t > req.deadline_s)
        if hit_eos or full or timed_out:
            res.latency = t - req.arrival_t
            res.completed = (hit_eos or full) and not timed_out
            res.timed_out = timed_out
            self._finished.append(res)
            return True                  # never occupies a decode slot
        slot = self._slots[slot_id]
        slot.req = req
        slot.res = res
        slot.pos = len(prompt)
        slot.done = False
        return True


# ---------------------------------------------------------------------------
# paged engine


DEFAULT_BLOCK_SIZE = 16


class PagedInferenceEngine(InferenceEngine):
    """Continuous-batching engine over a paged (block-pool) KV cache.

    Differences from the dense engine:
      * one global pool of ``num_blocks`` KV blocks instead of a dense
        (max_batch, max_seq) cache — admission is gated on free blocks,
        blocks are freed the step a request finishes;
      * a radix prefix cache: the cached prefix of a prompt (multi-turn
        history, shared system prompt) is leased by refcount and only the
        uncached suffix is prefilled — this is where the TTFT win on
        shared-prefix traffic comes from;
      * prompts are NOT bucket-truncated (truncation would shift token
        positions and break prefix identity); instead the uncached
        suffix is right-padded to a power-of-2 bucket and masked, which
        bounds compile specializations the same way.
    """

    paged = True

    def __init__(self, cfg: ModelConfig, params, backend: BackendProfile,
                 max_seq: int = 512, seed: int = 0, fns=None,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True):
        if not supports_paged(cfg):
            raise ValueError(f"{cfg.name}: family/attention has no paged path")
        if max_seq % block_size:
            raise ValueError(f"max_seq {max_seq} % block_size {block_size}")
        self.block_size = block_size
        self.blocks_per_seq = max_seq // block_size
        self.num_blocks = num_blocks or backend.max_batch * self.blocks_per_seq
        if self.num_blocks < self.blocks_per_seq:
            raise ValueError("pool smaller than one full sequence")
        self.pool = BlockPool(self.num_blocks, block_size)
        self.prefix: Optional[RadixPrefixCache] = (
            RadixPrefixCache(self.pool) if prefix_cache else None)
        self.hit_tokens = 0                       # prefix tokens NOT prefilled
        self.prompt_tokens = 0
        super().__init__(cfg, params, backend, max_seq, seed, fns)

    # -- hooks ----------------------------------------------------------
    def _make_slot(self) -> _PagedSlot:
        return _PagedSlot()

    def _init_cache(self):
        return init_paged_cache(self.cfg, self.num_blocks, self.block_size,
                                self._kv_dtype)

    def _compile(self) -> PagedCompiledFns:
        return compile_paged_fns(self.cfg, self.backend, self.max_seq,
                                 self.block_size)

    def _bind_fns(self) -> None:
        self._gather = self.fns.gather
        self._prefill = self.fns.prefill
        self._scatter = self.fns.scatter
        self._decode = self.fns.decode
        self._copy = self.fns.copy

    def _run_decode(self, tokens: np.ndarray, pos: np.ndarray):
        tables = np.zeros((self.max_batch, self.blocks_per_seq), np.int32)
        for i, s in enumerate(self._slots):
            if not s.done and s.table is not None:
                tables[i] = s.table
        return self._decode(self.params, jnp.asarray(tokens), self.cache,
                            jnp.asarray(tables), jnp.asarray(pos))

    # -- capacity -------------------------------------------------------
    def kv_free_frac(self) -> float:
        """Allocatable fraction of the pool — evictable prefix-cache
        blocks count as free (they are reclaimed on demand)."""
        free = self.pool.num_free
        if self.prefix:
            free += self.prefix.evictable_blocks()
        return free / self.num_blocks

    def kv_used_frac(self) -> float:
        return self.pool.used_frac

    def prefix_hit_rate(self) -> float:
        return self.hit_tokens / self.prompt_tokens if self.prompt_tokens else 0.0

    def prefix_peek(self, req: Request) -> int:
        """Cached-prefix tokens this request would reuse if admitted now
        (same prompt capping as admission). 0 without a prefix cache."""
        if not self.prefix:
            return 0
        prompt = req.tokens[-(self.max_seq - req.sampling.max_new_tokens - 1):]
        return min(self.prefix.peek(prompt), max(len(prompt) - 1, 0))

    def block_capacity(self) -> int:
        """Worst-case admissions the pool can still back (a request may
        need blocks_per_seq fresh blocks; evictable cache blocks count)."""
        blocks_free = self.pool.num_free
        if self.prefix:
            blocks_free += self.prefix.evictable_blocks()
        return blocks_free // self.blocks_per_seq

    def free_slots(self) -> int:
        """Admission capacity: free decode slots AND block headroom."""
        cap = min(self.idle_slots(), self.block_capacity())
        return max(0, cap - len(self._queue))

    # -- admission ------------------------------------------------------
    @staticmethod
    def _bucket_up(n: int) -> int:
        """Power-of-2 ceiling bucket (min 8) for the prefill SUFFIX —
        padding instead of the dense engine's truncation, so prompt
        tokens keep their absolute positions (prefix identity)."""
        b = 8
        while b < n:
            b *= 2
        return b

    def _admit(self, slot_id: int, req: Request) -> bool:
        bs = self.block_size
        prompt = req.tokens[-(self.max_seq - req.sampling.max_new_tokens - 1):]
        plen = len(prompt)

        # 1) prefix match: lease every cached full block of this prompt
        matched: List[int] = []
        keep = 0
        cow_src = None
        if self.prefix is not None:
            matched, m = self.prefix.match(prompt)
            # always recompute >= 1 token (the last token's logits seed
            # generation), so a fully-cached prompt keeps plen-1 tokens
            keep = min(m, plen - 1)
            n_keep = keep // bs
            if keep < m:                      # match overshoots the kept run
                if keep % bs:
                    cow_src = matched[n_keep]      # partial block -> COW
                    drop = matched[n_keep + 1:]
                else:
                    drop = matched[n_keep:]
                for b in drop:
                    self.pool.decref(b)
                matched = matched[:n_keep]

        # 2) allocate the rest of the sequence up front (no mid-flight OOM)
        total = min(plen + req.sampling.max_new_tokens, self.max_seq)
        n_new = math.ceil(total / bs) - len(matched)
        short = n_new - self.pool.num_free
        if short > 0 and self.prefix is not None:
            self.prefix.evict(short)
        if n_new > self.pool.num_free:
            for b in matched:                 # out of blocks: stay queued
                self.pool.decref(b)
            if cow_src is not None:
                self.pool.decref(cow_src)
            return False
        fresh = self.pool.alloc_many(n_new)
        if cow_src is not None:               # copy-on-write the shared tail
            self.cache = self._copy(self.cache, jnp.int32(cow_src),
                                    jnp.int32(fresh[0]))
            self.pool.decref(cow_src)
        owned = matched + fresh
        table = np.zeros((self.blocks_per_seq,), np.int32)
        table[:len(owned)] = owned
        self.hit_tokens += keep
        self.prompt_tokens += plen

        # 3) prefill ONLY the uncached suffix, padded to a pow2 bucket
        suffix = prompt[keep:]
        sb = self._bucket_up(len(suffix))
        padded = np.zeros((1, sb), np.int32)
        padded[0, :len(suffix)] = suffix
        # pow2 bound on the table entries holding CACHED context (the
        # suffix attends itself inside the compute core), so the gather
        # reads ~the reused prefix, not the full max_seq span
        ctx = 1
        while ctx * bs < keep:
            ctx *= 2
        ctx = min(ctx, self.blocks_per_seq)
        start, live = jnp.int32(keep), jnp.int32(len(suffix))
        ctx_kv = self._gather(self.cache, jnp.asarray(table[:ctx]))
        logits, new_kv = self._prefill(self.params, jnp.asarray(padded),
                                       ctx_kv, start, live)
        # first token is determined here (same dispatch-time TTFT
        # convention as the dense engine); the scatter below is cache
        # bookkeeping for future steps and blocks on the donated buffer
        res = GenResult(uid=req.uid, prompt_len=plen, cached_tokens=keep)
        res.ttft = time.perf_counter() - req.arrival_t
        self.cache = self._scatter(self.cache, new_kv, jnp.asarray(table),
                                   start, live)

        # 4) register the prompt's full blocks right away, so requests
        #    admitted later in this same step already share them
        if self.prefix is not None and plen >= bs:
            self.prefix.insert(prompt, table[: plen // bs].tolist())
        self.key, sk = jax.random.split(self.key)
        first = int(np.asarray(sample(logits, req.sampling, sk))[0])
        res.new_tokens.append(first)
        self._deltas.append((req.uid, first))
        sp = req.sampling
        t = time.perf_counter()
        hit_eos = sp.eos_id is not None and first == sp.eos_id
        full = len(res.new_tokens) >= sp.max_new_tokens
        timed_out = (req.deadline_s is not None and
                     t - req.arrival_t > req.deadline_s)
        if hit_eos or full or timed_out:
            res.latency = t - req.arrival_t
            res.completed = (hit_eos or full) and not timed_out
            res.timed_out = timed_out
            self._finished.append(res)
            for b in owned:                   # cache refs (if any) survive
                self.pool.decref(b)
            return True
        slot = self._slots[slot_id]
        slot.req = req
        slot.res = res
        slot.pos = plen
        slot.done = False
        slot.prompt = prompt
        slot.table = table
        slot.blocks = owned
        return True

    # -- reap -----------------------------------------------------------
    def _release(self, slot: _PagedSlot, register_prefix: bool = True) -> None:
        if slot.table is None:
            return
        if register_prefix and self.prefix is not None and slot.res is not None:
            # everything written (prompt + generated-but-last) is valid
            # KV; register its full blocks for future prefix hits
            seq = (slot.prompt + slot.res.new_tokens)[: slot.pos]
            n_full = len(seq) // self.block_size
            if n_full:
                self.prefix.insert(seq, slot.table[:n_full].tolist())
        for b in slot.blocks:
            self.pool.decref(b)
        slot.prompt = []
        slot.table = None
        slot.blocks = []
