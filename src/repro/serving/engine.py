"""In-process inference engine with slot-based continuous batching.

Real execution (CPU here, TPU mesh in production): one global KV-cache
pool of ``max_batch`` slots; requests prefill individually (B=1) and are
inserted into a free slot; every engine step runs ONE batched decode over
all active slots with per-slot positions (ragged batching — the model
decode path accepts a (B,) position vector). Finished/expired requests
free their slot immediately; waiting requests join mid-flight. This is
iteration-level (Orca-style) continuous batching, the same discipline
vLLM/TGI use.

The engine reports per-request TTFT / latency / completion, which is
exactly the telemetry the Pick-and-Spin control loop consumes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_cache, model_decode, model_prefill
from repro.serving.backend import BackendProfile
from repro.serving.sampling import SamplingParams, sample


@dataclass
class Request:
    uid: int
    tokens: List[int]
    sampling: SamplingParams
    deadline_s: Optional[float] = None
    arrival_t: float = 0.0
    src_embeds: Optional[np.ndarray] = None       # encdec stub input


@dataclass
class GenResult:
    uid: int
    prompt_len: int
    new_tokens: List[int] = field(default_factory=list)
    ttft: float = 0.0
    latency: float = 0.0
    completed: bool = False                       # finished within limits
    timed_out: bool = False


@dataclass
class _Slot:
    req: Optional[Request] = None
    res: Optional[GenResult] = None
    pos: int = 0                                  # next write position
    done: bool = True


def _insert_impl(cache, rcache, slot):
    def put(path, g, r):
        axis = 0 if any(getattr(k, "key", None) == "prefix" for k in path) else 1
        return jax.lax.dynamic_update_slice_in_dim(g, r.astype(g.dtype),
                                                   slot, axis=axis)
    return jax.tree_util.tree_map_with_path(put, cache, rcache)


@dataclass(frozen=True)
class CompiledFns:
    """Jitted step functions for one (config, backend, max_seq) service.

    Shareable across replicas: a second replica of a live service reuses
    the first replica's XLA executables, so only the first spin-up of a
    service ever pays compile — the dominant real cold-start cost. The
    replica pool caches these across scale-to-zero (its "code cache").
    """
    prefill: object
    decode: object
    insert: object


def compile_fns(cfg: ModelConfig, backend: BackendProfile,
                max_seq: int) -> CompiledFns:
    qc = backend.q_chunk

    def _prefill(params, batch):
        return model_prefill(params, cfg, batch, max_seq, q_chunk=qc)

    def _decode(params, token, cache, pos):
        return model_decode(params, cfg, token, cache, pos)

    return CompiledFns(prefill=jax.jit(_prefill), decode=jax.jit(_decode),
                       insert=jax.jit(_insert_impl))


class InferenceEngine:
    """Continuous-batching engine for one (model x backend) instance."""

    def __init__(self, cfg: ModelConfig, params, backend: BackendProfile,
                 max_seq: int = 512, seed: int = 0,
                 fns: Optional[CompiledFns] = None):
        self.cfg = cfg
        self.params = params
        self.backend = backend
        self.max_seq = max_seq
        self.max_batch = backend.max_batch
        self.key = jax.random.PRNGKey(seed)
        self._slots = [_Slot() for _ in range(self.max_batch)]
        self._queue: List[Request] = []
        self._kv_dtype = jnp.bfloat16 if backend.kv_dtype == "bfloat16" else jnp.float32
        self.cache = init_cache(cfg, self.max_batch, max_seq, self._kv_dtype)
        self._finished: List[GenResult] = []
        self.fns = fns or compile_fns(cfg, backend, max_seq)
        self._prefill = self.fns.prefill
        self._decode = self.fns.decode
        self._insert = self.fns.insert

    # -- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.arrival_t = req.arrival_t or time.perf_counter()
        self._queue.append(req)

    def has_work(self) -> bool:
        return bool(self._queue) or any(not s.done for s in self._slots)

    def free_slots(self) -> int:
        """Slots a scheduler may still fill (free minus already queued)."""
        return sum(1 for s in self._slots if s.done) - len(self._queue)

    def step(self) -> List[GenResult]:
        """Admit waiting requests, run one batched decode, reap finished."""
        now = time.perf_counter()
        # 1) admit
        for slot_id, slot in enumerate(self._slots):
            if not self._queue:
                break
            if slot.done:
                self._admit(slot_id, self._queue.pop(0))
        # 2) decode one token for all active slots
        active = [i for i, s in enumerate(self._slots) if not s.done]
        if active:
            tokens = np.zeros((self.max_batch, 1), np.int32)
            pos = np.zeros((self.max_batch,), np.int32)
            for i, s in enumerate(self._slots):
                if not s.done:
                    last = (s.res.new_tokens[-1] if s.res.new_tokens
                            else s.req.tokens[-1])
                    tokens[i, 0] = last
                    pos[i] = s.pos
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache, jnp.asarray(pos))
            # sample per request: group active slots by their SamplingParams
            # so mixed batches honor each request's temperature/top-k/top-p
            # (a single sample() over the batch would silently apply the
            # first active slot's params to everyone)
            nxt = np.zeros((self.max_batch,), np.int32)
            groups: Dict[SamplingParams, List[int]] = {}
            for i in active:
                groups.setdefault(self._slots[i].req.sampling, []).append(i)
            for sp, idxs in groups.items():
                self.key, sk = jax.random.split(self.key)
                toks = np.asarray(sample(logits[np.asarray(idxs)], sp, sk))
                for j, i in enumerate(idxs):
                    nxt[i] = toks[j]
            t = time.perf_counter()
            for i in active:
                s = self._slots[i]
                s.res.new_tokens.append(int(nxt[i]))
                s.pos += 1
                sp = s.req.sampling
                hit_eos = sp.eos_id is not None and int(nxt[i]) == sp.eos_id
                full = len(s.res.new_tokens) >= sp.max_new_tokens
                timed_out = (s.req.deadline_s is not None and
                             t - s.req.arrival_t > s.req.deadline_s)
                out_of_room = s.pos >= self.max_seq - 1
                if hit_eos or full or timed_out or out_of_room:
                    s.res.latency = t - s.req.arrival_t
                    s.res.completed = (hit_eos or full) and not timed_out
                    s.res.timed_out = timed_out
                    self._finished.append(s.res)
                    s.done = True
                    s.req = None
        return self.drain_finished()

    def drain_finished(self) -> List[GenResult]:
        out, self._finished = self._finished, []
        return out

    def run(self, requests: List[Request], max_steps: int = 100000
            ) -> List[GenResult]:
        """Synchronous convenience wrapper: serve everything to completion."""
        for r in requests:
            self.submit(r)
        results: List[GenResult] = []
        steps = 0
        while self.has_work() and steps < max_steps:
            results.extend(self.step())
            steps += 1
        return results

    # -- internals -------------------------------------------------------
    @staticmethod
    def _bucket(n: int) -> int:
        """Power-of-2 length bucket (floor, min 8) so prefill compiles a
        bounded number of specializations. Prompts are truncated from the
        left to the bucket (kept suffix), which preserves the systems
        metrics this engine exists to measure."""
        b = 8
        while b * 2 <= n:
            b *= 2
        return b

    def _admit(self, slot_id: int, req: Request) -> None:
        prompt = req.tokens[-(self.max_seq - req.sampling.max_new_tokens - 1):]
        prompt = prompt[-self._bucket(len(prompt)):]
        batch = {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])}
        if self.cfg.family == "encdec":
            se = (req.src_embeds if req.src_embeds is not None
                  else np.zeros((self.cfg.frontend_seq, self.cfg.d_model), np.float32))
            batch["src_embeds"] = jnp.asarray(se[None])
        logits, rcache = self._prefill(self.params, batch)
        self.cache = self._insert(self.cache, rcache, slot_id)
        res = GenResult(uid=req.uid, prompt_len=len(prompt))
        res.ttft = time.perf_counter() - req.arrival_t
        # first token comes from the prefill logits
        self.key, sk = jax.random.split(self.key)
        first = int(np.asarray(sample(logits, req.sampling, sk))[0])
        res.new_tokens.append(first)
        # the first token is subject to the same termination rules as
        # decoded ones: max_new_tokens=1 must return exactly one token,
        # and an EOS straight out of prefill must stop generation
        sp = req.sampling
        t = time.perf_counter()
        hit_eos = sp.eos_id is not None and first == sp.eos_id
        full = len(res.new_tokens) >= sp.max_new_tokens
        timed_out = (req.deadline_s is not None and
                     t - req.arrival_t > req.deadline_s)
        if hit_eos or full or timed_out:
            res.latency = t - req.arrival_t
            res.completed = (hit_eos or full) and not timed_out
            res.timed_out = timed_out
            self._finished.append(res)
            return                       # never occupies a decode slot
        slot = self._slots[slot_id]
        slot.req = req
        slot.res = res
        slot.pos = len(prompt)
        slot.done = False
