"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).
[arXiv:2405.04434]
60L d_model=5120 128H, MLA kv_lora=512, 2 shared + 160 routed top-6,
expert d_ff=1536, vocab=102400. First dense layer d_ff=12288.
MLA dims: qk_nope=128, qk_rope=64, v=128, q_lora=1536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,        # nominal; MLA stores a single latent KV stream
    head_dim=128,
    d_ff=12288,              # dense FFN width for the leading dense layer
    vocab_size=102400,
    attention_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1536,
    first_dense_layers=1,
    rope_theta=10000.0,
    act="silu",
)
