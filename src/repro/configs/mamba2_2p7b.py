"""mamba2-2.7b — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060]
64L d_model=2560 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, 80 SSD heads of dim 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attention_type="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=128,
    ssm_ngroups=1,
    tie_embeddings=True,
    norm_eps=1e-5,
)
