"""zamba2-1.2b — hybrid Mamba2 backbone + ONE shared attention block
applied periodically (weights shared across applications). [arXiv:2411.15242]
38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=128,
    attn_every=6,            # shared attention block after every 6 mamba layers
    rope_theta=10000.0,
    act="gelu",
)
