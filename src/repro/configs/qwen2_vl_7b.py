"""qwen2-vl-7b — VLM backbone with M-RoPE, dynamic resolution.
[arXiv:2409.12191]
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

The vision frontend (ViT + projector) is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings of shape
(batch, frontend_seq, d_model) plus (t, h, w) position triplets for M-RoPE.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    rope_theta=1000000.0,
    use_qkv_bias=True,
    act="silu",
    modality="vision",
    frontend_seq=256,                 # stubbed ViT patch embeddings per image
    mrope_sections=(16, 24, 24),      # t/h/w rotary sections (sum = head_dim/2)
)
