"""Architecture registry: ``--arch <id>`` resolution.

Maps the assigned architecture ids to their ``ModelConfig``s, carries the
Pick-and-Spin model-tier assignment used by the router (the paper's model
pool maps onto the assigned pool; see DESIGN.md §4), and records which
input shapes each arch supports (``long_500k`` skips per DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.configs import (
    command_r_plus_104b,
    deepseek_moe_16b,
    deepseek_v2_236b,
    glm4_9b,
    mamba2_2p7b,
    phi3_medium_14b,
    qwen2_vl_7b,
    seamless_m4t_medium,
    smollm_360m,
    zamba2_1p2b,
)

ARCHS: Dict[str, ModelConfig] = {
    "seamless-m4t-medium": seamless_m4t_medium.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "qwen2-vl-7b": qwen2_vl_7b.CONFIG,
    "mamba2-2.7b": mamba2_2p7b.CONFIG,
    "zamba2-1.2b": zamba2_1p2b.CONFIG,
    "phi3-medium-14b": phi3_medium_14b.CONFIG,
    "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
    "glm4-9b": glm4_9b.CONFIG,
    "smollm-360m": smollm_360m.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
}

# Pick-and-Spin model tiers (router target classes). The paper's pool
# (Gemma-3-27B / Llama-3-90B / Qwen-3-235B / DeepSeek-R1-685B) maps onto
# the assigned pool by capacity.
MODEL_TIERS: Dict[str, str] = {
    "smollm-360m": "small",
    "zamba2-1.2b": "small",
    "mamba2-2.7b": "small",
    "qwen2-vl-7b": "medium",
    "glm4-9b": "medium",
    "phi3-medium-14b": "medium",
    "deepseek-moe-16b": "medium",
    "seamless-m4t-medium": "medium",
    "command-r-plus-104b": "large",
    "deepseek-v2-236b": "large",
}

# long_500k policy (DESIGN.md §4):
#   native  — sub-quadratic decode as-is (SSM / hybrid w/ windowed shared attn)
#   sw      — runs under the sliding-window KV variant (ring buffer, 8192)
#   skip    — out of family distribution (enc-dec speech model)
LONG_CONTEXT_MODE: Dict[str, str] = {
    "mamba2-2.7b": "native",
    "zamba2-1.2b": "native",
    "smollm-360m": "sw",
    "phi3-medium-14b": "sw",
    "glm4-9b": "sw",
    "qwen2-vl-7b": "sw",
    "command-r-plus-104b": "sw",
    "deepseek-moe-16b": "sw",
    "deepseek-v2-236b": "sw",
    "seamless-m4t-medium": "skip",
}

SLIDING_WINDOW = 8192


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_config_for_shape(arch: str, shape: str) -> ModelConfig:
    """Config adjusted for an input shape (sliding-window for long_500k)."""
    cfg = get_config(arch)
    if shape == "long_500k":
        mode = LONG_CONTEXT_MODE[arch]
        if mode == "skip":
            raise ValueError(f"{arch} skips long_500k (see DESIGN.md)")
        if mode == "sw":
            cfg = cfg.with_sliding_window(SLIDING_WINDOW)
        if mode == "native" and cfg.family == "hybrid":
            cfg = cfg.with_sliding_window(SLIDING_WINDOW)
    return cfg


def supported_shapes(arch: str) -> List[InputShape]:
    out = []
    for name, shape in INPUT_SHAPES.items():
        if name == "long_500k" and LONG_CONTEXT_MODE[arch] == "skip":
            continue
        out.append(shape)
    return out


def all_pairs():
    """Every (arch, shape) combination the dry-run must pass."""
    for arch in ARCHS:
        for shape in supported_shapes(arch):
            yield arch, shape
