"""seamless-m4t-medium — encoder-decoder multimodal (speech) backbone.
[arXiv:2308.11596]
12L (enc) + 12L (dec) d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096
vocab=256206.

The audio frontend (mel-spectrogram + conformer conv feature extractor)
is a STUB per the assignment: ``input_specs()`` provides precomputed frame
embeddings (batch, frames, d_model) consumed by the text-side encoder.
No decode shapes beyond its family norms: ``long_500k`` is skipped for
this arch (full-attention enc-dec speech model; see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596",
    num_layers=12,           # decoder layers
    encoder_layers=12,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10000.0,
    act="gelu",
    modality="audio",
    frontend_seq=1024,       # stubbed audio frame embeddings
)
