from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig  # noqa: F401
