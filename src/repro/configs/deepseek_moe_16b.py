"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed experts, top-6.
[arXiv:2401.06066]
28L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=102400.
First layer uses a dense FFN (d_ff=10944), per the released architecture.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,              # dense FFN width for the leading dense layer
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10000.0,
    act="silu",
)
