"""Model configuration system.

One ``ModelConfig`` dataclass covers every assigned architecture family
(dense GQA, MLA, MoE, SSM, hybrid, encoder-decoder, VLM/audio backbones).
Each ``configs/<arch>.py`` exports ``CONFIG`` with the exact assigned
dimensions; ``ModelConfig.reduced()`` yields the CPU-smoke variant
(<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm
    source: str = ""               # citation for the assigned config

    # trunk dims
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention
    attention_type: str = "gqa"    # gqa | mla | none
    rope_theta: float = 10000.0
    use_qkv_bias: bool = False
    use_attn_out_bias: bool = False
    sliding_window: Optional[int] = None   # ring-buffer window (long-context variant)
    kv_cache_dtype: str = "bf16"           # bf16 | int8 (quantized GQA cache)
    logit_softcap: Optional[float] = None

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0    # leading layers that use dense FFN
    router_aux_coef: float = 0.001

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_ngroups: int = 1

    # hybrid (zamba2): one SHARED attention block applied every `attn_every`
    # mamba layers (weights shared across applications).
    attn_every: int = 0

    # encoder-decoder
    encoder_layers: int = 0
    cross_attention: bool = False

    # modality frontend (stubbed per assignment: input_specs provides the
    # precomputed frame/patch embeddings)
    modality: str = "text"         # text | audio | vision
    frontend_seq: int = 0          # frames/patches produced by the stub
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) dims

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"              # silu (swiglu) | gelu
    dtype: str = "bfloat16"
    max_seq_len: int = 524288

    # ---- derived -------------------------------------------------------
    @property
    def d_head_total(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def decoder_layers(self) -> int:
        return self.num_layers

    def moe_layer_indices(self) -> Tuple[int, ...]:
        if not self.has_moe:
            return ()
        return tuple(i for i in range(self.num_layers) if i >= self.first_dense_layers)

    # ---- parameter counting (used by the orchestrator cost model) -------
    def param_count(self) -> int:
        """Approximate total parameters (embeddings included)."""
        d = self.d_model
        n = 0
        # embeddings (+ output head unless tied)
        n += self.vocab_size * d
        if not self.tie_embeddings:
            n += self.vocab_size * d

        def attn_params() -> int:
            if self.attention_type == "mla":
                p = d * (self.q_lora_rank or d)
                qd = self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                p += (self.q_lora_rank or d) * qd
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                p += self.num_heads * self.v_head_dim * d
                return p
            qkv = d * self.d_head_total + 2 * d * self.kv_dim
            out = self.d_head_total * d
            return qkv + out

        def dense_ffn_params(dff: int) -> int:
            mult = 3 if self.act == "silu" else 2   # swiglu has gate+up+down
            return mult * d * dff

        def moe_ffn_params() -> int:
            routed = self.num_experts * dense_ffn_params(self.moe_d_ff) // 1
            shared = self.num_shared_experts * dense_ffn_params(self.moe_d_ff)
            router = d * self.num_experts
            return routed + shared + router

        def ssm_params() -> int:
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_nheads
            p = d * (2 * di + 2 * self.ssm_ngroups * ns + nh)  # in_proj (z,x,B,C,dt)
            p += self.ssm_conv_width * (di + 2 * self.ssm_ngroups * ns)
            p += di * d                                        # out_proj
            p += 2 * nh                                        # A_log, D
            return p

        if self.family == "ssm":
            n += self.num_layers * (ssm_params() + d)  # + norm
        elif self.family == "hybrid":
            n += self.num_layers * (ssm_params() + d)
            n += attn_params() + dense_ffn_params(self.d_ff) + 2 * d  # one shared block
        else:
            per_layer_attn = attn_params() + 2 * d
            if self.has_moe:
                moe_layers = len(self.moe_layer_indices())
                dense_layers = self.num_layers - moe_layers
                n += self.num_layers * per_layer_attn
                n += dense_layers * dense_ffn_params(self.d_ff)
                n += moe_layers * moe_ffn_params()
            else:
                n += self.num_layers * (per_layer_attn + dense_ffn_params(self.d_ff))
            if self.encoder_layers:
                n += self.encoder_layers * (attn_params() + dense_ffn_params(self.d_ff) + 2 * d)
                # decoder cross-attention
                n += self.num_layers * (attn_params() + d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top-k experts only)."""
        if not self.has_moe:
            return self.param_count()
        d = self.d_model
        mult = 3 if self.act == "silu" else 2
        expert_p = mult * d * self.moe_d_ff
        moe_layers = len(self.moe_layer_indices())
        inactive = moe_layers * (self.num_experts - self.experts_per_token) * expert_p
        return self.param_count() - inactive

    # ---- reduced smoke variant ------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU-runnable member of the same family: 2 layers, d_model<=512,
        <=4 experts, tiny vocab. Keeps every structural feature (GQA ratio,
        MLA, MoE shared+routed, SSD, hybrid period, enc-dec, M-RoPE)."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.num_heads, 4))
        kv_ratio = max(1, self.num_heads // max(1, self.num_kv_heads))
        kv = max(1, heads // min(kv_ratio, heads))
        hd = 32
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 4 * d) or 4 * d,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=4096,
        )
        if self.has_moe:
            changes.update(
                num_experts=4,
                experts_per_token=min(2, self.experts_per_token),
                num_shared_experts=min(1, self.num_shared_experts),
                moe_d_ff=max(32, d // 4),
                first_dense_layers=min(self.first_dense_layers, 1),
            )
        if self.attention_type == "mla":
            changes.update(
                kv_lora_rank=64, q_lora_rank=96,
                qk_rope_head_dim=16, qk_nope_head_dim=hd, v_head_dim=hd,
            )
        if self.family in ("ssm", "hybrid"):
            changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.family == "hybrid":
            changes.update(attn_every=1)
        if self.encoder_layers:
            changes.update(encoder_layers=2)
        if self.sliding_window:
            changes.update(sliding_window=64)
        if self.frontend_seq:
            changes.update(frontend_seq=16)
        if self.mrope_sections:
            # sections must sum to head_dim//2
            changes.update(mrope_sections=(4, 6, 6))
        return dataclasses.replace(self, **changes)

    def with_sliding_window(self, window: int = 8192) -> "ModelConfig":
        """Long-context variant: ring-buffer windowed attention."""
        return dataclasses.replace(
            self, name=self.name + "-sw", sliding_window=window)

    def with_int8_kv(self) -> "ModelConfig":
        """Serving variant: int8-quantized GQA KV cache (§Perf H1 it. 3)."""
        return dataclasses.replace(
            self, name=self.name + "-kvq", kv_cache_dtype="int8")


# ------------------------------------------------------------------------
# Input shapes assigned to this paper.
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524288, 1,   "decode"),
}
