"""Chip-second ledger: measured per-request cost attribution.

The simulator (``core/simulator.py``) *predicts* cost per query by
splitting each engine's busy time evenly across the requests sharing its
batch.  This module is the measured twin for the live serve plane: the
``ReplicaPool`` opens a ``ReplicaMeter`` per replica it spins up, every
``engine.step()`` reports its wall interval plus the uids active that
step, and the ledger

  * attributes the step's chip-seconds (wall seconds x ``chips``) evenly
    across the active requests — the simulator's shared-batch cost
    split, now measured;
  * accrues the gaps between steps (and trailing time until
    scale-to-zero retires the replica) as **idle** chip-seconds;
  * counts the measured spin-up window (param build + warm-up probes)
    as **cold** chip-seconds.

Conservation invariant (enforced in tier-1): for every ledger,

    attributed + idle + cold == total metered pool chip-seconds

where the right-hand side is computed *independently* from replica
lifetime wall-stamps, so a missed gap or double-counted step breaks it.

Hot-path discipline: ``on_step`` is pure-python accumulation into the
meter/ledger dicts — no registry writes, no device syncs.  Registry
metrics (``cost_per_query_usd`` gauge, ``request_chip_seconds``
histogram) are published from ``close_request``, which runs on the
gateway's response path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import MetricsRegistry, log_buckets

# byte-scale histogram bounds (1 KiB .. 1 TB, 3 per decade) — the default
# registry buckets are latency-shaped and would funnel KV sizes into +Inf
KV_BYTE_BUCKETS = log_buckets(1024.0, 1e12, per_decade=3)

# dtype string -> bytes per element, for config-derived resident sizes.
DTYPE_BYTES = {
    "float32": 4, "f32": 4, "fp32": 4,
    "bfloat16": 2, "bf16": 2, "float16": 2, "f16": 2, "fp16": 2,
    "int8": 1, "uint8": 1, "fp8": 1,
}


def dtype_nbytes(dtype: str) -> int:
    return DTYPE_BYTES.get(dtype, 2)


def param_bytes(cfg) -> int:
    """Resident parameter bytes from the config's own accounting
    (``param_count()`` x dtype width) — the production-shape figure the
    cost model prices, independent of any reduced test arch."""
    return int(cfg.param_count()) * dtype_nbytes(getattr(cfg, "dtype", "bfloat16"))


@dataclass
class ReplicaMeter:
    """Busy/idle/cold chip-second accumulator for one live replica."""
    model: str
    backend: str
    chips: int
    live_t: float                 # wall stamp when the replica went live
    cold_s: float = 0.0           # measured spin-up wall seconds
    busy_chip_s: float = 0.0
    idle_chip_s: float = 0.0
    mark: float = 0.0             # end of the last accounted interval
    down_t: Optional[float] = None

    def __post_init__(self) -> None:
        self.mark = self.live_t


class CostLedger:
    """Pool-wide chip-second ledger with per-request attribution."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 usd_per_chip_hour: Optional[float] = None):
        if usd_per_chip_hour is None:
            from repro.core.costmodel import USD_PER_CHIP_HOUR
            usd_per_chip_hour = USD_PER_CHIP_HOUR
        self.registry = registry
        self.usd_per_chip_hour = usd_per_chip_hour
        self.meters: List[ReplicaMeter] = []
        self.attributed_chip_s = 0.0          # running total, never decremented
        self._live: Dict[int, float] = {}     # uid -> chip-seconds so far
        self._model_usd: Dict[str, float] = {}
        self._model_n: Dict[str, int] = {}

    # -- replica lifecycle ----------------------------------------------
    def replica_up(self, model: str, backend: str, chips: int,
                   cold_s: float, t: float) -> ReplicaMeter:
        m = ReplicaMeter(model=model, backend=backend, chips=chips,
                         live_t=t, cold_s=cold_s)
        self.meters.append(m)
        return m

    def replica_down(self, meter: ReplicaMeter, t: float) -> None:
        if meter.down_t is not None:
            return
        tail = max(0.0, t - meter.mark)
        meter.idle_chip_s += tail * meter.chips
        meter.mark = meter.down_t = max(t, meter.mark)

    # -- hot path --------------------------------------------------------
    def on_step(self, meter: ReplicaMeter, t0: float, t1: float,
                uids: Sequence[int]) -> None:
        """Account one engine step over wall interval [t0, t1] with
        ``uids`` active.  The gap since the previous step is idle."""
        gap = t0 - meter.mark
        if gap > 0.0:
            meter.idle_chip_s += gap * meter.chips
        chip_s = max(0.0, t1 - t0) * meter.chips
        if uids:
            meter.busy_chip_s += chip_s
            share = chip_s / len(uids)
            live = self._live
            for u in uids:
                live[u] = live.get(u, 0.0) + share
            self.attributed_chip_s += chip_s
        else:
            meter.idle_chip_s += chip_s
        if t1 > meter.mark:
            meter.mark = t1

    # -- response path ---------------------------------------------------
    def close_request(self, uid: int, model: str,
                      t: Optional[float] = None) -> Optional[Tuple[float, float]]:
        """Finalize a request's attribution: returns ``(chip_seconds,
        cost_usd)``, or None if the uid never ran a step (shed before
        admission)."""
        chip_s = self._live.pop(uid, None)
        if chip_s is None:
            return None
        usd = chip_s * self.usd_per_chip_hour / 3600.0
        self._model_usd[model] = self._model_usd.get(model, 0.0) + usd
        self._model_n[model] = self._model_n.get(model, 0) + 1
        if self.registry is not None:
            mean = self._model_usd[model] / self._model_n[model]
            self.registry.gauge("cost_per_query_usd", model).set(mean, stamp=t)
            self.registry.histogram("request_chip_seconds",
                                    model).observe(chip_s)
        return chip_s, usd

    # -- accounting queries ----------------------------------------------
    def totals(self, now: Optional[float] = None) -> Dict[str, float]:
        """Ledger totals.  ``total`` is recomputed from replica lifetime
        wall-stamps — NOT from the busy/idle accumulators — so it is an
        independent check on the interval chaining.

        With ``now=None`` the ledger falls back to the newest timestamp
        it has itself observed (marks and down stamps), NOT the wall
        clock: the ledger's time domain is whatever its callers stamp
        with, and a ``time.perf_counter()`` fallback silently corrupts
        totals for simulated-clock drivers."""
        if now is None:
            now = max((m.down_t if m.down_t is not None else m.mark
                       for m in self.meters), default=0.0)
        busy = idle = cold = total = 0.0
        for m in self.meters:
            end = m.down_t if m.down_t is not None else now
            busy += m.busy_chip_s
            idle += m.idle_chip_s
            if m.down_t is None:
                idle += max(0.0, end - m.mark) * m.chips   # pending gap
            cold += m.cold_s * m.chips
            total += (max(0.0, end - m.live_t) + m.cold_s) * m.chips
        return {"busy_chip_s": busy, "idle_chip_s": idle,
                "cold_chip_s": cold, "total_chip_s": total,
                "attributed_chip_s": self.attributed_chip_s,
                "inflight_chip_s": sum(self._live.values())}

    def conservation_error(self, now: Optional[float] = None) -> float:
        """|attributed + idle + cold - total| / total (0.0 when empty)."""
        t = self.totals(now)
        if t["total_chip_s"] <= 0.0:
            return 0.0
        lhs = t["attributed_chip_s"] + t["idle_chip_s"] + t["cold_chip_s"]
        return abs(lhs - t["total_chip_s"]) / t["total_chip_s"]

    def cost_per_query_usd(self, model: str) -> float:
        n = self._model_n.get(model, 0)
        return self._model_usd.get(model, 0.0) / n if n else 0.0
