"""Serve-plane observability: metrics registry, request tracing, exporters.

``Observability`` is the per-plane bundle a ``ServeFrontend`` owns — ONE
registry + tracer + event log + cost ledger + flight recorder shared by
the scheduler, the replica pool and every engine it spins.  ``EngineObs``
is the slice handed to one engine (same objects, plus the service labels
and that replica's chip-second meter), so engine hot-path hooks never
look their service name up.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.obs.cost import (CostLedger, ReplicaMeter,  # noqa: F401
                            dtype_nbytes, param_bytes)
from repro.obs.export import (EventLog, prometheus_text,  # noqa: F401
                              write_metrics_dump)
from repro.obs.flight import FlightConfig, FlightRecorder  # noqa: F401
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge,  # noqa: F401
                               Histogram, MetricsRegistry, log_buckets,
                               snapshot_quantile)
from repro.obs.trace import Span, Tracer  # noqa: F401


@dataclass
class Observability:
    """One serve plane's shared observability surfaces."""
    registry: MetricsRegistry = field(default_factory=MetricsRegistry)
    tracer: Tracer = None
    events: EventLog = field(default_factory=EventLog)
    ledger: CostLedger = None
    flight: FlightRecorder = None

    def __post_init__(self) -> None:
        if self.tracer is None:
            self.tracer = Tracer(self.registry)
        if self.ledger is None:
            self.ledger = CostLedger(registry=self.registry)
        if self.flight is None:
            self.flight = FlightRecorder(events=self.events)

    def engine_obs(self, model: str, backend: str) -> "EngineObs":
        return EngineObs(registry=self.registry, tracer=self.tracer,
                         model=model, backend=backend,
                         cost=self.ledger, flight=self.flight)


@dataclass
class EngineObs:
    """One engine's view: the shared registry/tracer plus its labels,
    the pool ledger, and (once spun up) this replica's meter."""
    registry: MetricsRegistry
    tracer: Tracer
    model: str = ""
    backend: str = ""
    cost: Optional[CostLedger] = None
    flight: Optional[FlightRecorder] = None
    meter: Optional[ReplicaMeter] = None
