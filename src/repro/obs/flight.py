"""Anomaly flight recorder: bounded ring of per-step engine snapshots.

Always-on when metrics are on: every ``engine.step()`` appends one small
host-side dict (active slots, pending tokens, free blocks, budget spent,
burst depth) to a bounded ring.  When an anomaly trips — shed rate over
threshold across a trailing admission window, a deadline-expiry burst,
or an engine exception — the ring plus the tail of the ``EventLog`` is
dumped to JSONL so the minutes *before* the incident survive it.  A dump
can also be forced on demand (``--flight-record PATH``).

JSONL schema (one object per line, appended per dump):

    {"record": "dump",  "reason": ..., "t": ..., "steps": N, "events": M}
    {"record": "step",  "model": ..., "t": ..., "active": ..., ...}
    {"record": "event", "event": ..., "t": ..., ...}

Triggers honor a cooldown so a sustained storm produces one dump per
window, not one per request.  All timestamps are threaded from callers
(the scheduler's clock — wall or simulated), never sampled here.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

from repro.obs.export import EventLog


@dataclass
class FlightConfig:
    capacity: int = 512           # step snapshots retained
    event_tail: int = 256         # EventLog entries included per dump
    shed_window: int = 64         # trailing admissions considered
    shed_rate: float = 0.5        # trip when >= this fraction shed ...
    min_admissions: int = 16      # ... over at least this many arrivals
    expiry_window_s: float = 10.0
    expiry_burst: int = 8         # deadline expiries within the window
    cooldown_s: float = 5.0       # min spacing between automatic dumps
    path: Optional[str] = None    # JSONL sink; None = in-memory only


class FlightRecorder:
    def __init__(self, config: Optional[FlightConfig] = None,
                 events: Optional[EventLog] = None):
        self.config = config or FlightConfig()
        self.events = events
        self.steps: Deque[Dict] = deque(maxlen=self.config.capacity)
        self.dumps: List[Dict] = []          # dump metadata, for tests/CLI
        self._admits: Deque[int] = deque(maxlen=self.config.shed_window)
        self._expiries: Deque[float] = deque()
        self._last_dump_t: Optional[float] = None

    # -- ring ------------------------------------------------------------
    def record_step(self, model: str, t: float, **snapshot) -> None:
        """One engine step.  Host-side dict append only — never called
        with device values."""
        self.steps.append({"record": "step", "model": model,
                           "t": t, **snapshot})

    # -- anomaly triggers --------------------------------------------------
    def note_admission(self, shed: bool, t: float) -> None:
        self._admits.append(1 if shed else 0)
        n = len(self._admits)
        if n < self.config.min_admissions:
            return
        rate = sum(self._admits) / n
        if rate >= self.config.shed_rate:
            if self.trigger("shed_storm", t, shed_rate=round(rate, 4),
                            window=n):
                self._admits.clear()        # re-arm on a fresh window

    def note_expiry(self, t: float) -> None:
        self._expiries.append(t)
        cut = t - self.config.expiry_window_s
        while self._expiries and self._expiries[0] < cut:
            self._expiries.popleft()
        if len(self._expiries) >= self.config.expiry_burst:
            if self.trigger("expiry_burst", t, expiries=len(self._expiries)):
                self._expiries.clear()

    def note_exception(self, model: str, err: BaseException, t: float) -> None:
        self.trigger("engine_exception", t, model=model,
                     error=f"{type(err).__name__}: {err}")

    # -- dumping -----------------------------------------------------------
    def trigger(self, reason: str, t: float, **fields) -> bool:
        """Automatic dump, rate-limited by the cooldown.  Returns True
        if a dump was taken."""
        if (self._last_dump_t is not None
                and t - self._last_dump_t < self.config.cooldown_s):
            return False
        self._last_dump_t = t
        self.dump(reason, t=t, **fields)
        return True

    def dump(self, reason: str = "on-demand", t: float = 0.0,
             path: Optional[str] = None, **fields) -> Optional[str]:
        """Write the ring + event tail as JSONL (append).  Returns the
        path written, or None when no sink is configured (the dump is
        still recorded in ``self.dumps``)."""
        tail = []
        if self.events is not None:
            tail = list(self.events.events)[-self.config.event_tail:]
        meta = {"record": "dump", "reason": reason, "t": t,
                "steps": len(self.steps), "events": len(tail), **fields}
        self.dumps.append(meta)
        sink = path or self.config.path
        if sink is None:
            return None
        with open(sink, "a") as f:
            f.write(json.dumps(meta) + "\n")
            for s in self.steps:
                f.write(json.dumps(s) + "\n")
            for e in tail:
                f.write(json.dumps({"record": "event", **e}) + "\n")
        return sink
