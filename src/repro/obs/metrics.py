"""Labeled metrics registry for the serve plane.

Three instrument kinds, keyed by ``(name, label)`` where the label is
the model/service the sample belongs to:

  * ``Counter``   — monotone event count (sheds, preemptions, tokens);
  * ``Gauge``     — last-written point-in-time value, stamped with a
    monotonic set-time so merged snapshots keep the NEWEST write
    (max-by-timestamp is associative, unlike raw last-write-wins);
  * ``Histogram`` — fixed LOG-SPACED buckets over (1e-5, 1e4] with
    p50/p95/p99 quantile queries.  ``observe`` is one ``bisect`` plus a
    handful of float ops, cheap enough to run on the host side of every
    engine step; quantiles log-interpolate inside the landing bucket
    and clamp to the observed min/max, so the error is bounded by one
    bucket ratio (``10**(1/per_decade)``).

Snapshots are plain dicts of plain data and MERGE: counters and bucket
counts add, gauges keep the newest stamp, histogram min/max fold — all
associative and commutative, so ``ReplicaPool`` can aggregate per-engine
snapshots in any order and a multi-process collector could do the same.
"""
from __future__ import annotations

import math
import time
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Tuple


def log_buckets(lo: float = 1e-5, hi: float = 1e4,
                per_decade: int = 10) -> Tuple[float, ...]:
    """Upper bounds of log-spaced buckets covering (lo, hi]. Values at or
    below ``lo`` land in the first bucket; above ``hi`` in the +Inf
    overflow bucket (implicit: one more count slot than bounds)."""
    bounds: List[float] = []
    n = int(round(math.log10(hi / lo) * per_decade))
    for i in range(n + 1):
        bounds.append(lo * 10.0 ** (i / per_decade))
    return tuple(bounds)


DEFAULT_BUCKETS = log_buckets()


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last write wins, with a monotonic stamp so merges are associative
    (newest stamp survives regardless of merge order)."""
    __slots__ = ("value", "stamp")

    def __init__(self) -> None:
        self.value = 0.0
        self.stamp = 0.0

    def set(self, v: float, stamp: Optional[float] = None) -> None:
        self.value = float(v)
        self.stamp = time.perf_counter() if stamp is None else stamp


class Histogram:
    """Fixed log-spaced buckets + running sum/count/min/max."""
    __slots__ = ("bounds", "counts", "sum", "count", "min", "max")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_BUCKETS) -> None:
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # +1: +Inf overflow
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1]. Log-interpolated within the landing bucket and
        clamped to the observed [min, max]; 0.0 when empty."""
        if not self.count:
            return 0.0
        return _quantile(self.bounds, self.counts, self.count, q,
                         self.min, self.max)

    def snapshot(self) -> dict:
        return {"bounds": tuple(self.bounds), "counts": tuple(self.counts),
                "sum": self.sum, "count": self.count,
                "min": self.min, "max": self.max}


def _quantile(bounds, counts, total, q, vmin, vmax) -> float:
    target = q * total
    acc = 0.0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if acc + c >= target:
            # log-interpolate inside bucket i: (lo_i, hi_i]
            hi = bounds[i] if i < len(bounds) else vmax
            lo = bounds[i - 1] if i > 0 else min(vmin, hi)
            frac = (target - acc) / c
            if lo > 0 and hi > 0:
                est = lo * (hi / lo) ** frac
            else:                        # non-positive samples: linear
                est = lo + (hi - lo) * frac
            return min(max(est, vmin), vmax)
        acc += c
    return vmax


def snapshot_quantile(h: dict, q: float) -> float:
    """Quantile query over a histogram SNAPSHOT (e.g. after a merge)."""
    if not h["count"]:
        return 0.0
    return _quantile(h["bounds"], h["counts"], h["count"], q,
                     h["min"], h["max"])


_Key = Tuple[str, str]


class MetricsRegistry:
    """Get-or-create instruments keyed by ``(name, label)``."""

    def __init__(self) -> None:
        self._counters: Dict[_Key, Counter] = {}
        self._gauges: Dict[_Key, Gauge] = {}
        self._hists: Dict[_Key, Histogram] = {}

    # -- instruments -----------------------------------------------------
    def counter(self, name: str, label: str = "") -> Counter:
        key = (name, label)
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, label: str = "") -> Gauge:
        key = (name, label)
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, label: str = "",
                  bounds: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        key = (name, label)
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram(bounds)
        return h

    # -- queries ---------------------------------------------------------
    def quantile(self, name: str, label: str = "", q: float = 0.95) -> float:
        h = self._hists.get((name, label))
        return h.quantile(q) if h is not None else 0.0

    def value(self, name: str, label: str = "") -> float:
        """Counter or gauge value (0.0 when absent)."""
        c = self._counters.get((name, label))
        if c is not None:
            return c.value
        g = self._gauges.get((name, label))
        return g.value if g is not None else 0.0

    def labels(self, name: str) -> List[str]:
        return sorted({lb for (n, lb) in
                       list(self._counters) + list(self._gauges)
                       + list(self._hists) if n == name})

    # -- snapshots -------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: (g.stamp, g.value) for k, g in self._gauges.items()},
            "histograms": {k: h.snapshot() for k, h in self._hists.items()},
        }

    @staticmethod
    def merge(a: dict, b: dict) -> dict:
        """Associative + commutative snapshot merge: counters and bucket
        counts add, gauges keep the newest (stamp, value), histogram
        min/max fold."""
        out = {"counters": dict(a["counters"]),
               "gauges": dict(a["gauges"]),
               "histograms": {k: dict(v) for k, v in a["histograms"].items()}}
        for k, v in b["counters"].items():
            out["counters"][k] = out["counters"].get(k, 0.0) + v
        for k, sv in b["gauges"].items():
            cur = out["gauges"].get(k)
            out["gauges"][k] = sv if cur is None else max(cur, sv)
        for k, h in b["histograms"].items():
            cur = out["histograms"].get(k)
            if cur is None:
                out["histograms"][k] = dict(h)
            else:
                if cur["bounds"] != h["bounds"]:
                    raise ValueError(f"bucket mismatch merging {k}")
                out["histograms"][k] = {
                    "bounds": cur["bounds"],
                    "counts": tuple(x + y for x, y in
                                    zip(cur["counts"], h["counts"])),
                    "sum": cur["sum"] + h["sum"],
                    "count": cur["count"] + h["count"],
                    "min": min(cur["min"], h["min"]),
                    "max": max(cur["max"], h["max"])}
        return out

    @classmethod
    def merge_all(cls, snaps: Iterable[dict]) -> dict:
        out: Optional[dict] = None
        for s in snaps:
            out = s if out is None else cls.merge(out, s)
        return out if out is not None else {
            "counters": {}, "gauges": {}, "histograms": {}}
