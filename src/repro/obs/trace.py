"""Per-request lifecycle tracing for the serve plane.

One ``Span`` per request, assembled ENTIRELY from host-side event points
that already exist on the serve path — submit (frontend), admit (slot
occupied), each prefill chunk, first token, decode/burst token replay,
and the terminal resolution (finish / shed / cancel / timeout).  Every
timestamp is ``time.perf_counter()`` taken in host code the engine was
already running (the ``drain_deltas()``/``_consume_reason`` replay), so
tracing adds ZERO device->host syncs: the PR-5 transfer-guard contract
(decode moves only ``(max_batch,)`` int32 ids) holds with tracing on.

The tracer doubles as the per-service latency instrument: when built
with a ``MetricsRegistry`` it observes ``queue_wait_s`` at admit,
``ttft_s`` at first token, ``itl_s`` per decode token (burst iterations
spread their replay wall evenly over the K tokens) and ``e2e_s`` at
finish, labeled by model — the TTFT/ITL distributions Algorithm-1-style
control loops need, at histogram-update cost.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry


@dataclass
class Span:
    """One request's lifecycle. Timestamps are ``perf_counter`` values;
    0.0 means the phase never happened (e.g. shed before admission)."""
    uid: int
    model: str = ""
    backend: str = ""
    submit_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0
    last_token_t: float = 0.0
    chunks: int = 0                   # prefill passes
    chunk_tokens: int = 0             # prompt tokens actually prefilled
    decode_tokens: int = 0            # tokens sampled (incl. first)
    outcome: str = ""                 # stop|length|shed|cancelled|timeout
    chip_seconds: float = 0.0         # attributed device-seconds x chips
    cost_usd: float = 0.0             # chip_seconds at USD_PER_CHIP_HOUR
    # (event, t, value) in order: submit/admit/chunk/first_token/
    # decode (one entry per drain, value = tokens)/finish
    events: List[Tuple[str, float, float]] = field(default_factory=list)

    # -- derived phase durations ----------------------------------------
    @property
    def queue_wait_s(self) -> float:
        return max(self.admit_t - self.submit_t, 0.0) if self.admit_t else 0.0

    @property
    def prefill_s(self) -> float:
        if not (self.admit_t and self.first_token_t):
            return 0.0
        return max(self.first_token_t - self.admit_t, 0.0)

    @property
    def decode_s(self) -> float:
        if not (self.first_token_t and self.finish_t):
            return 0.0
        return max(self.finish_t - self.first_token_t, 0.0)

    @property
    def ttft_s(self) -> float:
        if not (self.submit_t and self.first_token_t):
            return 0.0
        return max(self.first_token_t - self.submit_t, 0.0)

    @property
    def e2e_s(self) -> float:
        return max(self.finish_t - self.submit_t, 0.0) if self.finish_t else 0.0

    def complete(self) -> bool:
        """Full lifecycle recorded: queue -> prefill chunk(s) -> first
        token -> decode -> finish."""
        return bool(self.admit_t and self.chunks >= 1 and self.first_token_t
                    and self.decode_tokens >= 1 and self.finish_t
                    and self.outcome)

    def to_dict(self) -> dict:
        return {
            "uid": self.uid, "model": self.model, "backend": self.backend,
            "outcome": self.outcome, "submit_t": self.submit_t,
            "admit_t": self.admit_t, "first_token_t": self.first_token_t,
            "finish_t": self.finish_t, "queue_wait_s": self.queue_wait_s,
            "prefill_s": self.prefill_s, "decode_s": self.decode_s,
            "ttft_s": self.ttft_s, "e2e_s": self.e2e_s,
            "chunks": self.chunks, "chunk_tokens": self.chunk_tokens,
            "decode_tokens": self.decode_tokens,
            "chip_seconds": self.chip_seconds, "cost_usd": self.cost_usd,
            "events": [list(e) for e in self.events],
        }


class Tracer:
    """Collects spans. Open spans live in a uid-keyed dict; finished
    spans move to a bounded ring (``max_spans``) for export. Events for
    unknown uids open a span lazily at admit (standalone engines), and
    negative uids (warm-up probes) are ignored so compile-time TTFTs
    never pollute the distributions."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 max_spans: int = 4096, keep_events: bool = True):
        self.registry = registry
        self.keep_events = keep_events
        self._live: Dict[int, Span] = {}
        self.finished: Deque[Span] = deque(maxlen=max_spans)

    def __len__(self) -> int:
        return len(self.finished)

    # -- lifecycle event points -----------------------------------------
    def on_submit(self, uid: int, model: str, backend: str,
                  t: float) -> None:
        if uid < 0:
            return
        span = Span(uid=uid, model=model, backend=backend, submit_t=t)
        if self.keep_events:
            span.events.append(("submit", t, 0.0))
        self._live[uid] = span

    def on_admit(self, uid: int, t: float, arrival_t: float = 0.0,
                 model: str = "", backend: str = "") -> None:
        if uid < 0:
            return
        span = self._live.get(uid)
        if span is None:                # standalone engine: open lazily
            span = Span(uid=uid, model=model, backend=backend,
                        submit_t=arrival_t or t)
            self._live[uid] = span
        span.admit_t = t
        if self.keep_events:
            span.events.append(("admit", t, 0.0))
        if self.registry is not None:
            self.registry.histogram("queue_wait_s", span.model).observe(
                span.queue_wait_s)

    def on_chunk(self, uid: int, t: float, n: int) -> None:
        span = self._live.get(uid)
        if span is None:
            return
        span.chunks += 1
        span.chunk_tokens += n
        if self.keep_events:
            span.events.append(("chunk", t, float(n)))

    def on_first_token(self, uid: int, t: float) -> None:
        span = self._live.get(uid)
        if span is None:
            return
        span.first_token_t = t
        span.last_token_t = t
        span.decode_tokens += 1
        if self.keep_events:
            span.events.append(("first_token", t, 1.0))
        if self.registry is not None:
            self.registry.histogram("ttft_s", span.model).observe(span.ttft_s)

    def on_tokens(self, uid: int, t: float, n: int = 1) -> None:
        """``n`` decode tokens landed for ``uid`` at host time ``t`` —
        one call per request per drain (a burst replay passes its whole
        accepted run, and the wall since the previous token spreads
        evenly over it)."""
        span = self._live.get(uid)
        if span is None or n <= 0:
            return
        if self.registry is not None and span.last_token_t:
            itl = max(t - span.last_token_t, 0.0) / n
            h = self.registry.histogram("itl_s", span.model)
            for _ in range(n):
                h.observe(itl)
        span.decode_tokens += n
        span.last_token_t = t
        if self.keep_events:
            span.events.append(("decode", t, float(n)))

    def on_finish(self, uid: int, t: float, outcome: str) -> Optional[Span]:
        """Close ``uid``'s span with its terminal resolution and move it
        to the finished ring. Returns the span (None if unknown)."""
        span = self._live.pop(uid, None)
        if span is None:
            return None
        span.finish_t = t
        span.outcome = outcome
        if self.keep_events:
            span.events.append(("finish", t, 0.0))
        if self.registry is not None:
            self.registry.histogram("e2e_s", span.model).observe(span.e2e_s)
        self.finished.append(span)
        return span

    # -- export ----------------------------------------------------------
    def drain(self) -> List[Span]:
        out = list(self.finished)
        self.finished.clear()
        return out

    def records(self) -> List[dict]:
        return [s.to_dict() for s in self.finished]
