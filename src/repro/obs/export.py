"""Exporters: Prometheus-style text exposition + JSONL event/span logs.

``prometheus_text`` renders a ``MetricsRegistry`` snapshot in the
Prometheus exposition format (counters, gauges, and histograms with
cumulative ``le`` buckets plus ``_sum``/``_count``, labeled by model) —
scrape-shaped, so pointing a real collector at a future HTTP frontend is
a transport problem, not a data-model one.

``EventLog`` is the serve plane's structured decision log: scale-up /
scale-to-zero decisions from ``Orchestrator.tick()``, shed / preempt /
cancel / expire events from the scheduler, and cold starts from
``ReplicaPool`` — each one a dict with a wall timestamp, written out as
JSON Lines.  This is the record that makes control-loop behavior
debuggable after the fact.

``write_metrics_dump(path, ...)`` is the one-call artifact writer behind
``launch/serve.py --metrics-dump`` and the benchmark drivers: exposition
text at ``path``, events at ``path + ".events.jsonl"``, finished request
spans at ``path + ".spans.jsonl"``.
"""
from __future__ import annotations

import json
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


class EventLog:
    """Bounded structured event log (newest ``maxlen`` kept)."""

    def __init__(self, maxlen: int = 8192):
        self.events: Deque[dict] = deque(maxlen=maxlen)

    def append(self, event: str, t: Optional[float] = None, **fields) -> None:
        rec = {"event": event,
               "t": time.perf_counter() if t is None else t}
        rec.update(fields)
        self.events.append(rec)

    def __len__(self) -> int:
        return len(self.events)

    def of(self, event: str) -> List[dict]:
        return [e for e in self.events if e["event"] == event]

    def to_jsonl(self) -> str:
        return "".join(json.dumps(e) + "\n" for e in self.events)


def _fmt(v: float) -> str:
    if v != v:                                     # NaN
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def _name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


def _esc(v: str) -> str:
    """Escape a label VALUE per the Prometheus exposition spec:
    backslash, double-quote and newline."""
    return (v.replace("\\", "\\\\").replace('"', '\\"')
             .replace("\n", "\\n"))


def _label_pairs(label: str) -> List[tuple]:
    """Registry labels are either a bare model name or a composite
    ``model|k=v|k2=v2`` (e.g. ``kv_pool_bytes``'s ``mdl|state=used``).
    Returns ``(key, value)`` pairs in exposition order."""
    if not label:
        return []
    parts = label.split("|")
    pairs = [("model", parts[0])] if parts[0] else []
    for p in parts[1:]:
        k, _, v = p.partition("=")
        pairs.append((_name(k), v))
    return pairs


def _label(label: str) -> str:
    pairs = _label_pairs(label)
    if not pairs:
        return ""
    body = ",".join(f'{k}="{_esc(v)}"' for k, v in pairs)
    return "{" + body + "}"


def prometheus_text(snapshot: dict, prefix: str = "repro_") -> str:
    """Render a ``MetricsRegistry.snapshot()`` (or a merge of several)
    as Prometheus text exposition."""
    lines: List[str] = []
    typed: set = set()       # metric names with a # TYPE line already out
    by_name: Dict[str, list] = {}
    for (name, label), v in sorted(snapshot.get("counters", {}).items()):
        by_name.setdefault(("counter", name), []).append((label, v))
    for (name, label), (_t, v) in sorted(snapshot.get("gauges", {}).items()):
        by_name.setdefault(("gauge", name), []).append((label, v))
    for (kind, name), rows in sorted(by_name.items()):
        metric = prefix + _name(name)
        if metric not in typed:
            lines.append(f"# TYPE {metric} {kind}")
            typed.add(metric)
        for label, v in rows:
            lines.append(f"{metric}{_label(label)} {_fmt(v)}")
    hists = snapshot.get("histograms", {})
    for (name, label) in sorted(hists):
        h = hists[(name, label)]
        metric = prefix + _name(name)
        if metric not in typed:
            lines.append(f"# TYPE {metric} histogram")
            typed.add(metric)
        pairs = _label_pairs(label)
        lab = "".join(f'{k}="{_esc(v)}",' for k, v in pairs)
        acc = 0
        for bound, c in zip(list(h["bounds"]) + [math.inf], h["counts"]):
            acc += c
            lines.append(f'{metric}_bucket{{{lab}le="{_fmt(bound)}"}} {acc}')
        lines.append(f"{metric}_sum{_label(label)} {_fmt(h['sum'])}")
        lines.append(f"{metric}_count{_label(label)} {h['count']}")
    return "\n".join(lines) + "\n"


def write_metrics_dump(path: str, registry: MetricsRegistry,
                       events: Optional[EventLog] = None,
                       tracer: Optional[Tracer] = None) -> List[str]:
    """Write the full observability artifact set. Returns the paths
    written: exposition text at ``path``, plus ``.events.jsonl`` /
    ``.spans.jsonl`` siblings when an event log / tracer is given."""
    paths = [path]
    with open(path, "w") as f:
        f.write(prometheus_text(registry.snapshot()))
    if events is not None:
        p = path + ".events.jsonl"
        with open(p, "w") as f:
            f.write(events.to_jsonl())
        paths.append(p)
    if tracer is not None:
        p = path + ".spans.jsonl"
        with open(p, "w") as f:
            for rec in tracer.records():
                f.write(json.dumps(rec) + "\n")
        paths.append(p)
    return paths
