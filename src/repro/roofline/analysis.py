"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text, summing output
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with while-loop (scan) bodies multiplied by their
trip count (recovered from the loop condition's comparison constant).
``cost_analysis`` under scan is cross-checked against the analytic
6*N*D model-FLOPs and a trip-count correction is applied when XLA
reports the loop body only once (logged per entry).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# TPU v5e constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s/link

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every TYPE[dims] group in a (possibly tuple) shape."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (full names, incl. '.clone' suffixes)."""
    comps: Dict[str, str] = {}
    cur_name: Optional[str] = None
    cur_lines: List[str] = []
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if m:
            cur_name = m.group(1)
            cur_lines = []
            continue
        if cur_name is not None:
            if line.startswith("}"):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
            else:
                cur_lines.append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


_COLL_RE = re.compile(
    r"=\s*(?P<shape>.*?)\s"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start|-done)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")


def _trip_count(cond_body: str) -> int:
    """Loop bound: the largest integer constant compared in the condition."""
    consts = [int(c) for c in
              re.findall(r"constant\((\d+)\)", cond_body)]
    return max(consts) if consts else 1


def parse_collectives(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)

    # direct collective bytes per computation
    direct: Dict[str, Dict[str, int]] = {}
    counts: Dict[str, Dict[str, int]] = {}
    whiles: Dict[str, List[Tuple[str, str]]] = {}   # comp -> [(body, cond)]
    for name, body in comps.items():
        d: Dict[str, int] = {}
        c: Dict[str, int] = {}
        for line in body.splitlines():
            m = _COLL_RE.search(line)
            if m and m.group("variant") != "-done":   # count starts once
                b = _shape_bytes(m.group("shape"))
                op = m.group("op")
                d[op] = d.get(op, 0) + b
                c[op] = c.get(op, 0) + 1
            wm = _WHILE_RE.search(line)
            if wm:
                whiles.setdefault(name, []).append((wm.group(2), wm.group(1)))
        direct[name] = d
        counts[name] = c

    # expand while bodies by trip count (one level of nesting handled by
    # recursion)
    def total(name: str, depth: int = 0) -> Tuple[Dict[str, int], Dict[str, int]]:
        if depth > 8 or name not in direct:
            return {}, {}
        d = dict(direct[name])
        c = dict(counts[name])
        for body, cond in whiles.get(name, []):
            trips = _trip_count(comps.get(cond, ""))
            bd, bc = total(body, depth + 1)
            for k, v in bd.items():
                d[k] = d.get(k, 0) + v * trips
            for k, v in bc.items():
                c[k] = c.get(k, 0) + v * trips
        return d, c

    if entry:
        d, c = total(entry)
    else:   # fallback: flat sum
        d, c = {}, {}
        for dd in direct.values():
            for k, v in dd.items():
                d[k] = d.get(k, 0) + v
        for cc in counts.values():
            for k, v in cc.items():
                c[k] = c.get(k, 0) + v
    return CollectiveStats(bytes_by_op=d, count_by_op=c)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float          # analytic 6*N_active*D (train) or 2*N*D
    scan_corrected: bool
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        self.collective_s = self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "scan_corrected": self.scan_corrected,
        }


def analytic_model_flops(param_count_active: int, shape_kind: str,
                         tokens: int) -> float:
    """6*N*D for training; 2*N*D for inference (per step tokens)."""
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * param_count_active * tokens


def analytic_memory_bytes(param_count: int, active_param_count: int,
                          shape_kind: str, tokens: int, d_model: int,
                          num_layers: int, cache_bytes: int = 0) -> float:
    """HBM-traffic floor per step (the scan undercount makes raw HLO bytes
    a lower bound too; the roofline memory term takes the max of both).

      train   : params f32 (read+write) + grads f32 (write+read) +
                AdamW mu/nu f32 (read+write each) + activation traffic
                (~14 d_model-sized tensors per layer per token, bf16,
                x2 for the remat recompute pass)
      prefill : weights bf16 read + activation traffic + cache write
      decode  : active weights bf16 read (streamed once per step) +
                full cache read + activations (1 token)
    """
    act_traffic = 14 * tokens * d_model * num_layers * 2     # bf16
    if shape_kind == "train":
        params_traffic = param_count * 4 * (2 + 2 + 4)       # p, g, mu, nu
        return params_traffic + 2 * act_traffic
    if shape_kind == "prefill":
        return 2 * active_param_count + act_traffic + cache_bytes
    # decode
    return 2 * active_param_count + cache_bytes + 14 * d_model * num_layers * 2
