"""Zamba2-style hybrid trunk: Mamba2 backbone + ONE shared attention
transformer block applied after every ``attn_every`` mamba layers
(weights shared across applications, each application owning its own KV
cache). [arXiv:2411.15242]

Simplification recorded in DESIGN.md: Zamba2 concatenates the original
embedding stream into the shared block's input and applies per-application
LoRA deltas; we feed the running hidden state directly and share the block
verbatim. The scheduling structure (periodic shared global-attention over a
linear-time SSM backbone) — which is what matters for serving cost and for
the orchestrator's latency model — is preserved.

Layer grouping: mamba layers run under ``lax.scan`` per group
(num_layers split into ceil(L / attn_every) groups), the shared attention
block is unrolled between groups.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (Params, embed_init, init_rmsnorm, rmsnorm,
                                 rope_cos_sin, stack_init)
from repro.models.mlp import ffn, init_ffn
from repro.models.ssm import (init_mamba2, init_mamba2_state, mamba2_decode,
                              mamba2_forward)
from repro.models.transformer import _adtype, unembed


def _groups(cfg: ModelConfig):
    """[(start, end, has_attn_after)] covering all mamba layers."""
    k = cfg.attn_every
    out = []
    i = 0
    while i < cfg.num_layers:
        j = min(i + k, cfg.num_layers)
        out.append((i, j, j - i == k))
        i = j
    return out


def num_attn_applications(cfg: ModelConfig) -> int:
    return sum(1 for _, _, a in _groups(cfg) if a)


def init_hybrid(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "layers": stack_init(ks[1], cfg.num_layers, lambda k: {
            "norm": init_rmsnorm(cfg.d_model, dtype),
            "mixer": init_mamba2(cfg, k, dtype),
        }),
        "shared_attn_norm": init_rmsnorm(cfg.d_model, dtype),
        "shared_attn": attn.init_gqa(cfg, ks[2], dtype),
        "shared_ffn_norm": init_rmsnorm(cfg.d_model, dtype),
        "shared_ffn": init_ffn(cfg, ks[3], dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[4], cfg.vocab_size, cfg.d_model, dtype)
    return p


def _slice_layers(layers: Params, a: int, b: int) -> Params:
    return jax.tree_util.tree_map(lambda x: x[a:b], layers)


def _shared_block_full(params, cfg, h, cos, sin, q_chunk):
    x = rmsnorm(params["shared_attn_norm"], h, cfg.norm_eps)
    h = h + attn.gqa_full(params["shared_attn"], cfg, x, cos, sin,
                          q_chunk=q_chunk)
    x = rmsnorm(params["shared_ffn_norm"], h, cfg.norm_eps)
    return h + ffn(params["shared_ffn"], cfg, x)


def hybrid_forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
                   remat: bool = True, q_chunk: int = 512,
                   return_hidden: bool = False,
                   **_) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = params["embed"][tokens].astype(_adtype(cfg))
    B, S, _ = h.shape
    cos, sin = rope_cos_sin(jnp.arange(S)[None, :].repeat(B, 0),
                            cfg.head_dim, cfg.rope_theta)

    def mamba_body(h, lp):
        x = rmsnorm(lp["norm"], h, cfg.norm_eps)
        return h + mamba2_forward(lp["mixer"], cfg, x), None

    if remat:
        mamba_body = jax.checkpoint(mamba_body)
    for a, b, has_attn in _groups(cfg):
        h, _ = jax.lax.scan(mamba_body, h, _slice_layers(params["layers"], a, b))
        if has_attn:
            h = _shared_block_full(params, cfg, h, cos, sin, q_chunk)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    return unembed(params, cfg, h), jnp.zeros((), jnp.float32)


def hybrid_prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   cache_len: int, *, q_chunk: int = 512,
                   **_) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][tokens].astype(_adtype(cfg))
    B, S, _ = h.shape
    cos, sin = rope_cos_sin(jnp.arange(S)[None, :].repeat(B, 0),
                            cfg.head_dim, cfg.rope_theta)
    eff = cache_len if cfg.sliding_window is None else cfg.sliding_window

    def mamba_body(h, lp):
        x = rmsnorm(lp["norm"], h, cfg.norm_eps)
        o, st = mamba2_forward(lp["mixer"], cfg, x, return_state=True)
        return h + o, st

    mamba_states, attn_caches = [], []
    for a, b, has_attn in _groups(cfg):
        h, st = jax.lax.scan(mamba_body, h, _slice_layers(params["layers"], a, b))
        mamba_states.append(st)
        if has_attn:
            x = rmsnorm(params["shared_attn_norm"], h, cfg.norm_eps)
            o, c = attn.gqa_prefill(params["shared_attn"], cfg, x, cos, sin,
                                    eff, q_chunk=q_chunk)
            h = h + o
            x = rmsnorm(params["shared_ffn_norm"], h, cfg.norm_eps)
            h = h + ffn(params["shared_ffn"], cfg, x)
            attn_caches.append(c)
    mamba_stack = jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *mamba_states)
    attn_stack = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=0), *attn_caches)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h[:, -1]), {"mamba": mamba_stack,
                                            "attn": attn_stack}


def hybrid_decode(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                  cache: Params, pos, **_) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token].astype(_adtype(cfg))
    B = h.shape[0]
    p_ = jnp.asarray(pos, jnp.int32)
    positions = jnp.full((B, 1), p_) if p_.ndim == 0 else p_[:, None]
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)

    def mamba_body(h, xs):
        lp, st = xs
        x = rmsnorm(lp["norm"], h, cfg.norm_eps)
        o, st = mamba2_decode(lp["mixer"], cfg, x, st)
        return h + o, st

    new_mamba, new_attn = [], []
    app = 0
    for a, b, has_attn in _groups(cfg):
        lp = _slice_layers(params["layers"], a, b)
        st = jax.tree_util.tree_map(lambda x: x[a:b], cache["mamba"])
        h, st = jax.lax.scan(mamba_body, h, (lp, st))
        new_mamba.append(st)
        if has_attn:
            c = jax.tree_util.tree_map(lambda x: x[app], cache["attn"])
            x = rmsnorm(params["shared_attn_norm"], h, cfg.norm_eps)
            o, c = attn.gqa_decode(params["shared_attn"], cfg, x, cos, sin, c, pos)
            h = h + o
            x = rmsnorm(params["shared_ffn_norm"], h, cfg.norm_eps)
            h = h + ffn(params["shared_ffn"], cfg, x)
            new_attn.append(c)
            app += 1
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    new_cache = {
        "mamba": jax.tree_util.tree_map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba),
        "attn": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *new_attn),
    }
    return unembed(params, cfg, h[:, -1]), new_cache


def init_hybrid_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None) -> Params:
    dtype = dtype or _adtype(cfg)
    eff = cache_len if cfg.sliding_window is None else min(cfg.sliding_window, cache_len)
    one = init_mamba2_state(cfg, batch, dtype)
    mamba = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one)
    napp = num_attn_applications(cfg)
    kv = jnp.zeros((napp, batch, eff, cfg.num_kv_heads, cfg.head_dim), dtype)
    return {"mamba": mamba, "attn": {"k": kv, "v": kv}}
