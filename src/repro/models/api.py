"""Unified model API: family dispatch for init / forward / prefill / decode.

Batch dicts (see ``launch.specs.input_specs`` for the dry-run versions):
  dense | moe        {"tokens": (B, S) i32}
  vlm                {"tokens": (B, S_text) i32,
                      "vision_embeds": (B, F, d) — stubbed ViT output,
                      "positions": (B, F + S_text, 3) M-RoPE triplets}
  encdec (audio)     {"tokens": (B, S) i32,
                      "src_embeds": (B, F, d) — stubbed audio frontend}
  ssm | hybrid       {"tokens": (B, S) i32}
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.common import Params


def init_model(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    if cfg.family == "ssm":
        return ssm_lm.init_ssm_lm(cfg, key, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid(cfg, key, dtype)
    if cfg.family == "encdec":
        return encdec.init_encdec(cfg, key, dtype)
    return transformer.init_lm(cfg, key, dtype)


def model_forward(params: Params, cfg: ModelConfig, batch: dict, *,
                  remat: bool = True, q_chunk: int = 512, moe_cf=1.25,
                  return_hidden: bool = False
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full (causal) forward for training. Returns (logits, moe-aux), or
    (hidden, moe-aux) with ``return_hidden`` (chunked-CE path)."""
    tokens = batch["tokens"]
    if cfg.family == "ssm":
        return ssm_lm.ssm_lm_forward(params, cfg, tokens, remat=remat,
                                     return_hidden=return_hidden)
    if cfg.family == "hybrid":
        return hybrid.hybrid_forward(params, cfg, tokens, remat=remat,
                                     q_chunk=q_chunk,
                                     return_hidden=return_hidden)
    if cfg.family == "encdec":
        return encdec.encdec_forward(params, cfg, tokens,
                                     src_embeds=batch["src_embeds"],
                                     remat=remat, q_chunk=q_chunk,
                                     return_hidden=return_hidden)
    return transformer.lm_forward(
        params, cfg, tokens,
        positions=batch.get("positions"),
        extra_embeds=batch.get("vision_embeds"),
        remat=remat, q_chunk=q_chunk, moe_cf=moe_cf,
        return_hidden=return_hidden)


def model_prefill(params: Params, cfg: ModelConfig, batch: dict,
                  cache_len: int, *, q_chunk: int = 512, moe_cf=1.25
                  ) -> Tuple[jnp.ndarray, Params]:
    tokens = batch["tokens"]
    if cfg.family == "ssm":
        return ssm_lm.ssm_lm_prefill(params, cfg, tokens)
    if cfg.family == "hybrid":
        return hybrid.hybrid_prefill(params, cfg, tokens, cache_len,
                                     q_chunk=q_chunk)
    if cfg.family == "encdec":
        return encdec.encdec_prefill(params, cfg, tokens, cache_len,
                                     src_embeds=batch["src_embeds"],
                                     q_chunk=q_chunk)
    return transformer.lm_prefill(
        params, cfg, tokens, cache_len,
        positions=batch.get("positions"),
        extra_embeds=batch.get("vision_embeds"),
        q_chunk=q_chunk, moe_cf=moe_cf)


def model_decode(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                 cache: Params, pos, *,
                 positions: Optional[jnp.ndarray] = None, moe_cf=None
                 ) -> Tuple[jnp.ndarray, Params]:
    if cfg.family == "ssm":
        return ssm_lm.ssm_lm_decode(params, cfg, token, cache, pos)
    if cfg.family == "hybrid":
        return hybrid.hybrid_decode(params, cfg, token, cache, pos)
    if cfg.family == "encdec":
        return encdec.encdec_decode(params, cfg, token, cache, pos)
    return transformer.lm_decode(params, cfg, token, cache, pos,
                                 positions=positions, moe_cf=moe_cf)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> Params:
    if cfg.family == "ssm":
        return ssm_lm.init_ssm_cache(cfg, batch, cache_len, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_hybrid_cache(cfg, batch, cache_len, dtype)
    if cfg.family == "encdec":
        return encdec.init_encdec_cache(cfg, batch, cache_len, dtype=dtype)
    return transformer.init_lm_cache(cfg, batch, cache_len, dtype)
