"""Mamba2 LM trunk (attention-free): scan over SSD blocks."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, embed_init, init_rmsnorm, rmsnorm, stack_init
from repro.models.ssm import (init_mamba2, init_mamba2_state, mamba2_decode,
                              mamba2_forward)
from repro.models.transformer import _adtype, unembed


def init_ssm_lm(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "embed": embed_init(k1, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "layers": stack_init(k2, cfg.num_layers, lambda k: {
            "norm": init_rmsnorm(cfg.d_model, dtype),
            "mixer": init_mamba2(cfg, k, dtype),
        }),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(k3, cfg.vocab_size, cfg.d_model, dtype)
    return p


def ssm_lm_forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
                   remat: bool = True, return_hidden: bool = False,
                   **_) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = params["embed"][tokens].astype(_adtype(cfg))

    def body(h, lp):
        x = rmsnorm(lp["norm"], h, cfg.norm_eps)
        h = h + mamba2_forward(lp["mixer"], cfg, x)
        return h, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    return unembed(params, cfg, h), jnp.zeros((), jnp.float32)


def ssm_lm_prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   cache_len: int = 0, **_) -> Tuple[jnp.ndarray, Params]:
    """cache_len is irrelevant for SSMs (O(1) state)."""
    h = params["embed"][tokens].astype(_adtype(cfg))

    def body(h, lp):
        x = rmsnorm(lp["norm"], h, cfg.norm_eps)
        o, state = mamba2_forward(lp["mixer"], cfg, x, return_state=True)
        return h + o, state

    h, states = jax.lax.scan(body, h, params["layers"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h[:, -1]), {"stack": states}


def ssm_lm_decode(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                  cache: Params, pos, **_) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token].astype(_adtype(cfg))

    def body(h, xs):
        lp, st = xs
        x = rmsnorm(lp["norm"], h, cfg.norm_eps)
        o, st = mamba2_decode(lp["mixer"], cfg, x, st)
        return h + o, st

    h, new_states = jax.lax.scan(body, h, (params["layers"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h[:, -1]), {"stack": new_states}


def init_ssm_cache(cfg: ModelConfig, batch: int, cache_len: int = 0,
                   dtype=None) -> Params:
    dtype = dtype or _adtype(cfg)
    one = init_mamba2_state(cfg, batch, dtype)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one)
    return {"stack": stacked}
