"""Encoder-decoder trunk (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings ``src_embeds`` (B, F, d_model) supplied by
``input_specs()``. The text decoder is causal self-attention +
cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (Params, embed_init, init_rmsnorm, rmsnorm,
                                 rope_cos_sin, stack_init)
from repro.models.mlp import ffn, init_ffn
from repro.models.transformer import _adtype, unembed


def init_encdec(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_gqa(cfg, k1, dtype),
            "ffn_norm": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_ffn(cfg, k2, dtype=dtype),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "self_norm": init_rmsnorm(cfg.d_model, dtype),
            "self_attn": attn.init_gqa(cfg, k1, dtype),
            "cross_norm": init_rmsnorm(cfg.d_model, dtype),
            "cross_attn": attn.init_gqa(cfg, k2, dtype),
            "ffn_norm": init_rmsnorm(cfg.d_model, dtype),
            "ffn": init_ffn(cfg, k3, dtype=dtype),
        }

    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": stack_init(ks[1], cfg.encoder_layers, enc_block),
        "enc_norm": init_rmsnorm(cfg.d_model, dtype),
        "dec_layers": stack_init(ks[2], cfg.num_layers, dec_block),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
        "lm_head": embed_init(ks[3], cfg.vocab_size, cfg.d_model, dtype),
    }


def encode(params: Params, cfg: ModelConfig, src_embeds: jnp.ndarray, *,
           q_chunk: int = 512, remat: bool = True) -> jnp.ndarray:
    h = src_embeds.astype(_adtype(cfg))
    B, F, _ = h.shape
    cos, sin = rope_cos_sin(jnp.arange(F)[None, :].repeat(B, 0),
                            cfg.head_dim, cfg.rope_theta)

    def body(h, lp):
        x = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        h = h + attn.gqa_full(lp["attn"], cfg, x, cos, sin, causal=False,
                              q_chunk=q_chunk)
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        return h + ffn(lp["ffn"], cfg, x), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def encdec_forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
                   src_embeds: Optional[jnp.ndarray] = None, q_chunk: int = 512,
                   remat: bool = True, return_hidden: bool = False,
                   **_) -> Tuple[jnp.ndarray, jnp.ndarray]:
    enc = encode(params, cfg, src_embeds, q_chunk=q_chunk, remat=remat)
    h = params["embed"][tokens].astype(_adtype(cfg))
    B, S, _ = h.shape
    cos, sin = rope_cos_sin(jnp.arange(S)[None, :].repeat(B, 0),
                            cfg.head_dim, cfg.rope_theta)

    def body(h, lp):
        x = rmsnorm(lp["self_norm"], h, cfg.norm_eps)
        h = h + attn.gqa_full(lp["self_attn"], cfg, x, cos, sin, q_chunk=q_chunk)
        x = rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
        kv = attn.cross_kv(lp["cross_attn"], cfg, enc)
        h = h + attn.cross_attend(lp["cross_attn"], cfg, x, kv, q_chunk=q_chunk)
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        return h + ffn(lp["ffn"], cfg, x), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    return unembed(params, cfg, h), jnp.zeros((), jnp.float32)


def encdec_prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   cache_len: int, *, src_embeds: Optional[jnp.ndarray] = None,
                   q_chunk: int = 512, **_) -> Tuple[jnp.ndarray, Params]:
    enc = encode(params, cfg, src_embeds, q_chunk=q_chunk, remat=False)
    h = params["embed"][tokens].astype(_adtype(cfg))
    B, S, _ = h.shape
    cos, sin = rope_cos_sin(jnp.arange(S)[None, :].repeat(B, 0),
                            cfg.head_dim, cfg.rope_theta)

    def body(h, lp):
        x = rmsnorm(lp["self_norm"], h, cfg.norm_eps)
        o, self_c = attn.gqa_prefill(lp["self_attn"], cfg, x, cos, sin,
                                     cache_len, q_chunk=q_chunk)
        h = h + o
        x = rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
        kv = attn.cross_kv(lp["cross_attn"], cfg, enc)
        h = h + attn.cross_attend(lp["cross_attn"], cfg, x, kv, q_chunk=q_chunk)
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        return h + ffn(lp["ffn"], cfg, x), {"self": self_c, "cross": kv}

    h, caches = jax.lax.scan(body, h, params["dec_layers"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h[:, -1]), {"stack": caches}


def encdec_decode(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                  cache: Params, pos, **_) -> Tuple[jnp.ndarray, Params]:
    h = params["embed"][token].astype(_adtype(cfg))
    B = h.shape[0]
    p_ = jnp.asarray(pos, jnp.int32)
    positions = jnp.full((B, 1), p_) if p_.ndim == 0 else p_[:, None]
    cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)

    def body(h, xs):
        lp, c = xs
        x = rmsnorm(lp["self_norm"], h, cfg.norm_eps)
        o, self_c = attn.gqa_decode(lp["self_attn"], cfg, x, cos, sin,
                                    c["self"], pos)
        h = h + o
        x = rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
        o = attn.decode_attention_jnp(
            (x @ lp["cross_attn"]["wq"].astype(x.dtype)).reshape(
                B, 1, cfg.num_heads, cfg.head_dim),
            c["cross"]["k"], c["cross"]["v"],
            jnp.int32(c["cross"]["k"].shape[1]))
        o = attn._out_proj(lp["cross_attn"], cfg, o)
        h = h + o
        x = rmsnorm(lp["ffn_norm"], h, cfg.norm_eps)
        return h + ffn(lp["ffn"], cfg, x), {"self": self_c, "cross": c["cross"]}

    h, new_stack = jax.lax.scan(body, h, (params["dec_layers"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, cfg, h[:, -1]), {"stack": new_stack}


def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      enc_len: Optional[int] = None, dtype=None) -> Params:
    dtype = dtype or _adtype(cfg)
    enc_len = enc_len or cfg.frontend_seq
    L = cfg.num_layers
    kv = lambda s: jnp.zeros((L, batch, s, cfg.num_kv_heads, cfg.head_dim), dtype)
    return {"stack": {
        "self": {"k": kv(cache_len), "v": kv(cache_len)},
        "cross": {"k": kv(enc_len), "v": kv(enc_len)},
    }}
