"""Feed-forward blocks: SwiGLU (gate/up/down) and GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, act_fn, dense_init


def init_ffn(cfg: ModelConfig, key, d_ff: int = 0, dtype=jnp.float32) -> Params:
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act == "silu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": dense_init(k1, d, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d, dtype),
    }


def ffn(params: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    act = act_fn(cfg.act)
    if "w_gate" in params:
        h = act(x @ params["w_gate"].astype(x.dtype)) * (x @ params["w_up"].astype(x.dtype))
    else:
        h = act(x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)
