"""Mixture-of-Experts FFN: shared experts + fine-grained routed top-k.

Implementation: capacity-based scatter dispatch (static shapes, SPMD
friendly, differentiable):

  1. router softmax over experts; top-k per token (weights renormalized);
  2. per-(token, k) slot position inside its expert via a cumsum rank over
     the flattened token axis; tokens past ``capacity`` are dropped
     (their combine weight contributes nothing — residual carries them);
  3. scatter tokens into an (E, C, d) buffer; one batched einsum per
     FFN matrix runs every expert on its C slots — compute scales with
     topk * tokens * capacity_factor, NOT with num_experts;
  4. gather + weighted combine back to (B, S, d).

The expert dimension shards over the ``model`` mesh axis (expert
parallelism); XLA lowers the scatter/gather into the all-to-all pattern.
Aux load-balance loss follows Switch/DeepSeek: E * sum_e f_e * p_e.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, act_fn, dense_init


def init_moe(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)

    def ew(k, a, b):
        return (jax.random.normal(k, (e, a, b), jnp.float32) / jnp.sqrt(a)).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),   # router kept f32
        "w_gate": ew(ks[1], d, f),
        "w_up": ew(ks[2], d, f),
        "w_down": ew(ks[3], f, d),
    }
    if cfg.num_shared_experts:
        from repro.models.mlp import init_ffn
        p["shared"] = init_ffn(cfg, ks[4], d_ff=cfg.num_shared_experts * f, dtype=dtype)
    return p


def moe_ffn(
    params: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,                 # (B, S, d)
    *,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, aux_loss).

    ``capacity_factor=None`` means no-drop: capacity = T (worst case every
    token routes one of its top-k picks to the same expert). Used for
    decode steps, where T is small and exactness matters more than the
    dispatch-buffer size.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)
    act = act_fn(cfg.act)

    logits = xt.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style on full probs + top-k counts)
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)       # (T, K, E)
    frac_tokens = one_hot.sum(axis=(0, 1)) / (T * K)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # position of each (token, k) inside its expert queue
    flat_e = top_e.reshape(T * K)                               # token-major
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)             # (T*K, E)
    pos_in_e = (jnp.cumsum(oh, axis=0) - oh)                    # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]

    if capacity_factor is None:
        C = T
    else:
        C = max(1, min(T, int(capacity_factor * T * K / E)))
    keep = pos < C
    w = top_p.reshape(T * K) * keep                             # dropped -> 0

    # scatter tokens into (E, C, d)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E, C, d), x.dtype)
    safe_pos = jnp.where(keep, pos, C - 1)
    buf = buf.at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], xt[tok_idx], 0).astype(x.dtype))

    # expert FFN on (E, C, d)
    h = act(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(x.dtype))

    # gather + combine
    y_tok = y[flat_e, safe_pos]                                 # (T*K, d)
    contrib = y_tok * w[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok_idx].add(contrib)

    if "shared" in params:
        from repro.models.mlp import ffn
        out = out + ffn(params["shared"], cfg, xt)
    return out.reshape(B, S, d), aux.astype(jnp.float32)
