"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Chunked SSD forward (training/prefill): intra-chunk dual-form matmuls +
inter-chunk state recurrence under ``lax.scan`` — the structure the Pallas
``ssd_scan`` kernel tiles for the MXU (chunk = 128 aligns the Q x Q and
Q x N matmuls to hardware tiles). Decode is the O(1) recurrent update —
this is why SSM archs are the natural ``long_500k`` servers (DESIGN.md §4).

State layout per layer:
  conv_state: (B, conv_w - 1, d_conv_channels)   causal-conv tail
  ssm_state:  (B, H, P, N)                       SSD recurrent state
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Params, dense_init, rmsnorm

NEG_INF = -1e30


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    nh = cfg.ssm_nheads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_ngroups
    conv_ch = di + 2 * G * N
    return di, nh, P, N, G, conv_ch


def init_mamba2(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    di, nh, P, N, G, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 6)
    # separate z / xBC / dt projections (single fused matrix has a width
    # like 10832 that no mesh axis divides — split keeps TP clean)
    p = {
        "in_z": dense_init(ks[4], d, di, dtype),
        "in_xbc": dense_init(ks[5], d, conv_ch, dtype),
        "in_dt": dense_init(ks[0], d, nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) / math.sqrt(cfg.ssm_conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": dense_init(ks[3], di, d, dtype),
    }
    return p


# ---------------------------------------------------------------------------
# chunked SSD core


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q) -> (..., Q, Q) with out[i, j] = sum_{k=j+1..i} a_k for
    i >= j (diag 0), -inf above the diagonal."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, NEG_INF)


def ssd_chunked(
    x: jnp.ndarray,        # (B, L, H, P) — already dt-scaled NO (raw)
    dt: jnp.ndarray,       # (B, L, H) — post-softplus
    A: jnp.ndarray,        # (H,) negative
    Bm: jnp.ndarray,       # (B, L, H, N) — group-broadcast to heads
    Cm: jnp.ndarray,       # (B, L, H, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,   # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N)). Computation in f32."""
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (L + pad) // Q

    f32 = jnp.float32
    xc = x.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, H, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, H, N).astype(f32)
    a = dtc * A.astype(f32)[None, None, None, :]          # (B,nc,Q,H)
    a_hq = jnp.moveaxis(a, -1, -2)                        # (B,nc,H,Q)
    a_cum = jnp.cumsum(a_hq, axis=-1)                     # (B,nc,H,Q)
    xdt = xc * dtc[..., None]                             # dt-scaled input

    # intra-chunk (dual / attention-like form)
    Lmat = jnp.exp(_segsum(a_hq))                         # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", Cc, Bc, Lmat, xdt)

    # per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)       # (B,nc,H,Q)
    states = jnp.einsum("bcshn,bchs,bcshp->bchpn", Bc, decay_states, xdt)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[..., -1])                 # (B,nc,H)
    h0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((Bsz, H, P, N), f32))

    def step(h, inp):
        s_c, g_c = inp                                    # (B,H,P,N), (B,H)
        h_prev = h
        h = h * g_c[..., None, None] + s_c
        return h, h_prev

    states_s = jnp.moveaxis(states, 1, 0)                 # (nc,B,H,P,N)
    decay_s = jnp.moveaxis(chunk_decay, 1, 0)             # (nc,B,H)
    final, prev_states = jax.lax.scan(step, h0, (states_s, decay_s))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B,nc,H,P,N)

    # contribution of the carried-in state within each chunk
    state_decay = jnp.exp(a_cum)                          # (B,nc,H,Q)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, P)
    if pad:
        y = y[:, :L]
    return y, final


# ---------------------------------------------------------------------------
# causal depthwise conv1d


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x: (B, L, C); w: (W, C) depthwise taps; tail: (B, W-1, C) carry-in."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    L = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + L] * w[i].astype(x.dtype)
    return y + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# block forward


def _in_proj(params: Params, cfg: ModelConfig, x: jnp.ndarray):
    z = x @ params["in_z"].astype(x.dtype)
    xBC = x @ params["in_xbc"].astype(x.dtype)
    dt = x @ params["in_dt"].astype(x.dtype)
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC: jnp.ndarray):
    di, nh, P, N, G, _ = _dims(cfg)
    xs = xBC[..., :di]
    Bm = xBC[..., di:di + G * N]
    Cm = xBC[..., di + G * N:]
    B_, L = xs.shape[:2]
    xs = xs.reshape(B_, L, nh, P)
    rep = nh // G
    Bm = jnp.repeat(Bm.reshape(B_, L, G, N), rep, axis=2)
    Cm = jnp.repeat(Cm.reshape(B_, L, G, N), rep, axis=2)
    return xs, Bm, Cm


def mamba2_forward(
    params: Params, cfg: ModelConfig, x: jnp.ndarray,
    state: Optional[Params] = None, return_state: bool = False,
):
    """Full-sequence forward. x: (B, L, d). Returns y (+ state dict)."""
    di, nh, P, N, G, conv_ch = _dims(cfg)
    B_, L, _ = x.shape
    z, xBC_raw, dt_raw = _in_proj(params, cfg, x)
    xBC = xBC_raw
    tail = state["conv"] if state is not None else None
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"], tail))
    xs, Bm, Cm = _split_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    init = state["ssm"] if state is not None else None
    y, final = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B_, L, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    if return_state:
        prev_tail = (tail if tail is not None else
                     jnp.zeros((B_, cfg.ssm_conv_width - 1, conv_ch), x.dtype))
        new_tail = jnp.concatenate([prev_tail, xBC_raw],
                                   axis=1)[:, -(cfg.ssm_conv_width - 1):]
        return out, {"conv": new_tail, "ssm": final.astype(jnp.float32)}
    return out


def mamba2_decode(
    params: Params, cfg: ModelConfig, x: jnp.ndarray, state: Params,
) -> Tuple[jnp.ndarray, Params]:
    """One-token recurrent step. x: (B, 1, d)."""
    di, nh, P, N, G, conv_ch = _dims(cfg)
    B_ = x.shape[0]
    z, xBC_new, dt_raw = _in_proj(params, cfg, x)

    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), xBC_new], axis=1)
    w = params["conv_w"].astype(x.dtype)
    xBC = jnp.einsum("bwc,wc->bc", conv_in, w)[:, None, :] + params["conv_b"].astype(x.dtype)
    xBC = jax.nn.silu(xBC)
    new_conv = conv_in[:, 1:]

    xs, Bm, Cm = _split_xbc(cfg, xBC)                     # (B,1,H,P/N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])[:, 0]   # (B,H)
    A = -jnp.exp(params["A_log"])
    g = jnp.exp(dt * A[None, :])                          # (B,H)
    h = state["ssm"].astype(jnp.float32)                  # (B,H,P,N)
    xdt = xs[:, 0].astype(jnp.float32) * dt[..., None]    # (B,H,P)
    h = h * g[..., None, None] + jnp.einsum("bhp,bhn->bhpn", xdt,
                                            Bm[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, Cm[:, 0].astype(jnp.float32))
    y = y + params["D"][None, :, None] * xs[:, 0].astype(jnp.float32)
    y = y.reshape(B_, 1, di).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"conv": new_conv, "ssm": h}


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, nh, P, N, G, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, P, N), jnp.float32),
    }
