from repro.models.api import (init_cache, init_model, model_decode,  # noqa: F401
                              model_forward, model_prefill)
